"""Elastic training with FT-managed membership + tree weight broadcast.

    PYTHONPATH=src python examples/elastic_train.py

Walks through the FaaSNet-on-TPU story end to end (host-level simulation +
real training on this process):
1. 8 hosts join the elastic pool — each streams the checkpoint from its FT
   parent, never the central store (except the first).
2. Straggler + failure are injected; the FT repairs; training restarts
   from the latest block checkpoint and reproduces the reference loss.
3. The device-plane broadcast schedules are compared on serialized link
   traffic (the §Perf "paper-representative" metric).
"""
import sys

sys.path.insert(0, "src")

from repro.configs import ModelConfig
from repro.distributed.broadcast import binomial_rounds, faasnet_rounds
from repro.distributed.elastic import ElasticConfig, ElasticCoordinator
from repro.distributed.fault import FaultCoordinator
from repro.train.loop import SimulatedFailure, run_train

CFG = ModelConfig(
    name="elastic_demo", family="dense", n_layers=3, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=344, vocab_size=1024, attn_impl="full", remat="none",
)


def main() -> None:
    print("== 1. elastic join: 8 hosts, weights stream down the FT ==")
    ec = ElasticCoordinator(ElasticConfig(payload_bytes=2 * 10**9))
    for i in range(8):
        r = ec.join(now=float(i))
        src = r.upstream or "CENTRAL STORE"
        print(f"  host{i}: from {src:13s} in {r.provision_latency_s:5.1f}s "
              f"(tree height {r.tree_height})")
    print(f"  mesh proposal (tp=16): data x model = {ec.propose_mesh(16)}")

    print("== 2. failure: FT repair + checkpoint restart ==")
    fc = FaultCoordinator(ec.mgr)
    for h in ec.hosts:
        fc.monitor.beat(h, 0.0)
    victim = ec.hosts[2]
    for h in ec.hosts:
        if h != victim:
            fc.monitor.beat(h, 40.0)
    actions = fc.tick(now=45.0)
    print(f"  dead={actions['dead']} -> tree repaired, "
          f"{len(ec.hosts)} hosts remain, height "
          f"{ec.mgr.trees[ec.cfg.model_id].height}")

    ckpt = "/tmp/repro_elastic"
    import shutil

    shutil.rmtree(ckpt, ignore_errors=True)
    try:
        run_train(CFG, steps=20, seq_len=64, batch=4, ckpt_dir=ckpt,
                  ckpt_every=10, fail_at_step=15, log_every=5)
    except SimulatedFailure as e:
        print(f"  {e} -> restarting from latest checkpoint")
    res = run_train(CFG, steps=20, seq_len=64, batch=4, ckpt_dir=ckpt,
                    ckpt_every=10, log_every=5)
    print(f"  resumed from step {res.resumed_from}, finished at "
          f"{res.final_step}, loss {res.losses[20]:.4f}")

    print("== 3. device-plane broadcast schedules (32 DP replicas, 2 GB) ==")
    payload, bw = 2e9, 50e9
    for name, ser in (
        ("naive (registry analogue)", 31 * payload),
        ("allgather", 32 * payload),
        ("binomial tree", 5 * payload),
        ("FaaSNet pipelined tree", len(faasnet_rounds(32, 32)) * payload / 32),
        ("  + int8 compression", len(faasnet_rounds(32, 32)) * payload / 64),
    ):
        print(f"  {name:28s} serialized {ser/1e9:7.1f} GB  "
              f"modeled {ser/bw:6.2f}s")
    print("OK")


if __name__ == "__main__":
    main()
