"""Burst serving with FaaSNet cold starts (paper §4.2 + §4.6, end to end).

    PYTHONPATH=src python examples/burst_serving.py

1. Trains a tiny LM briefly and checkpoints it in the block format.
2. Cold-starts a serving engine TWO ways: full restore vs FaaSNet lazy
   (on-demand) restore — printing time-to-first-weights and bytes fetched.
3. Simulates a 64-VM provisioning burst for the same checkpoint payload
   under faasnet / on-demand / baseline to show the fleet-level effect.
4. Serves a batch of requests through prefill + decode.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ModelConfig
from repro.models import model_for
from repro.serving.engine import ServeEngine
from repro.sim import WaveConfig, provision_wave
from repro.train.loop import run_train

CFG = ModelConfig(
    name="serve_demo", family="dense", n_layers=4, d_model=192, n_heads=6,
    n_kv_heads=2, d_ff=512, vocab_size=2048, attn_impl="full", remat="none",
)


def main() -> None:
    ckpt_dir = "/tmp/repro_burst_serving"
    print("== 1. train briefly + checkpoint (block format) ==")
    run_train(CFG, steps=12, seq_len=128, batch=4, ckpt_dir=ckpt_dir,
              ckpt_every=12, log_every=6)
    mgr_train = CheckpointManager(ckpt_dir)
    step = mgr_train.latest_step()
    model = model_for(CFG)
    import jax

    from repro.train.step import init_train_state

    like = model.init(jax.random.key(0))
    # export a serving checkpoint (params only) from the train checkpoint
    p0, o0 = init_train_state(CFG, jax.random.key(0))
    state = mgr_train.restore(step, {"params": p0, "opt": o0})
    mgr = CheckpointManager(ckpt_dir + "_serve")
    mgr.save(step, jax.tree.map(lambda a, b: a.astype(b.dtype),
                                state["params"], like))

    print("== 2. cold start: full vs on-demand (lazy) restore ==")
    eng_full = ServeEngine(CFG)
    eng_full.start(mgr, step, like, lazy=False)
    print(f"  full restore: {eng_full.cold_start_stats['t_full_s']*1e3:.1f} ms")
    eng = ServeEngine(CFG)
    eng.start(mgr, step, like, lazy=True)
    s = eng.cold_start_stats
    print(f"  lazy restore: first leaves in {s['t_first_leaves_s']*1e3:.1f} ms "
          f"({s['first_fetch_compressed_bytes']/1e3:.0f} KB compressed), "
          f"full in {s['t_full_s']*1e3:.1f} ms, "
          f"read amplification {s['read_amplification']:.2f}x")

    print("== 3. fleet-level burst: provision this image to 64 VMs ==")
    ckpt_bytes = mgr._load_manifest(step)[0]["block_manifest"]["raw_size"]
    wave = WaveConfig(image_bytes=max(int(ckpt_bytes), 50_000_000),
                      container_start=0.5)
    for system in ("faasnet", "on_demand", "baseline"):
        lat = provision_wave(system, 64, wave)
        print(f"  {system:10s} mean={np.mean(list(lat.values())):6.2f}s "
              f"max={max(lat.values()):6.2f}s")

    print("== 4. serve a burst of requests ==")
    rng = np.random.default_rng(0)
    for _ in range(6):
        eng.submit(rng.integers(0, CFG.vocab_size, size=12), max_new_tokens=6)
    done = []
    while eng.queue:
        done += eng.step_batch()
    for r in done[:3]:
        print(f"  req{r.rid}: {len(r.out_tokens)} tokens "
              f"ttft={(r.t_first_token - r.t_submit)*1e3:.0f}ms "
              f"total={(r.t_done - r.t_submit)*1e3:.0f}ms")
    print(f"OK: served {len(done)} requests")


if __name__ == "__main__":
    main()
