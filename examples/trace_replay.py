"""Replay the paper's IoT production trace against three systems (§4.2).

    PYTHONPATH=src python examples/trace_replay.py [--minutes 35]
"""
import argparse
import sys

sys.path.insert(0, "src")

import statistics as st

from repro.sim import ReplayConfig, TraceReplay, iot_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=35)
    ap.add_argument("--scale", type=float, default=1 / 3)
    args = ap.parse_args()

    trace = iot_trace(scale=args.scale)[: args.minutes * 60]
    burst_t = 9 * 60
    print(f"IoT trace: {args.minutes} min at {args.scale:.2f} scale "
          f"(peak {max(trace):.0f} RPS)")
    print(f"{'system':12s} {'peak resp':>10s} {'recovery':>9s} "
          f"{'prov mean':>10s} {'VMs used':>9s}")
    for system in ("faasnet", "on_demand", "baseline"):
        r = TraceReplay(ReplayConfig(system=system, idle_reclaim_s=420))
        tl = r.run(trace)
        peak = max(ts.mean_response_s for ts in tl if ts.t >= burst_t)
        rec = r.recovery_time(burst_t + 60, normal_s=3.5)
        pm = st.mean(r.prov_latencies) if r.prov_latencies else 0.0
        vms = max(ts.active_vms for ts in tl)
        print(f"{system:12s} {peak:9.1f}s {rec:8.0f}s {pm:9.1f}s {vms:9d}")
    print("paper:       faasnet 6s / 28s recovery; baseline 28s / 113s")


if __name__ == "__main__":
    main()
