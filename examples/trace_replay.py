"""Replay the paper's production traces against three systems (§4.2).

Single tenant (IoT trace, the paper's Figure 11 shape)::

    PYTHONPATH=src python examples/trace_replay.py [--minutes 35]

Multi-tenant (overlapping IoT/gaming/diurnal/constant waves on one shared
registry + VM pool, with a mid-wave scheduler failover)::

    PYTHONPATH=src python examples/trace_replay.py --multi [--tenants 8]

Either mode accepts a sharded registry — e.g. 4 replicas with round-robin
fetchers (each shard keeps the full per-replica egress/QPS, so shards add
capacity)::

    PYTHONPATH=src python examples/trace_replay.py \
        --registry-shards 4 --shard-policy replicated

Multi-tenant mode shares the VM pool across tenants by default (memory-aware
co-location, paper §3.1); compare against the legacy exclusive leasing or
the predictive reclaim policy with::

    PYTHONPATH=src python examples/trace_replay.py --multi \
        --placement exclusive
    PYTHONPATH=src python examples/trace_replay.py --multi \
        --reclaim histogram

Request-level serving (sub-tick dispatch, per-VM CPU slots, cold-start herd
control) reports end-to-end p50/p99 response instead of tick-quantized
latencies::

    PYTHONPATH=src python examples/trace_replay.py --multi --serving
"""
import argparse
import sys

sys.path.insert(0, "src")

import statistics as st

from repro.core.registry import PLACEMENT_POLICIES
from repro.sim import (
    PLACEMENTS,
    RECLAIM_POLICIES,
    RegistrySpec,
    ReplayConfig,
    TraceReplay,
    iot_trace,
)


def _registry_spec(args, base) -> "RegistrySpec | None":
    """None for the stock 1-shard registry (bit-identical legacy path).

    ``base`` is the mode's own config (ReplayConfig / MultiTenantConfig):
    each shard keeps that config's full per-replica egress cap and QPS.
    """
    if args.registry_shards == 1 and args.shard_policy == "hash_by_function":
        return None
    return RegistrySpec(
        shards=args.registry_shards,
        egress_cap=base.registry_out_cap,
        qps=base.registry_qps,
        policy=args.shard_policy,
    )


def single_tenant(args) -> None:
    trace = iot_trace(scale=args.scale)[: args.minutes * 60]
    burst_t = 9 * 60
    spec = _registry_spec(args, ReplayConfig())
    print(f"IoT trace: {args.minutes} min at {args.scale:.2f} scale "
          f"(peak {max(trace):.0f} RPS)")
    if spec is not None:
        print(f"registry: {spec.shards} shard(s), policy={spec.policy}")
    print(f"{'system':12s} {'peak resp':>10s} {'recovery':>9s} "
          f"{'prov mean':>10s} {'VMs used':>9s}")
    for system in ("faasnet", "on_demand", "baseline"):
        r = TraceReplay(
            ReplayConfig(system=system, idle_reclaim_s=420, registry=spec)
        )
        tl = r.run(trace)
        peak = max(ts.mean_response_s for ts in tl if ts.t >= burst_t)
        rec = r.recovery_time(burst_t + 60, normal_s=3.5)
        pm = st.mean(r.prov_latencies) if r.prov_latencies else 0.0
        vms = max(ts.active_vms for ts in tl)
        print(f"{system:12s} {peak:9.1f}s {rec:8.0f}s {pm:9.1f}s {vms:9d}")
    print("paper:       faasnet 6s / 28s recovery; baseline 28s / 113s")


def multi_tenant(args) -> None:
    from repro.sim import (
        MultiTenantConfig,
        MultiTenantReplay,
        multi_tenant_config,
        serving_config,
    )

    spec = _registry_spec(args, MultiTenantConfig())
    factory = serving_config if args.serving else multi_tenant_config
    results = {}
    for system in ("faasnet", "baseline"):
        cfg = factory(
            args.seed,
            n_tenants=args.tenants,
            vm_pool_size=args.pool,
            minutes=args.minutes,
            scale=args.multi_scale,
            system=system,
            failover_at=args.minutes * 30,  # mid-run scheduler failover
            registry=spec,
            placement=args.placement,
            reclaim=args.reclaim,
        )
        results[system] = MultiTenantReplay(cfg).run()
    res = results["faasnet"]
    shards = spec.shards if spec is not None else 1
    print(f"{args.tenants} tenants sharing {args.pool} VMs + a "
          f"{shards}-shard registry ({args.placement} placement, "
          f"{args.reclaim} reclaim), "
          f"{args.minutes} min, scheduler failover at t={args.minutes * 30}s "
          f"(failovers={res.failovers})")
    if args.serving:
        print(f"{'tenant':12s} {'requests':>8s} {'p50 resp':>9s} "
              f"{'p99 resp':>9s} {'wasted':>7s} {'peak VMs':>8s}")
        for fid, tr in sorted(res.per_tenant.items()):
            print(f"{fid:12s} {tr.requests:8d} {tr.p50_response_s:8.2f}s "
                  f"{tr.p99_response_s:8.2f}s {tr.wasted_provisions:7d} "
                  f"{tr.peak_vms:8d}")
    else:
        print(f"{'tenant':12s} {'requests':>8s} {'p99 resp':>9s} "
              f"{'p99 prov':>9s} {'peak VMs':>8s}")
        for fid, tr in sorted(res.per_tenant.items()):
            print(f"{fid:12s} {tr.requests:8d} {tr.p99_response_s:8.1f}s "
                  f"{tr.p99_prov_s:8.1f}s {tr.peak_vms:8d}")
    base_prov = results["baseline"].total_prov_time_s
    ratio = res.total_prov_time_s / base_prov if base_prov > 0 else float("nan")
    print(f"total provisioning time: faasnet {res.total_prov_time_s:.0f}s vs "
          f"baseline {base_prov:.0f}s "
          f"-> {(1 - ratio) * 100:.1f}% less (paper: 75.2%)")
    print(f"pool footprint: {res.vm_hours():.1f} VM-hours, "
          f"{res.cold_starts} cold starts, peak NIC utilization "
          f"{res.peak_nic_utilization:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=35)
    ap.add_argument("--scale", type=float, default=1 / 3)
    ap.add_argument("--multi", action="store_true",
                    help="overlapping multi-tenant waves instead of one tenant")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--pool", type=int, default=2000)
    ap.add_argument("--multi-scale", type=float, default=0.25,
                    help="trace scale for --multi (the IoT tenant's factor)")
    ap.add_argument("--registry-shards", type=int, default=1,
                    help="registry shard/replica count (each shard keeps the "
                         "full per-replica egress cap and QPS)")
    ap.add_argument("--shard-policy", default="hash_by_function",
                    choices=PLACEMENT_POLICIES,
                    help="blob placement across shards")
    ap.add_argument("--placement", default="shared",
                    choices=PLACEMENTS,
                    help="--multi: shared = memory-aware co-location "
                         "(default); exclusive = legacy per-tenant leasing")
    ap.add_argument("--reclaim", default="fixed",
                    choices=RECLAIM_POLICIES,
                    help="--multi: idle-instance reclaim policy")
    ap.add_argument("--serving", action="store_true",
                    help="--multi: request-level serving (sub-tick dispatch, "
                         "per-VM CPU slots, herd-controlled admission); "
                         "reports end-to-end p50/p99 response per tenant")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.multi:
        multi_tenant(args)
    else:
        single_tenant(args)


if __name__ == "__main__":
    main()
