"""Quickstart: train a small LM with FaaSNet-format checkpointing.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]

Trains a ~4M-param dense transformer on the synthetic pipeline, saving
block-format checkpoints (zstd blocks + offset-table manifest — the paper's
I/O-efficient format) and printing the loss curve.  Scale up with
``--arch`` (any of the ten assigned architectures' smoke configs) or
``--full-100m`` for the ~100M-param config used in EXPERIMENTS.md.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import ARCH_IDS, ModelConfig, get_smoke
from repro.optim.adamw import AdamWConfig
from repro.train.loop import run_train

SMALL = ModelConfig(
    name="quickstart_4m", family="dense", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=683, vocab_size=4096, attn_impl="full", remat="none",
)

LM_100M = ModelConfig(
    name="quickstart_100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=2048, vocab_size=32768,
    attn_impl="chunked", attn_chunk=256,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--arch", choices=ARCH_IDS, default=None,
                    help="train a smoke config of an assigned arch instead")
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = SMALL
    if args.arch:
        cfg = get_smoke(args.arch)
    if args.full_100m:
        cfg = LM_100M
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} seq={args.seq_len} batch={args.batch}")
    res = run_train(
        cfg, steps=args.steps, seq_len=args.seq_len, batch=args.batch,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 10),
        async_save=True, log_every=max(args.steps // 12, 1),
        opt=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
    )
    print("loss curve:")
    for step, loss in sorted(res.losses.items()):
        print(f"  step {step:5d}  loss {loss:.4f}")
    print(f"wall {res.wall_s:.1f}s  checkpoints in {args.ckpt_dir}")
    first, last = min(res.losses), max(res.losses)
    assert res.losses[last] < res.losses[first], "loss did not decrease!"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
