#!/usr/bin/env bash
# Tier-1 test invocation — CI and humans run exactly this.
#
#   scripts/ci.sh                 fast suite (the tier-1 gate)
#   scripts/ci.sh --runslow       also run the 1000-VM scale tests
#   scripts/ci.sh tests/test_sim.py -k determinism   any pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
