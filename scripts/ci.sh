#!/usr/bin/env bash
# Tier-1 test invocation — CI and humans run exactly this.
#
#   scripts/ci.sh                 fast suite (the tier-1 gate)
#   scripts/ci.sh --runslow       also run the 1000-VM scale tests and the
#                                 10k-VM / 100k-container mega-burst
#   scripts/ci.sh tests/test_sim.py -k determinism   any pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Perf smoke: the control plane must stay O(log n).  Building a 5k-node
# FunctionTree plus 500 churn ops takes ~50 ms on the frontier/index paths
# and seconds on the old O(n²) BFS-scan paths, so a generous 1.25 s budget
# can never be met by a quadratic regression silently sneaking back in.
python - <<'PY'
import random, time
from repro.core import FunctionTree

t0 = time.perf_counter()
ft = FunctionTree("perf-smoke")
for i in range(5_000):
    ft.insert(f"v{i}")
rng = random.Random(0)
ids = [f"v{i}" for i in range(5_000)]
for _ in range(500):
    v = ids[rng.randrange(len(ids))]
    ft.delete(v)
    ft.insert(v)
elapsed = time.perf_counter() - t0
ft.check_invariants()
budget = 1.25
assert elapsed < budget, (
    f"perf smoke FAILED: 5k-node FT build + 500 churn ops took {elapsed:.2f} s "
    f"(budget {budget} s) — the O(n^2) control-plane path is back"
)
print(f"perf smoke ok: 5k-node FT build + 500 churn ops in {elapsed*1e3:.0f} ms")
PY

# Trace-replay smoke: a short multi-tenant prefix with a mid-run scheduler
# failover must (a) finish in seconds, (b) keep the pool partitioned at
# every tick, and (c) be bit-identical to an uninterrupted run — the tier-1
# guard on the whole replay stack (traces -> FTManager -> FlowSim).
python - <<'PY'
import time
from repro.sim import MultiTenantReplay, multi_tenant_config

t0 = time.perf_counter()
cfg = multi_tenant_config(
    n_tenants=3, vm_pool_size=200, minutes=3, failover_at=80, check_partition=True
)
res = MultiTenantReplay(cfg).run()
plain = multi_tenant_config(
    n_tenants=3, vm_pool_size=200, minutes=3, failover_at=None
)
unbroken = MultiTenantReplay(plain).run()
elapsed = time.perf_counter() - t0
assert res.failovers == 1
assert res.timelines == unbroken.timelines, "failover perturbed the replay"
assert sum(t.provisioned for t in res.per_tenant.values()) > 0
budget = 10.0
assert elapsed < budget, (
    f"trace smoke FAILED: 3-tenant / 3-min replay took {elapsed:.2f} s "
    f"(budget {budget} s)"
)
print(
    f"trace smoke ok: 3-tenant replay + failover parity in {elapsed*1e3:.0f} ms"
)
PY

# Registry shard-sweep smoke: per-shard egress accounting must not silently
# regress to a single aggregate cap.  With 4 replicated shards the baseline
# (registry-bound) wave must speed up >= 2x while faasnet (NIC-bound at the
# root) moves < 5% — the paper's §4.3 bottleneck-removal claim in miniature.
python - <<'PY'
import time
from repro.sim import RegistrySpec, WaveConfig, provision_wave
from repro.sim.engine import GBPS

t0 = time.perf_counter()
def makespan(system, shards):
    cfg = WaveConfig(
        per_stream_cap=float("inf"),
        registry=RegistrySpec(
            shards=shards, egress_cap=9.5 * GBPS, qps=1100.0, policy="replicated"
        ),
    )
    return max(provision_wave(system, 64, cfg).values())

b1, b4 = makespan("baseline", 1), makespan("baseline", 4)
f1, f4 = makespan("faasnet", 1), makespan("faasnet", 4)
elapsed = time.perf_counter() - t0
speedup = b1 / b4
drift = abs(f4 - f1) / f1 * 100.0
assert speedup >= 2.0, (
    f"registry smoke FAILED: baseline only sped up {speedup:.2f}x with 4 "
    f"shards ({b1:.1f}s -> {b4:.1f}s) — per-shard egress accounting has "
    f"regressed to an aggregate cap"
)
assert drift < 5.0, (
    f"registry smoke FAILED: faasnet moved {drift:.1f}% with 4 shards "
    f"({f1:.2f}s -> {f4:.2f}s) — it should be insensitive to registry "
    f"bandwidth"
)
budget = 10.0
assert elapsed < budget, (
    f"registry smoke FAILED: sweep took {elapsed:.2f} s (budget {budget} s)"
)
print(
    f"registry smoke ok: baseline {speedup:.2f}x faster with 4 shards, "
    f"faasnet drift {drift:.2f}%, in {elapsed*1e3:.0f} ms"
)
PY

# Shared-pool smoke: memory-aware cross-tenant placement must actually pay.
# On a short 3-tenant prefix the shared pool (a) spends fewer VM-hours than
# exclusive leasing, (b) genuinely co-locates tenants (more inserts than
# reservations), and (c) faasnet still beats the docker-pull baseline on the
# worst tenant's p99 provisioning latency — all under the per-tick
# memory/occupancy invariant checks.
python - <<'PY'
import time
from repro.sim import MultiTenantReplay, multi_tenant_config

t0 = time.perf_counter()
def run(**kw):
    cfg = multi_tenant_config(
        n_tenants=3, vm_pool_size=200, minutes=3, failover_at=None,
        check_partition=True, **kw,
    )
    return MultiTenantReplay(cfg).run()

shared = run(placement="shared")
excl = run(placement="exclusive")
base = run(placement="shared", system="baseline")
elapsed = time.perf_counter() - t0
assert shared.vm_seconds < excl.vm_seconds, (
    f"placement smoke FAILED: shared pool used {shared.vm_seconds:.0f} VM-s, "
    f"exclusive {excl.vm_seconds:.0f} VM-s — co-location is not saving "
    f"capacity"
)
stats = shared.manager_stats
assert stats["inserts"] > stats["reservations"], (
    f"placement smoke FAILED: {stats['inserts']} inserts vs "
    f"{stats['reservations']} reservations — no cross-tenant co-location "
    f"happened"
)
worst_f = max(t.p99_prov_s for t in shared.per_tenant.values())
worst_b = max(t.p99_prov_s for t in base.per_tenant.values())
assert worst_f < worst_b, (
    f"placement smoke FAILED: faasnet worst p99 provisioning {worst_f:.2f}s "
    f"not better than baseline {worst_b:.2f}s on the shared pool"
)
budget = 10.0
assert elapsed < budget, (
    f"placement smoke FAILED: took {elapsed:.2f} s (budget {budget} s)"
)
print(
    f"placement smoke ok: shared {shared.vm_seconds:.0f} VM-s vs exclusive "
    f"{excl.vm_seconds:.0f} VM-s, faasnet p99prov {worst_f:.2f}s vs baseline "
    f"{worst_b:.2f}s, in {elapsed*1e3:.0f} ms"
)
PY

# Serving smoke: the request-level layer must (a) herd-control cold bursts —
# one right-sized wave instead of a reservation per queued request, so it
# wastes no provisions where naive admission wastes hundreds — and (b) keep
# faasnet's end-to-end p99 response ahead of the docker-pull baseline (every
# cold request under baseline waits out a full image pull).
python - <<'PY'
import time
from repro.sim import MultiTenantReplay, serving_config
from repro.sim.multi_tenant import MultiTenantConfig, ServingConfig, TenantConfig

t0 = time.perf_counter()
def burst(herd):
    trace = [0.0] * 3 + [500.0] + [0.0] * 26
    return MultiTenantConfig(
        tenants=[TenantConfig("cold", trace, seed=3, function_duration_s=0.5,
                              max_reserve_per_tick=100_000)],
        vm_pool_size=600,
        serving=ServingConfig(herd_control=herd),
        check_partition=True,
    )
h = MultiTenantReplay(burst(True)).run().per_tenant["cold"]
n = MultiTenantReplay(burst(False)).run().per_tenant["cold"]
assert h.completed == n.completed == 500, (h.completed, n.completed)
assert h.wasted_provisions < n.wasted_provisions, (
    f"serving smoke FAILED: herd wasted {h.wasted_provisions} provisions, "
    f"naive {n.wasted_provisions} — herd control is not paying"
)
assert h.provisioned < n.provisioned, (
    f"serving smoke FAILED: herd provisioned {h.provisioned} >= naive "
    f"{n.provisioned} — the admission gate is not parking the herd"
)

p99 = {}
for system in ("faasnet", "baseline"):
    cfg = serving_config(n_tenants=3, vm_pool_size=300, minutes=2,
                         failover_at=None, check_partition=True, system=system)
    res = MultiTenantReplay(cfg).run()
    p99[system] = max(tr.p99_response_s for tr in res.per_tenant.values())
elapsed = time.perf_counter() - t0
assert p99["faasnet"] < p99["baseline"], (
    f"serving smoke FAILED: faasnet p99 response {p99['faasnet']:.2f}s not "
    f"better than baseline {p99['baseline']:.2f}s"
)
budget = 10.0
assert elapsed < budget, (
    f"serving smoke FAILED: took {elapsed:.2f} s (budget {budget} s)"
)
print(
    f"serving smoke ok: herd {h.provisioned} provisioned/"
    f"{h.wasted_provisions} wasted vs naive {n.provisioned}/"
    f"{n.wasted_provisions}, faasnet p99 {p99['faasnet']:.2f}s vs baseline "
    f"{p99['baseline']:.2f}s, in {elapsed*1e3:.0f} ms"
)
PY

# Vector-engine smoke: the vectorized backend must stay bit-identical to
# the incremental engine — a 128-VM wave across all five systems and a
# paper-shape burst (1000 VMs, 5 fns x 500 containers) compare equal on
# latencies, event logs, and peak-egress telemetry — and must hold an
# events/s floor (measured ~84k on an idle dev box; 20k tolerates a loaded
# CI host but still catches an order-of-magnitude engine regression).
python - <<'PY'
import time
from repro.sim import SYSTEMS, ScaleConfig, WaveConfig, provision_wave, run_scale

t0 = time.perf_counter()
for system in SYSTEMS:
    a = provision_wave(system, 128, WaveConfig())
    b = provision_wave(system, 128, WaveConfig(engine="vector"))
    assert a == b, (
        f"vector smoke FAILED: engine divergence on the 128-VM {system} wave"
    )

res = {}
for eng in ("incremental", "vector"):
    cfg = ScaleConfig(churn_ops=20, seed=3, wave=WaveConfig(engine=eng))
    res[eng] = run_scale(cfg)
inc, vec = res["incremental"], res["vector"]
assert vec.trace == inc.trace, (
    "vector smoke FAILED: burst event logs diverge between engines"
)
assert vec.peak_registry_egress == inc.peak_registry_egress
assert vec.peak_shard_egress == inc.peak_shard_egress
elapsed = time.perf_counter() - t0
floor = 20_000.0
assert vec.events_per_s >= floor, (
    f"vector smoke FAILED: {vec.events_per_s:,.0f} events/s on the "
    f"paper-shape burst (floor {floor:,.0f}) — the vector engine has "
    f"regressed an order of magnitude"
)
budget = 10.0
assert elapsed < budget, (
    f"vector smoke FAILED: took {elapsed:.2f} s (budget {budget} s)"
)
print(
    f"vector smoke ok: 128-VM waves + paper burst bit-identical, "
    f"{vec.events_per_s:,.0f} events/s (incremental "
    f"{inc.events_per_s:,.0f}), in {elapsed*1e3:.0f} ms"
)
PY

# Block-provisioning smoke: the §3.1–§3.2 block/layer path must (a) leave
# the legacy scalar goldens bit-identical when disabled (cfg.image=None is
# the default — same WaveConfig, same engines, same numbers), (b) make layer
# sharing pay: consecutive waves from shared base images dedup in the per-VM
# block caches and beat disjoint images to runnable, and (c) keep the
# incremental and vector engines bit-identical with blocks ON.
python - <<'PY'
import time
from repro.core import BlockCache, shared_base_images, disjoint_images
from repro.sim import WaveConfig, block_wave, provision_wave

t0 = time.perf_counter()
legacy = {s: provision_wave(s, 32, WaveConfig()) for s in ("faasnet", "baseline")}
again = {s: provision_wave(s, 32, WaveConfig(image=None)) for s in ("faasnet", "baseline")}
assert legacy == again, (
    "blocks smoke FAILED: cfg.image=None perturbed the legacy scalar waves"
)

def deploy(images):
    cache = BlockCache()
    cfg = WaveConfig(container_start=0.5)
    return sum(
        max(v["runnable"] for v in block_wave("faasnet", 4, cfg, images=img,
                                              cache=cache).values())
        for img in images
    )

shared = deploy(shared_base_images(6, 2, image_bytes=128 << 20))
disjoint = deploy(disjoint_images(6, image_bytes=128 << 20))
assert shared < disjoint, (
    f"blocks smoke FAILED: shared bases {shared:.1f}s not faster than "
    f"disjoint {disjoint:.1f}s — block-cache dedup is not paying"
)

img = shared_base_images(1, 1, image_bytes=128 << 20)[0]
inc = block_wave("faasnet", 16, WaveConfig(engine="incremental"), images=img)
vec = block_wave("faasnet", 16, WaveConfig(engine="vector"), images=img)
assert inc == vec, (
    "blocks smoke FAILED: engine divergence on the block wave"
)
assert all(v["runnable"] < v["done"] for v in inc.values()), (
    "blocks smoke FAILED: runnable milestone did not precede full arrival"
)
elapsed = time.perf_counter() - t0
budget = 10.0
assert elapsed < budget, (
    f"blocks smoke FAILED: took {elapsed:.2f} s (budget {budget} s)"
)
print(
    f"blocks smoke ok: blocks-off bit-identical, shared bases "
    f"{disjoint / shared:.2f}x faster to runnable, engines match, in "
    f"{elapsed*1e3:.0f} ms"
)
PY

# Wide-front smoke: the vector engine's batched recompute must actually
# batch.  On the paper-shape burst the wide-front dispatch count must
# undercut the retired per-depth sweep by >= 1.1x (measured 1.13x on this
# workload — the stagger-serialized closures are mostly single-tree, so
# same-depth merging was already free and the wide-front gain is bounded
# by cross-depth rounds).  When jax is importable the pallas cap-chain
# backend (engine="vector_jax") must stay bit-identical to the numpy
# engines on a wave and a block wave; jax missing skips that half with a
# notice — the numpy wide-front assert runs either way.
python - <<'PY'
import time
from repro.sim import ScaleConfig, WaveConfig, provision_wave, run_scale

t0 = time.perf_counter()
cfg = ScaleConfig(churn_ops=20, seed=3, wave=WaveConfig(engine="vector"))
res = run_scale(cfg)
ds = res.dispatch_stats
fronts = ds["fronts_scalar"] + ds["fronts_vector"]
reduction = ds["legacy_levels"] / fronts
assert reduction >= 1.1, (
    f"widefront smoke FAILED: {fronts} wide-front dispatches vs "
    f"{ds['legacy_levels']} per-depth sweeps ({reduction:.2f}x, floor 1.1x) "
    f"— the cross-tree front batching has regressed"
)
assert ds["flows_vector"] > ds["flows_scalar"], (
    f"widefront smoke FAILED: {ds['flows_vector']} flows took the vector "
    f"path vs {ds['flows_scalar']} scalar — the batched path is not "
    f"carrying the bulk of the work"
)

from repro.kernels.cap_chain import have_jax

if have_jax():
    a = provision_wave("faasnet", 96, WaveConfig(engine="vector"))
    b = provision_wave("faasnet", 96, WaveConfig(engine="vector_jax"))
    assert a == b, (
        "widefront smoke FAILED: vector_jax diverged from vector on the "
        "96-VM wave"
    )
    from repro.core import shared_base_images
    from repro.sim import block_wave

    img = shared_base_images(1, 1, image_bytes=96 << 20)[0]
    bv = block_wave("faasnet", 12, WaveConfig(engine="vector"), images=img)
    bj = block_wave("faasnet", 12, WaveConfig(engine="vector_jax"), images=img)
    assert bv == bj, (
        "widefront smoke FAILED: vector_jax diverged from vector on the "
        "block wave"
    )
    jax_note = "vector_jax bit-identical on wave + block wave"
else:
    jax_note = "jax not importable — vector_jax smoke SKIPPED (numpy-only host)"
elapsed = time.perf_counter() - t0
budget = 20.0
assert elapsed < budget, (
    f"widefront smoke FAILED: took {elapsed:.2f} s (budget {budget} s)"
)
print(
    f"widefront smoke ok: {reduction:.2f}x dispatch reduction "
    f"({fronts} fronts vs {ds['legacy_levels']} per-depth sweeps), "
    f"{jax_note}, in {elapsed*1e3:.0f} ms"
)
PY

exec python -m pytest -x -q "$@"
