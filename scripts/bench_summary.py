#!/usr/bin/env python
"""Print the headline numbers from every BENCH_*.json in one table.

Consolidates the six benchmark artifacts the repo produces —

  * ``BENCH_scale.json``     (benchmarks/bench_scale_1000.py: §4.2 burst)
  * ``BENCH_trace.json``     (benchmarks/bench_trace_replay.py: §4.2 traces)
  * ``BENCH_registry.json``  (benchmarks/bench_registry_sweep.py: §4.3)
  * ``BENCH_placement.json`` (benchmarks/bench_placement.py: §3.1/§5 pool)
  * ``BENCH_serving.json``   (benchmarks/bench_serving.py: request serving)
  * ``BENCH_blocks.json``    (benchmarks/bench_blocks.py: §3.1–§3.2 blocks)

— into one terminal summary, so "where do we stand vs the paper" is a
single command.  Missing files are reported and skipped, never fatal.

Usage::

    python scripts/bench_summary.py            # reads ./BENCH_*.json
    python scripts/bench_summary.py --dir path/to/artifacts
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def _load(root: Path, name: str) -> dict | None:
    p = root / name
    if not p.exists():
        print(f"  [missing] {name} — run its benchmark to produce it")
        return None
    with open(p) as f:
        return json.load(f)


def summarize_scale(d: dict) -> None:
    print(
        f"  {d['n_vms']} VMs x {d['n_functions']} fns x "
        f"{d['containers_per_function']}/fn: fetch makespan "
        f"{d['fetch_makespan_s']:.1f} s (paper §4.2: {d['paper_reference_s']} s), "
        f"{d['events_per_s']:,.0f} events/s, FT build {d['ft_build_s']*1e3:.0f} ms"
    )
    vec = d.get("vector")
    if vec:
        print(
            f"  vector engine: {vec['events_per_s']:,.0f} events/s "
            f"({vec['speedup_vs_incremental']:.1f}x incremental, "
            f"match={vec['matches_incremental']})"
        )
    mega = d.get("mega_burst")
    if mega:
        mv = mega.get("vector")
        mv_s = (
            f", vector {mv['events_per_s']:,.0f} events/s" if mv else ""
        )
        print(
            f"  mega-burst {mega['n_vms']} VMs / {mega['n_containers']} "
            f"containers: {mega['total_wall_s']:.1f} s wall, control-plane "
            f"build {mega['control_plane_build_s']:.1f} s, "
            f"{mega['events_per_s']:,.0f} events/s{mv_s}"
        )
    giga = d.get("giga_burst")
    if giga:
        sp = giga.get("speedup_vs_mega_incremental")
        sp_s = f" ({sp:.1f}x mega-tier incremental)" if sp else ""
        print(
            f"  giga-burst {giga['n_vms']} VMs / {giga['n_containers']} "
            f"containers [{giga.get('engine', 'vector')}]: "
            f"{giga['total_wall_s']:.1f} s wall, engine {giga['wall_s']:.1f} s, "
            f"{giga['events_per_s']:,.0f} events/s{sp_s}"
        )


def summarize_trace(d: dict) -> None:
    print(
        f"  {d['n_tenants']} tenants x {d['vm_pool_size']} VMs x "
        f"{d['minutes']} min: prov-time ratio vs baseline "
        f"{d['prov_time_ratio_vs_baseline']:.3f} "
        f"({d['prov_time_reduction_pct']:.1f}% less; paper: "
        f"{d['paper_reduction_pct']}%), peak registry "
        f"{d['peak_registry_egress_gbps']:.2f} Gbps, "
        f"failovers={d['failovers']}"
    )


def summarize_registry(d: dict) -> None:
    top = str(max(int(s) for s in d["shard_counts"]))
    sp = d["speedup_vs_1_shard"]
    print(
        f"  {top} replicated shards: baseline {sp['baseline'][top]:.2f}x, "
        f"on_demand {sp['on_demand'][top]:.2f}x faster, faasnet "
        f"{sp['faasnet'][top]:.2f}x (insensitive — §4.3 bottleneck removed)"
    )


def summarize_placement(d: dict) -> None:
    rows = d["rows"]
    ft = d["ft_aware_vs_binpack_worst_p99_prov"]
    rec = d["histogram_vs_fixed_reclaim"]
    print(
        f"  {d['n_tenants']} tenants x {d['vm_pool_size']} VMs x "
        f"{d['minutes']} min: shared pool "
        f"{rows['shared']['vm_hours']:.1f} VM-h vs exclusive "
        f"{rows['exclusive']['vm_hours']:.1f} VM-h "
        f"({d['shared_vs_exclusive_vm_hours_saved_pct']:.1f}% saved)"
    )
    print(
        f"  §5 FT-aware worst p99 prov {ft['ft_aware_s']:.2f} s vs binpack "
        f"{ft['binpack_s']:.2f} s; histogram reclaim "
        f"{rec['vm_hours_histogram']:.1f} VM-h / {rec['cold_starts_histogram']} "
        f"cold starts vs fixed {rec['vm_hours_fixed']:.1f} VM-h / "
        f"{rec['cold_starts_fixed']}"
    )


def summarize_serving(d: dict) -> None:
    mix, cold = d["mix"], d["cold_burst"]
    fa, ba = mix["faasnet"], mix["baseline"]
    print(
        f"  mix: {mix['n_tenants']} tenants x {mix['minutes']} min: pooled "
        f"p50/p99 response {fa['pooled_p50_s']:.2f}/{fa['pooled_p99_s']:.2f} s, "
        f"platform p99 {fa['platform_p99_s']:.2f} s "
        f"(baseline {ba['platform_p99_s']:.2f} s)"
    )
    h, n = cold["herd"], cold["naive"]
    print(
        f"  cold burst {cold['burst_requests']} reqs: herd "
        f"{h['total_provisioned']} provisioned / {h['total_wasted']} wasted / "
        f"p99 {h['platform_p99_s']:.2f} s vs naive {n['total_provisioned']} / "
        f"{n['total_wasted']} / {n['platform_p99_s']:.2f} s"
    )


def summarize_blocks(d: dict) -> None:
    sh, rp = d["layer_sharing"], d["runnable_at_prefix"]
    amp = d["read_amplification"]["by_block_size"]
    k512 = str(512 * 1024)
    print(
        f"  {sh['n_functions']} fns on {sh['n_bases']} shared bases: "
        f"{sh['runnable_speedup_shared_vs_disjoint']:.2f}x faster to runnable "
        f"than disjoint ({sh['shared_runnable_total_s']:.1f}s vs "
        f"{sh['disjoint_runnable_total_s']:.1f}s)"
    )
    print(
        f"  runnable at boot prefix {rp['runnable_makespan_s']:.2f}s vs full "
        f"arrival {rp['full_arrival_makespan_s']:.2f}s "
        f"({rp['runnable_vs_full_ratio']:.0%}); Fig. 20 @ 512 KB blocks: "
        f"amp {amp[k512]['read_amplification']:.3f}, boot fetch "
        f"{amp[k512]['fetched_fraction_of_image']:.1%} of the image"
    )


SECTIONS = (
    ("BENCH_scale.json", "scale burst (§4.2)", summarize_scale),
    ("BENCH_trace.json", "multi-tenant traces (§4.2)", summarize_trace),
    ("BENCH_registry.json", "registry shard sweep (§4.3)", summarize_registry),
    ("BENCH_placement.json", "shared pool placement (§3.1/§5)", summarize_placement),
    ("BENCH_serving.json", "request-level serving (§4.4)", summarize_serving),
    ("BENCH_blocks.json", "block-level provisioning (§3.1–§3.2)", summarize_blocks),
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--dir", default=".", help="directory holding the BENCH_*.json artifacts"
    )
    args = ap.parse_args()
    root = Path(args.dir)
    for fname, title, fn in SECTIONS:
        print(f"{title} [{fname}]")
        d = _load(root, fname)
        if d is not None:
            try:
                fn(d)
            except KeyError as e:  # stale artifact from an older bench version
                print(f"  [stale] {fname} lacks {e}; re-run its benchmark")
        print()


if __name__ == "__main__":
    main()
