"""Train-step factory: microbatched grad accumulation + ZeRO-1 resharding.

``make_train_step(cfg, mesh, ...)`` builds a jit-able
``train_step(params_bf16, opt_state, batch) -> (params, opt_state, metrics)``
with:
  * gradient accumulation over ``n_micro`` microbatches via lax.scan
    (activation memory bounded by the microbatch, not the global batch);
  * per-microbatch reduce-scatter of grads into the ZeRO-1 layout
    (grads are constrained to the optimizer-state sharding immediately,
    so the f32 accumulator is DP-sharded — memory O(params/dp));
  * AdamW on the DP-sharded master/moments, then all-gather of the new
    bf16 params back to the replicated-over-data layout;
  * optional int8 error-feedback gradient compression (beyond-paper knob,
    compare in §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.api import sharding_context
from repro.distributed.sharding import ShardingRules
from repro.models import model_for
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

PyTree = Any


def _split_microbatches(batch: dict, n_micro: int) -> dict:
    def split(x):
        b = x.shape[0] if getattr(x, "ndim", 0) else 0
        if getattr(x, "ndim", 0) == 0:
            return x
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def make_train_step(cfg, mesh=None, *, opt: AdamWConfig | None = None,
                    n_micro: int = 1):
    opt = opt or AdamWConfig()
    model = model_for(cfg)
    rules = ShardingRules(cfg, mesh) if mesh is not None else None

    def loss_fn(params, micro):
        loss, metrics = model.loss(params, micro)
        return loss, metrics

    def train_step(params, opt_state, batch):
        micro = _split_microbatches(batch, n_micro)
        opt_spec = None
        if rules is not None:
            shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
            )
            opt_spec = rules.opt_shardings(shapes)

        def shard_like_opt(g):
            if opt_spec is None:
                return g
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s), g, opt_spec
            )

        def micro_step(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            g = shard_like_opt(jax.tree.map(lambda x: x.astype(jnp.float32), g))
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, loss_acc + loss), metrics["ce"]

        g0 = shard_like_opt(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )
        (g_sum, loss_sum), ce_all = jax.lax.scan(
            micro_step, (g0, jnp.zeros((), jnp.float32)), micro
        )
        grads = jax.tree.map(lambda g: g / n_micro, g_sum)
        new_master, new_opt, om = adamw_update(opt, grads, opt_state)
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), new_master, params
        )
        if rules is not None:
            shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), new_params
            )
            pspecs = rules.params_shardings(shapes)
            new_params = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                new_params, pspecs,
            )
        metrics = {
            "loss": loss_sum / n_micro,
            "ce_last": ce_all[-1],
            **om,
        }
        return new_params, new_opt, metrics

    return model, train_step


def init_train_state(cfg, key, mesh=None):
    """Host-side init: params (compute dtype) + optimizer state."""
    model = model_for(cfg)
    params = model.init(key)
    dtype = jnp.dtype(cfg.compute_dtype)
    params_c = jax.tree.map(lambda p: p.astype(dtype), params)
    opt_state = init_opt_state(params)
    return params_c, opt_state
