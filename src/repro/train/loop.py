"""Training loop with checkpoint/restart fault tolerance.

``run_train`` drives the jitted train step over the synthetic pipeline,
checkpointing every ``ckpt_every`` steps in the FaaSNet block format (with
optional async host-side writes).  ``fail_at_step`` raises a simulated
hard failure; calling ``run_train`` again with the same directory resumes
from the latest complete checkpoint — the integration test asserts the
restarted run reproduces the uninterrupted loss trajectory exactly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import make_batch
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: dict[int, float] = field(default_factory=dict)
    wall_s: float = 0.0
    resumed_from: Optional[int] = None


def run_train(
    cfg,
    *,
    steps: int,
    seq_len: int = 256,
    batch: int = 8,
    n_micro: int = 1,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    async_save: bool = False,
    fail_at_step: Optional[int] = None,
    opt: AdamWConfig | None = None,
    seed: int = 0,
    log_every: int = 10,
    mesh=None,
) -> TrainResult:
    opt = opt or AdamWConfig(warmup_steps=10, total_steps=steps)
    model, train_step = make_train_step(cfg, mesh, opt=opt, n_micro=n_micro)
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    mgr = (
        CheckpointManager(ckpt_dir, async_save=async_save)
        if ckpt_dir is not None
        else None
    )
    params, opt_state = init_train_state(cfg, jax.random.key(seed))
    start_step = 0
    resumed_from = None
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            resumed_from = latest

    res = TrainResult(steps_run=0, final_step=start_step, resumed_from=resumed_from)
    t0 = time.monotonic()
    for step in range(start_step, steps):
        b = make_batch(cfg, seq_len, batch, kind="train", seed=seed * 100_003 + step)
        params, opt_state, metrics = jitted(params, opt_state, b)
        res.steps_run += 1
        res.final_step = step + 1
        if (step + 1) % log_every == 0 or step + 1 == steps:
            res.losses[step + 1] = float(metrics["loss"])
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
        if fail_at_step is not None and step + 1 == fail_at_step:
            if mgr is not None:
                mgr.wait()
            res.wall_s = time.monotonic() - t0
            raise SimulatedFailure(f"injected failure at step {step + 1}")
    if mgr is not None:
        mgr.wait()
    res.wall_s = time.monotonic() - t0
    return res
