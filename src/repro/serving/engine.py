"""Batched serving engine with FaaSNet cold-start integration.

A minimal-but-real continuous-batching server:
  * requests enter a queue; the batcher packs up to ``max_batch`` prompts
    (padded to a bucket length) per prefill;
  * decode proceeds in lockstep for the active batch until each request
    hits EOS/max_tokens;
  * **cold start** uses the paper's on-demand path: ``start()`` lazily
    restores only the leaves needed to begin (embedding + first stage +
    head) via the block checkpoint, starts serving, and completes the rest
    of the restore "in the background" (synchronously here, but the fetch
    statistics show exactly how many bytes the fast path needed — the
    Fig. 20 measurement on a real model).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.models import model_for

PyTree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (T,) int32
    max_new_tokens: int = 8
    out_tokens: list[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


FIRST_LEAF_PRED = (
    lambda p: p.startswith("embed")
    or p.startswith("stages/0")
    or p.startswith("lm_head")
    or p.startswith("final_norm")
)


class ServeEngine:
    def __init__(self, cfg, *, max_batch: int = 4, max_len: int = 128) -> None:
        self.cfg = cfg
        self.model = model_for(cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        self.params: Optional[PyTree] = None
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self.cold_start_stats: dict = {}
        self._rid = 0

    # ------------------------------------------------------------------
    # Cold start (paper §3.5 on-demand I/O applied to a model checkpoint)
    # ------------------------------------------------------------------
    def start(self, ckpt: CheckpointManager, step: int, like: PyTree,
              *, lazy: bool = True) -> None:
        t0 = time.monotonic()
        if lazy:
            partial_params, finish, reader = ckpt.restore_lazy(
                step, like, FIRST_LEAF_PRED
            )
            t_first = time.monotonic() - t0
            first_bytes = reader.stats.fetched_compressed
            self.params = finish()
            self.cold_start_stats = {
                "t_first_leaves_s": t_first,
                "t_full_s": time.monotonic() - t0,
                "first_fetch_compressed_bytes": first_bytes,
                "total_fetch_compressed_bytes": reader.stats.fetched_compressed,
                "read_amplification": reader.stats.amplification(),
            }
        else:
            self.params = ckpt.restore(step, like)
            self.cold_start_stats = {"t_full_s": time.monotonic() - t0}

    def set_params(self, params: PyTree) -> None:
        self.params = params

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 8) -> int:
        self._rid += 1
        self.queue.append(
            Request(self._rid, np.asarray(prompt, np.int32), max_new_tokens,
                    t_submit=time.monotonic())
        )
        return self._rid

    def step_batch(self) -> list[Request]:
        """Serve one batch from the queue to completion. Returns finished."""
        assert self.params is not None, "engine not started"
        batch_reqs = [self.queue.popleft()
                      for _ in range(min(self.max_batch, len(self.queue)))]
        if not batch_reqs:
            return []
        t = max(len(r.prompt) for r in batch_reqs)
        b = len(batch_reqs)
        toks = np.zeros((b, t), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, t - len(r.prompt):] = r.prompt  # left-pad
        budget = max(r.max_new_tokens for r in batch_reqs)
        cache_len = t + budget
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache_len=cache_len
        )
        last = jnp.argmax(logits[:, -1], axis=-1)
        now = time.monotonic()
        for i, r in enumerate(batch_reqs):
            r.out_tokens.append(int(last[i]))
            r.t_first_token = now
        for k in range(1, budget):
            batch_in = {
                "tokens": last[:, None].astype(jnp.int32),
                "pos": jnp.asarray(t + k - 1, jnp.int32),
            }
            logits, cache = self.model.decode_step(self.params, batch_in, cache)
            last = jnp.argmax(logits[:, -1], axis=-1)
            for i, r in enumerate(batch_reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(last[i]))
        for r in batch_reqs:
            r.t_done = time.monotonic()
        self.done += batch_reqs
        return batch_reqs
