"""Weight broadcast to DP replicas — FaaSNet's function tree on the ICI mesh.

The host-plane FT streams image blocks down a balanced binary tree of VMs;
the device-plane analogue replicates a weight buffer from DP-replica 0 to
all replicas.  Schedules (selectable, compared in §Perf):

  * ``naive``     — root sends the full payload to each replica in turn
                    (DP-1 serialized ppermutes) — the "registry" baseline:
                    every consumer is served by one source.
  * ``allgather`` — ``lax.all_gather`` + take replica 0's copy: one op, but
                    DP× the payload moves per device.
  * ``binomial``  — ⌈log₂DP⌉ ppermute rounds, doubling the holder set each
                    round; every round moves the full payload.
  * ``pipelined`` — **the FaaSNet schedule**: payload split into B blocks
                    that stream down a *complete binary tree* (heap layout,
                    the same balanced shape the FT maintains), each parent
                    alternating between its two children round-robin — the
                    single-port constraint that made FaaSNet pick fan-out 2
                    (paper Fig. 16: outbound ≈ 2× inbound).  Time ≈
                    (2B + 2·depth) block-times ≈ 2·payload/bw, independent
                    of DP — vs DP·payload (naive) or log₂DP·payload
                    (binomial).
  * int8 compression (``compress=True``) halves wire bytes — the on-device
    analogue of the paper's zstd-block trade of cheap compute for scarce
    bandwidth (§3.5).

All schedules run inside shard_map over the data axes with ``lax.ppermute``
and are exact: non-root replicas start from garbage and end bit-identical
to the root (tested on a CPU mesh).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

PyTree = Any
SCHEDULES = ("naive", "allgather", "binomial", "pipelined")


# ----------------------------------------------------------------------
# Flatten a param pytree into one contiguous buffer (the "image")
# ----------------------------------------------------------------------
@dataclass
class FlatSpec:
    treedef: Any
    shapes: list[tuple[int, ...]]
    dtypes: list[Any]
    sizes: list[int]
    pad: int
    total: int


def flatten_pytree(tree: PyTree, dtype=jnp.bfloat16, pad_to: int = 1):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    flat = jnp.concatenate([l.astype(dtype).reshape(-1) for l in leaves])
    total = flat.shape[0]
    pad = (-total) % pad_to
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, FlatSpec(treedef, shapes, dtypes, sizes, pad, total + pad)


def unflatten_pytree(flat: jax.Array, spec: FlatSpec) -> PyTree:
    out, off = [], 0
    for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        out.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(spec.treedef, out)


# ----------------------------------------------------------------------
# FaaSNet schedule generation (host-side, static)
# ----------------------------------------------------------------------
@dataclass
class Round:
    perm: list[tuple[int, int]]  # (src, dst) replica pairs this round
    send_blk: np.ndarray  # (DP,) block index each replica sends (or 0)
    recv_blk: np.ndarray  # (DP,) block index each replica writes (or 0)
    recv_mask: np.ndarray  # (DP,) bool — replica receives this round


def _heap_children(i: int, n: int) -> list[int]:
    return [c for c in (2 * i + 1, 2 * i + 2) if c < n]


def faasnet_rounds(dp: int, n_blocks: int) -> list[Round]:
    """Single-port, complete-binary-tree, block-streaming schedule."""
    have: list[set[int]] = [set(range(n_blocks)) if i == 0 else set() for i in range(dp)]
    # per-node FIFO of (block, child) send tasks; children alternate by turn
    pending: list[list[tuple[int, int]]] = [[] for _ in range(dp)]
    for b in range(n_blocks):
        for c in _heap_children(0, dp):
            pending[0].append((b, c))
    rounds: list[Round] = []
    done_total = dp * n_blocks
    while sum(len(h) for h in have) < done_total:
        perm, sb, rb, rm = [], np.zeros(dp, np.int32), np.zeros(dp, np.int32), np.zeros(dp, bool)
        busy_dst: set[int] = set()
        sends: list[tuple[int, int, int]] = []  # (src, dst, blk)
        for i in range(dp):
            # pick the first sendable task whose dst is free this round
            for ti, (blk, dst) in enumerate(pending[i]):
                if dst not in busy_dst and blk in have[i] and blk not in have[dst]:
                    sends.append((i, dst, blk))
                    busy_dst.add(dst)
                    pending[i].pop(ti)
                    break
        if not sends:
            raise AssertionError("schedule deadlock (should not happen)")
        for src, dst, blk in sends:
            perm.append((src, dst))
            sb[src] = blk
            rb[dst] = blk
            rm[dst] = True
            have[dst].add(blk)
            for c in _heap_children(dst, dp):
                pending[dst].append((blk, c))
        rounds.append(Round(perm, sb, rb, rm))
    return rounds


def binomial_rounds(dp: int) -> list[list[tuple[int, int]]]:
    out = []
    r = 1
    while r < dp:
        out.append([(i, i + r) for i in range(r) if i + r < dp])
        r *= 2
    return out


# ----------------------------------------------------------------------
# Device-side application
# ----------------------------------------------------------------------
def _bcast_body(buf, *, axes, dp, schedule, n_blocks, rounds_info):
    """Runs inside shard_map; buf is this device's local flat shard."""
    idx = jax.lax.axis_index(axes)
    if schedule == "allgather":
        g = jax.lax.all_gather(buf, axes)  # (DP, n)
        return g[0]
    if schedule == "naive":
        out = buf
        for dst in range(1, dp):
            recv = jax.lax.ppermute(out, axes, [(0, dst)])
            out = jnp.where(idx == dst, recv, out)
        return out
    if schedule == "binomial":
        out = buf
        for perm in rounds_info:
            recv = jax.lax.ppermute(out, axes, perm)
            dsts = jnp.asarray([d for _, d in perm], jnp.int32)
            is_dst = jnp.isin(idx, dsts)
            out = jnp.where(is_dst, recv, out)
        return out
    # pipelined (FaaSNet)
    n = buf.shape[0]
    chunk = n // n_blocks
    out = buf
    for rnd in rounds_info:
        send_blk = jnp.asarray(rnd.send_blk)[idx]
        recv_blk = jnp.asarray(rnd.recv_blk)[idx]
        recv_mask = jnp.asarray(rnd.recv_mask)[idx]
        outgoing = jax.lax.dynamic_slice(out, (send_blk * chunk,), (chunk,))
        incoming = jax.lax.ppermute(outgoing, axes, rnd.perm)
        cur = jax.lax.dynamic_slice(out, (recv_blk * chunk,), (chunk,))
        new = jnp.where(recv_mask, incoming, cur)
        out = jax.lax.dynamic_update_slice(out, new, (recv_blk * chunk,))
    return out


@dataclass
class BroadcastReport:
    schedule: str
    dp: int
    n_blocks: int
    payload_bytes: int
    rounds: int
    serialized_bytes: int  # per-link serialized traffic (time model numerator)

    def modeled_time_s(self, link_bw: float = 50e9) -> float:
        return self.serialized_bytes / link_bw


def tree_broadcast(
    params: PyTree,
    mesh: Mesh,
    *,
    schedule: str = "pipelined",
    n_blocks: int = 32,
    dtype=jnp.bfloat16,
    compress: bool = False,
) -> tuple[PyTree, BroadcastReport]:
    """Replicate ``params`` from DP-replica 0 to all DP replicas.

    Params are assumed sharded over the model axis only (each data replica
    holds a full model-shard copy — possibly stale/garbage on non-root
    replicas).  Returns (params, report).
    """
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = int(np.prod([mesh.shape[a] for a in axes]))
    flat, spec = flatten_pytree(params, dtype=dtype, pad_to=n_blocks)
    if compress:
        from repro.optim.compress import dequantize_int8, quantize_int8

        q, scale = quantize_int8(flat.reshape(n_blocks, -1))
        payload = q.reshape(-1)
        scale_flat = scale.reshape(-1)
    else:
        payload = flat

    if schedule == "pipelined":
        rounds_info = faasnet_rounds(dp, n_blocks)
        n_rounds = len(rounds_info)
        ser_bytes = n_rounds * (payload.nbytes // n_blocks)
    elif schedule == "binomial":
        rounds_info = binomial_rounds(dp)
        n_rounds = len(rounds_info)
        ser_bytes = n_rounds * payload.nbytes
    elif schedule == "naive":
        rounds_info = None
        n_rounds = dp - 1
        ser_bytes = (dp - 1) * payload.nbytes
    elif schedule == "allgather":
        rounds_info = None
        n_rounds = 1
        ser_bytes = dp * payload.nbytes
    else:
        raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")

    body = partial(
        _bcast_body, axes=axes, dp=dp, schedule=schedule,
        n_blocks=n_blocks, rounds_info=rounds_info,
    )
    # payload replicated over every mesh axis; ppermute moves it over the
    # data axes (each data replica holds its own full copy conceptually)
    fn = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                   check_vma=False)
    new_payload = fn(payload)
    if compress:
        sc = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)(scale_flat)
        deq = dequantize_int8(new_payload.reshape(n_blocks, -1), sc)
        flat_out = deq.reshape(-1)
    else:
        flat_out = new_payload
    report = BroadcastReport(
        schedule=schedule, dp=dp, n_blocks=n_blocks,
        payload_bytes=int(payload.nbytes), rounds=n_rounds,
        serialized_bytes=int(ser_bytes),
    )
    return unflatten_pytree(flat_out, spec), report
