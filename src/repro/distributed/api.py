"""Sharding context: how model code requests activation shardings.

Model code is mesh-agnostic; it calls ``constrain(x, ("data", None, ...))``
with *logical* axis names.  Inside a :func:`sharding_context` those names
are translated to the active mesh's axes (e.g. logical "data" → physical
("pod", "data") on the multi-pod mesh) and applied with
``with_sharding_constraint``; outside any context it is a no-op, so tests
and single-device runs never touch the mesh machinery.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _translate(axis, mapping) -> object:
    if axis is None:
        return None
    phys = mapping.get(axis, ())
    if phys == ():
        return None
    return phys


@contextmanager
def sharding_context(mesh, logical_to_physical: dict[str, tuple[str, ...]]):
    """Activate activation-constraint translation for model code."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, logical_to_physical)
    try:
        yield
    finally:
        _state.ctx = prev


def active_mesh() -> Optional[object]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, mapping = ctx
    if len(logical) != x.ndim:
        return x  # shape-polymorphic call sites may not match; skip silently
    spec = P(*[_translate(a, mapping) for a in logical])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
