"""PartitionSpec rules: params, optimizer state, caches, batches.

Policy (v5e production mesh, axes ("data","model") or ("pod","data","model")):
  * activations/batch  — batch dim over DATA (pod+data combined), when divisible;
  * attention          — q heads over MODEL; kv heads over MODEL when divisible
                         else replicated (GQA kv < tp);
  * mlp                — d_ff over MODEL (megatron column/row split);
  * MoE                — experts over MODEL (EP); router replicated;
  * mamba              — SSD heads over MODEL when divisible else replicated;
  * embedding/lm head  — vocab over MODEL;
  * KV caches          — batch over DATA; kv-heads over MODEL when divisible,
                         else sequence over MODEL (context-sharded decode);
  * optimizer state / master params (ZeRO-1) — param spec + the first
    still-unsharded dim divisible by |DATA| goes over DATA.

Every rule checks divisibility and degrades to replication, so any config
lowers on any mesh; the roofline then shows what the degradation costs.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: Mesh) -> str:
    return "model"


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _dim(size: int, want: int) -> bool:
    return want > 0 and size % want == 0


class ShardingRules:
    def __init__(self, cfg, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.dp = data_axes(mesh)
        self.tp = axis_size(mesh, "model")
        self.dp_size = axis_size(mesh, self.dp)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------------
    # Parameter rules, keyed on the leaf's path within the params pytree
    # ------------------------------------------------------------------
    def param_spec(self, path: tuple[str, ...], shape: tuple[int, ...]) -> P:
        cfg, tp = self.cfg, self.tp
        # stage-stacked leaves carry a leading repeat dim; rules address the
        # trailing dims, so compute an offset
        name = path[-1]
        parent = path[-2] if len(path) >= 2 else ""
        gparent = path[-3] if len(path) >= 3 else ""

        def lead(base: P, base_ndim: int) -> P:
            extra = len(shape) - base_ndim
            return P(*([None] * extra + list(base)))

        # embeddings / head
        if parent == "embed" and name == "table":
            return P("model", None) if _dim(shape[0], tp) else P(None, None)
        if parent == "lm_head" and name == "w":
            return P(None, "model") if _dim(shape[1], tp) else P(None, None)
        if parent in ("frontend_proj", "mm_proj"):
            return P(*([None] * len(shape)))
        # attention
        if parent in ("attn", "self_attn", "cross_attn") or gparent in (
            "attn", "self_attn", "cross_attn"
        ):
            if name == "wq":
                ok = _dim(shape[-2], tp)
                return lead(P(None, "model" if ok else None, None), 3)
            if name in ("wk", "wv"):
                ok = _dim(shape[-2], tp)
                return lead(P(None, "model" if ok else None, None), 3)
            if name == "wo":
                ok = _dim(shape[-3], tp)
                return lead(P("model" if ok else None, None, None), 3)
            if name in ("bq", "bk", "bv"):
                ok = _dim(shape[-2], tp)
                return lead(P("model" if ok else None, None), 2)
            return lead(P(None), 1)  # bo, q_norm/k_norm scales
        # dense mlp (incl. MoE shared expert)
        if parent in ("mlp", "shared"):
            if name in ("w_gate", "w_up"):
                return lead(P(None, "model" if _dim(shape[-1], tp) else None), 2)
            if name == "w_out":
                return lead(P("model" if _dim(shape[-2], tp) else None, None), 2)
            if name == "b_up":
                return lead(P("model" if _dim(shape[-1], tp) else None), 1)
            return lead(P(None), 1)
        # MoE experts (EP over model)
        if parent == "moe":
            if name in ("w_gate", "w_up", "w_down"):
                ok = _dim(shape[-3], tp)
                return lead(P("model" if ok else None, None, None), 3)
            if name == "router":
                return lead(P(None, None), 2)
        # mamba
        if parent == "mamba":
            s = cfg.ssm
            heads_ok = s is not None and _dim(s.n_heads, tp)
            if name in ("w_x", "w_z"):
                return lead(P(None, "model" if heads_ok else None), 2)
            if name == "w_dt":
                return lead(P(None, "model" if heads_ok else None), 2)
            if name in ("w_B", "w_C"):
                return lead(P(None, None), 2)
            if name in ("conv_x",):
                return lead(P(None, "model" if heads_ok else None), 2)
            if name in ("conv_B", "conv_C"):
                return lead(P(None, None), 2)
            if name in ("dt_bias", "A_log", "D"):
                return lead(P("model" if heads_ok else None), 1)
            if name == "w_out":
                return lead(P("model" if heads_ok else None, None), 2)
            if parent == "mamba" and name == "scale":  # out_norm
                return lead(P("model" if heads_ok else None), 1)
        if gparent == "mamba" and parent == "out_norm":
            s = cfg.ssm
            heads_ok = s is not None and _dim(s.n_heads, tp)
            return lead(P("model" if heads_ok else None), 1)
        # norms, scalars, everything else: replicated
        return P(*([None] * len(shape)))

    def params_shardings(self, params_shapes: PyTree) -> PyTree:
        def one(path, leaf):
            keys = tuple(
                k.key if hasattr(k, "key") else str(k.idx) if hasattr(k, "idx") else str(k)
                for k in path
            )
            return self.named(self.param_spec(keys, leaf.shape))

        return jax.tree_util.tree_map_with_path(one, params_shapes)

    # ------------------------------------------------------------------
    # Optimizer / master (ZeRO-1): extend with DATA on first free dim
    # ------------------------------------------------------------------
    def zero1_spec(self, pspec: P, shape: tuple[int, ...]) -> P:
        parts = list(pspec) + [None] * (len(shape) - len(pspec))
        for i, (sz, ax) in enumerate(zip(shape, parts)):
            if ax is None and _dim(sz, self.dp_size):
                parts[i] = self.dp
                break
        return P(*parts)

    def opt_shardings(self, params_shapes: PyTree) -> PyTree:
        def one(path, leaf):
            keys = tuple(
                k.key if hasattr(k, "key") else str(k.idx) if hasattr(k, "idx") else str(k)
                for k in path
            )
            return self.named(self.zero1_spec(self.param_spec(keys, leaf.shape), leaf.shape))

        return jax.tree_util.tree_map_with_path(one, params_shapes)

    # ------------------------------------------------------------------
    # Batches
    # ------------------------------------------------------------------
    def batch_spec(self, name: str, shape: tuple[int, ...]) -> P:
        if name == "pos" or not shape:
            return P()
        b = shape[0]
        lead = self.dp if _dim(b, self.dp_size) else None
        return P(lead, *([None] * (len(shape) - 1)))

    def batch_shardings(self, batch: dict) -> dict:
        return {
            k: self.named(self.batch_spec(k, v.shape)) for k, v in batch.items()
        }

    # ------------------------------------------------------------------
    # KV / state caches
    # ------------------------------------------------------------------
    def cache_spec(self, path: tuple[str, ...], shape: tuple[int, ...]) -> P:
        cfg, tp = self.cfg, self.tp
        name = path[-1]
        if name in ("k", "v") or (len(shape) >= 4 and name in ("0", "1")):
            # (.., B, Hkv, S, hd): batch→data; heads→model if divisible else seq→model
            base_ndim = 4
            extra = len(shape) - base_ndim
            b, hkv, s, hd = shape[extra:]
            lead = self.dp if _dim(b, self.dp_size) else None
            if _dim(hkv, tp):
                spec = [lead, "model", None, None]
            elif _dim(s, tp):
                spec = [lead, None, "model", None]
            else:
                spec = [lead, None, None, None]
            return P(*([None] * extra + spec))
        if name in ("ks", "vs"):  # (.., B, Hkv, S) quantization scales
            extra = len(shape) - 3
            b, hkv, s = shape[extra:]
            lead = self.dp if _dim(b, self.dp_size) else None
            if _dim(hkv, tp):
                spec = [lead, "model", None]
            elif _dim(s, tp):
                spec = [lead, None, "model"]
            else:
                spec = [lead, None, None]
            return P(*([None] * extra + spec))
        if name == "ssm":  # (.., B, H, P, N)
            extra = len(shape) - 4
            b, h, pdim, n = shape[extra:]
            lead = self.dp if _dim(b, self.dp_size) else None
            spec = [lead, "model" if _dim(h, tp) else None, None, None]
            return P(*([None] * extra + spec))
        if name.startswith("conv_"):  # (.., B, W-1, CH)
            extra = len(shape) - 3
            b, w, ch = shape[extra:]
            lead = self.dp if _dim(b, self.dp_size) else None
            ok = _dim(ch, tp) and name == "conv_x" and self.cfg.ssm is not None and _dim(
                self.cfg.ssm.n_heads, tp
            )
            spec = [lead, None, "model" if ok else None]
            return P(*([None] * extra + spec))
        # fallback: batch-only
        lead = self.dp if shape and _dim(shape[0], self.dp_size) else None
        return P(lead, *([None] * (len(shape) - 1)))

    def cache_shardings(self, cache_shapes: PyTree) -> PyTree:
        def one(path, leaf):
            keys = tuple(
                k.key if hasattr(k, "key") else str(k.idx) if hasattr(k, "idx") else str(k)
                for k in path
            )
            return self.named(self.cache_spec(keys, leaf.shape))

        return jax.tree_util.tree_map_with_path(one, cache_shapes)

    # logical → physical translation for activation constraints
    def logical_mapping(self) -> dict[str, tuple[str, ...]]:
        return {"data": self.dp, "model": ("model",)}
