"""Elastic scaling: host join/leave with FT-managed weight provisioning.

A joining host is FaaSNet's "reserved VM": the coordinator inserts it into
the model's function tree, it streams checkpoint blocks from its upstream
peer (never the central store, as long as ≥1 warm host exists — paper
§3.4), and once enough blocks arrive it becomes schedulable.  Leaving /
failed hosts trigger tree repair.  The coordinator also proposes mesh
reshapes when the data-parallel width changes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.ft_manager import FTManager, VMInfo
from repro.sim.engine import FlowSim, SimConfig


@dataclass
class JoinResult:
    host: str
    upstream: Optional[str]  # None => fetched from the central store
    provision_latency_s: float
    tree_height: int


@dataclass
class ElasticConfig:
    model_id: str = "model"
    payload_bytes: int = 2 * 10**9  # checkpoint size streamed to joiners
    startup_fraction: float = 1.0  # training needs all weights
    per_stream_cap: float = 30e6
    hop_latency: float = 0.05
    store_cap: float = 5e9  # central checkpoint store egress


class ElasticCoordinator:
    """Tracks membership; times provisioning with the flow simulator."""

    def __init__(self, cfg: ElasticConfig | None = None) -> None:
        self.cfg = cfg or ElasticConfig()
        self.mgr = FTManager()
        self._counter = 0
        self.history: list[JoinResult] = []

    @property
    def hosts(self) -> list[str]:
        ft = self.mgr.trees.get(self.cfg.model_id)
        return ft.vm_ids() if ft is not None else []

    # ------------------------------------------------------------------
    def join(self, host: str | None = None, now: float = 0.0) -> JoinResult:
        cfg = self.cfg
        if host is None:
            host = f"host{self._counter}"
            self._counter += 1
        if host not in self.mgr.vms:
            self.mgr.add_free_vm(VMInfo(host))
            self.mgr.reserve_vm(now)
        upstream = self.mgr.insert(cfg.model_id, host, now)
        # time the stream from upstream (or store) with the flow model
        sim = FlowSim(SimConfig(per_stream_cap=cfg.per_stream_cap,
                                registry_out_cap=cfg.store_cap,
                                hop_latency=cfg.hop_latency))
        from repro.core.topology import REGISTRY, DistributionPlan, Flow

        src = upstream if upstream is not None else REGISTRY
        payload = int(cfg.payload_bytes * cfg.startup_fraction)
        done = {}
        sim.add_plan(
            DistributionPlan(flows=[Flow(src, host, "ckpt", payload)],
                             streaming=True),
            on_node_done=lambda vm, t: done.setdefault(vm, t),
        )
        sim.run()
        ft = self.mgr.trees[cfg.model_id]
        res = JoinResult(host, upstream, done.get(host, 0.0), ft.height)
        self.history.append(res)
        return res

    def leave(self, host: str) -> None:
        self.mgr.delete(self.cfg.model_id, host)
        vm = self.mgr.vms[host]
        vm.functions.discard(self.cfg.model_id)
        self.mgr.release_vm(host)

    def fail(self, host: str) -> list[str]:
        return self.mgr.on_vm_failure(host)

    # ------------------------------------------------------------------
    def propose_mesh(self, model_parallel: int = 16) -> tuple[int, int]:
        """(data, model) mesh shape for the current host count.

        Elastic DP: the data axis is the largest power of two ≤ #hosts;
        spare hosts stay warm in the FT as provisioning seeds.
        """
        n = len(self.hosts)
        if n == 0:
            return (0, model_parallel)
        dp = 2 ** int(math.log2(max(n, 1)))
        return (dp, model_parallel)
