"""Fault tolerance: heartbeats, straggler detection, restart policy.

Host-plane machinery mirroring the paper's §3.3 "FT Fault Tolerance": the
scheduler pings VMs (here: training hosts) and repairs trees on misses.
Device-plane recovery is checkpoint/restart (``CheckpointManager``) plus
re-replication of weights via ``broadcast.tree_broadcast``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.ft_manager import FTManager


@dataclass
class HeartbeatMonitor:
    """Detects dead hosts from missed heartbeats (paper: scheduler pings)."""

    timeout_s: float = 10.0
    last_seen: dict[str, float] = field(default_factory=dict)

    def beat(self, host: str, now: float) -> None:
        self.last_seen[host] = now

    def dead_hosts(self, now: float) -> list[str]:
        return [h for h, t in self.last_seen.items() if now - t > self.timeout_s]


@dataclass
class StragglerDetector:
    """EWMA step-time tracking; flags hosts persistently slower than the fleet.

    The mitigation mirrors FaaSNet's adaptivity: a flagged interior FT node
    is demoted to a leaf (delete + re-insert), so it stops throttling its
    subtree's inbound streams.
    """

    alpha: float = 0.2
    threshold: float = 1.5  # x fleet median
    ewma: dict[str, float] = field(default_factory=dict)

    def record(self, host: str, step_time_s: float) -> None:
        prev = self.ewma.get(host, step_time_s)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time_s

    def stragglers(self) -> list[str]:
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        return [h for h, v in self.ewma.items() if v > self.threshold * median]


class FaultCoordinator:
    """Glues heartbeats + FT repair + checkpoint restart decisions."""

    def __init__(
        self,
        mgr: FTManager,
        monitor: Optional[HeartbeatMonitor] = None,
        detector: Optional[StragglerDetector] = None,
        on_restart: Optional[Callable[[list[str]], None]] = None,
    ) -> None:
        self.mgr = mgr
        self.monitor = monitor or HeartbeatMonitor()
        self.detector = detector or StragglerDetector()
        self.on_restart = on_restart
        self.events: list[tuple[float, str, str]] = []

    def tick(self, now: float) -> dict:
        """Run detection; repair trees; return actions taken."""
        dead = [
            h for h in self.monitor.dead_hosts(now)
            if h in self.mgr.vms and self.mgr.vms[h].alive
        ]
        repaired: list[str] = []
        for h in dead:
            repaired += self.mgr.on_vm_failure(h)
            self.events.append((now, "failure", h))
        slow = self.detector.stragglers()
        demoted = []
        for h in slow:
            vm = self.mgr.vms.get(h)
            if vm is None or not vm.alive:
                continue
            for fid in list(vm.functions):
                ft = self.mgr.trees.get(fid)
                if ft is not None and h in ft and ft.children_of(h):
                    self.mgr.delete(fid, h)
                    self.mgr.insert(fid, h, now)  # re-attach at frontier => leaf
                    demoted.append((fid, h))
                    self.events.append((now, "demote", h))
        if dead and self.on_restart is not None:
            self.on_restart(dead)
        return {"dead": dead, "repaired_functions": repaired, "demoted": demoted}
