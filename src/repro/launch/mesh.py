"""Production mesh construction (single-pod 16×16, multi-pod 2×16×16).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return make_mesh(shape, axes)
