import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks device count on first init.
"""Dry-run of the FaaSNet weight-broadcast schedules on the production mesh.

This is the §Perf cell most representative of the paper's technique: the
checkpoint payload (an arch's bf16 parameters, model-sharded) must reach
every data replica.  For each schedule we lower + compile the ppermute
program, parse collective traffic from the HLO, and model the serialized
link time (rounds are serialized; sends within a round are concurrent on
disjoint links — the schedule generator guarantees single-port validity).

    python -m repro.launch.broadcast_dryrun --arch jamba_v01_52b --mesh both
"""
import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def run_one(arch: str, mesh_kind: str, schedule: str, n_blocks: int,
            outdir: str, compress: bool = False) -> dict:
    from repro.configs import get_config
    from repro.distributed.broadcast import (
        _bcast_body,
        binomial_rounds,
        faasnet_rounds,
    )
    from repro.launch.hlo_analysis import ICI_BW, analyze_hlo
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    import numpy as np

    dp = int(np.prod([mesh.shape[a] for a in axes]))
    tp = mesh.shape["model"]

    cfg = get_config(arch)
    n_items = cfg.param_count()  # one element per parameter
    dtype = jnp.int8 if compress else jnp.bfloat16
    itemsize = 1 if compress else 2
    payload_bytes = n_items * itemsize  # int8 compression halves wire bytes
    # pad so the per-model-shard slice splits evenly into blocks
    per_shard = -(-n_items // tp)
    per_shard = -(-per_shard // n_blocks) * n_blocks
    buf_struct = jax.ShapeDtypeStruct((per_shard * tp,), dtype)

    if schedule == "pipelined":
        rounds_info = faasnet_rounds(dp, n_blocks)
        rounds = len(rounds_info)
        ser_bytes = rounds * (per_shard * itemsize // n_blocks)
    elif schedule == "binomial":
        rounds_info = binomial_rounds(dp)
        rounds = len(rounds_info)
        ser_bytes = rounds * per_shard * itemsize
    elif schedule == "naive":
        rounds_info = None
        rounds = dp - 1
        ser_bytes = rounds * per_shard * itemsize
    elif schedule == "allgather":
        rounds_info = None
        rounds = 1
        ser_bytes = dp * per_shard * itemsize
    else:
        raise ValueError(schedule)

    body = partial(_bcast_body, axes=axes, dp=dp, schedule=schedule,
                   n_blocks=n_blocks, rounds_info=rounds_info)
    fn = shard_map(body, mesh=mesh, in_specs=P("model"), out_specs=P("model"),
                   check_vma=False)
    t0 = time.time()
    lowered = jax.jit(fn, donate_argnums=(0,)).lower(buf_struct)
    compiled = lowered.compile()
    stats = analyze_hlo(compiled.as_text())
    out = {
        "arch": arch,
        "mesh": mesh_kind,
        "schedule": schedule + ("_int8" if compress else ""),
        "dp": dp,
        "n_blocks": n_blocks,
        "payload_gb": payload_bytes / 1e9,
        "per_device_shard_gb": per_shard * itemsize / 1e9,
        "rounds": rounds,
        "hlo_collective_bytes": stats.collective_bytes,
        "hlo_collective_ops": stats.count_by_kind,
        "serialized_bytes_per_link": ser_bytes,
        "modeled_time_s": ser_bytes / ICI_BW,
        "compile_s": round(time.time() - t0, 2),
    }
    os.makedirs(outdir, exist_ok=True)
    name = f"{arch}__{mesh_kind}__{out['schedule']}__b{n_blocks}.json"
    with open(os.path.join(outdir, name), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba_v01_52b")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--schedules", default="naive,allgather,binomial,pipelined")
    ap.add_argument("--n-blocks", type=int, default=32)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--out", default="results/broadcast")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        for sched in args.schedules.split(","):
            r = run_one(args.arch, mk, sched, args.n_blocks, args.out)
            print(
                f"OK {args.arch} {mk:6s} {sched:10s} rounds={r['rounds']:3d} "
                f"coll={r['hlo_collective_bytes']/1e9:8.2f}GB "
                f"modeled={r['modeled_time_s']:7.3f}s "
                f"compile={r['compile_s']}s",
                flush=True,
            )
            if args.compress and sched == "pipelined":
                r = run_one(args.arch, mk, sched, args.n_blocks, args.out,
                            compress=True)
                print(
                    f"OK {args.arch} {mk:6s} {sched}_int8 rounds={r['rounds']:3d} "
                    f"coll={r['hlo_collective_bytes']/1e9:8.2f}GB "
                    f"modeled={r['modeled_time_s']:7.3f}s",
                    flush=True,
                )


if __name__ == "__main__":
    main()
