"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains reduced (smoke) configs end to end with
checkpoint/restart; on a real TPU pod the same entry point takes
``--mesh single|multi`` and jits through the production mesh with the
sharding rules from repro.distributed (the dry-run proves those lower).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published config (TPU-scale) instead of smoke")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import run_train

    cfg = get_config(args.arch) if args.full_config else get_smoke(args.arch)
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps")
    res = run_train(
        cfg, steps=args.steps, seq_len=args.seq_len, batch=args.batch,
        n_micro=args.n_micro, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, fail_at_step=args.fail_at,
        opt=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                        total_steps=args.steps),
        log_every=max(1, args.steps // 20),
    )
    for step, loss in sorted(res.losses.items()):
        print(f"step {step:6d}  loss {loss:.4f}")
    if res.resumed_from:
        print(f"(resumed from checkpoint step {res.resumed_from})")


if __name__ == "__main__":
    main()
