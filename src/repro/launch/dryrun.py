import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the appropriate step (train_step / prefill /
decode) against ShapeDtypeStruct inputs (no allocation), compiles it for
the production mesh, and records:
  * memory_analysis()  — proves the cell fits per-device HBM;
  * cost_analysis()    — HLO FLOPs / bytes for §Roofline;
  * collective stats   — parsed from the optimized HLO text;
  * the derived three-term roofline (repro.launch.hlo_analysis).

Run one cell:   python -m repro.launch.dryrun --arch deepseek_7b --shape train_4k --mesh single
Run everything: python -m repro.launch.dryrun --all --mesh both
Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp


# per-arch microbatch counts for train_4k (global batch 256) — chosen so
# per-device activations fit 16 GB HBM with block remat
N_MICRO = {
    "stablelm_12b": 8,
    "deepseek_7b": 8,
    "gemma3_1b": 16,
    "internlm2_20b": 16,
    "jamba_v01_52b": 32,
    "whisper_medium": 8,
    "deepseek_moe_16b": 16,
    "granite_moe_1b": 8,
    "mamba2_130m": 4,
    "llava_next_mistral_7b": 8,
}


def _cfg_for(arch: str, kind: str = "train", overrides: dict | None = None):
    from dataclasses import replace

    from repro.configs import get_config

    cfg = get_config(arch)
    # the dry-run lowers the chunked attention path (Pallas cannot lower on
    # CPU hosts); on real TPUs select attn_impl="pallas".  Decode shapes use
    # the int8-quantized KV cache (production default; halves HBM residency).
    kv = "int8" if kind == "decode" else "bf16"
    # grouped GQA decode is the validated default (§Perf hillclimb B: the
    # repeat-KV baseline all-gathers the sequence-sharded cache every step)
    cfg = replace(cfg, attn_impl="chunked", kv_cache_dtype=kv,
                  gqa_decode="grouped")
    for key, val in (overrides or {}).items():
        if "." in key:  # nested, e.g. ssm.chunk=128
            sub, leaf = key.split(".", 1)
            cfg = replace(cfg, **{sub: replace(getattr(cfg, sub), **{leaf: val})})
        else:
            cfg = replace(cfg, **{key: val})
    return cfg


def lower_cell(arch: str, shape_name: str, mesh, *, n_micro: int | None = None,
               overrides: dict | None = None):
    """Returns (lowered, meta) for one cell."""
    from repro.configs import SHAPES
    from repro.data.synthetic import batch_specs
    from repro.distributed.api import sharding_context
    from repro.distributed.sharding import ShardingRules
    from repro.models import model_for
    from repro.optim.adamw import init_opt_state
    from repro.train.step import make_train_step

    shape = SHAPES[shape_name]
    cfg = _cfg_for(arch, shape.kind, overrides)
    rules = ShardingRules(cfg, mesh)
    model = model_for(cfg)

    bf16 = jnp.bfloat16

    def bf16_struct(tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, bf16 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
            ),
            tree,
        )

    params_struct = bf16_struct(jax.eval_shape(model.init, jax.random.key(0)))
    p_shard = rules.params_shardings(params_struct)
    batch = batch_specs(cfg, shape.seq_len, shape.global_batch, kind=shape.kind)
    b_shard = rules.batch_shardings(batch)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch}

    with sharding_context(mesh, rules.logical_mapping()):
        if shape.kind == "train":
            nm = n_micro or N_MICRO.get(arch, 8)
            meta["n_micro"] = nm
            _, train_step = make_train_step(cfg, mesh, n_micro=nm)
            opt_struct = jax.eval_shape(init_opt_state, params_struct)
            o_shard = rules.opt_shardings(opt_struct)
            fn = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params_struct, opt_struct, batch)
        elif shape.kind == "prefill":
            def prefill(params, b):
                return model.prefill(params, b)

            # emitted caches MUST be sharded explicitly: left to XLA they
            # come out replicated (observed 98 GiB/device on jamba)
            out_struct = jax.eval_shape(prefill, params_struct, batch)
            from jax.sharding import PartitionSpec as P

            logits_shape = out_struct[0].shape
            lspec = rules.batch_spec("logits", logits_shape)
            if logits_shape[-1] % rules.tp == 0:
                lspec = P(*(list(lspec)[:-1] + ["model"]))
            out_sh = (rules.named(lspec), rules.cache_shardings(out_struct[1]))
            fn = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                         out_shardings=out_sh)
            lowered = fn.lower(params_struct, batch)
        else:  # decode
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            c_shard = rules.cache_shardings(cache_struct)

            def decode(params, b, cache):
                return model.decode_step(params, b, cache)

            fn = jax.jit(
                decode, in_shardings=(p_shard, b_shard, c_shard),
                donate_argnums=(2,),
            )
            lowered = fn.lower(params_struct, batch, cache_struct)
    return lowered, meta, cfg


def analyze(lowered, meta: dict, cfg, mesh) -> dict:
    from repro.launch.hlo_analysis import analyze_hlo, roofline_terms

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    out = dict(meta)
    out["compile_s"] = round(compile_s, 2)
    out["n_devices"] = mesh.devices.size

    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(ma, k)
        }
        args = out["memory"].get("argument_size_in_bytes", 0)
        alias = out["memory"].get("alias_size_in_bytes", 0)
        temp = out["memory"].get("temp_size_in_bytes", 0)
        outb = out["memory"].get("output_size_in_bytes", 0)
        out["memory"]["peak_per_device_bytes"] = args + temp + max(outb - alias, 0)
    except Exception as e:  # pragma: no cover
        out["memory_error"] = str(e)

    try:
        ca = compiled.cost_analysis()
        out["cost_analysis_raw"] = {  # XLA's numbers: while bodies counted ONCE
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
    except Exception as e:  # pragma: no cover
        out["cost_error"] = str(e)

    text = compiled.as_text()
    hs = analyze_hlo(text)  # while-trip-scaled: the numbers the roofline uses
    out["cost"] = {"flops": hs.flops, "bytes_accessed": hs.bytes_accessed}
    out["collectives"] = hs.to_dict()

    # roofline
    tokens = meta["seq_len"] * meta["global_batch"] if meta["kind"] != "decode" \
        else meta["global_batch"]
    n_active = cfg.active_param_count()
    mult = 6 if meta["kind"] == "train" else 2
    model_flops = mult * n_active * tokens
    out["model_flops_basis"] = {
        "active_params": n_active, "tokens": tokens, "multiplier": mult
    }
    if "cost" in out:
        out["roofline"] = roofline_terms(
            hlo_flops=out["cost"]["flops"],
            hlo_bytes=out["cost"]["bytes_accessed"],
            collective_bytes=hs.collective_bytes,
            chips=mesh.devices.size,
            model_flops=model_flops,
        )
        if meta["kind"] == "decode" and "memory" in out:
            # decode is memory-bound by construction: the right roofline
            # denominator is one pass over the per-device resident state
            # (param shard + KV/SSM cache shard) = argument bytes.  The
            # HLO-derived memory term is clamped from below by that ideal
            # (a step cannot read less than its resident state once), so
            # the fraction is ≤ 1 by construction.
            from repro.launch.hlo_analysis import HBM_BW

            r = out["roofline"]
            ideal_s = out["memory"]["argument_size_in_bytes"] / HBM_BW
            r["ideal_memory_s"] = ideal_s
            r["memory_s"] = max(r["memory_s"], ideal_s)
            terms = {k: r[k] for k in ("compute_s", "memory_s", "collective_s")}
            r["dominant"] = max(terms, key=terms.get).replace("_s", "")
            r["bound_s"] = max(terms.values())
            r["roofline_fraction"] = ideal_s / max(r["bound_s"], 1e-30)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: str,
             *, n_micro: int | None = None, tag: str = "",
             overrides: dict | None = None) -> dict:
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    lowered, meta, cfg = lower_cell(arch, shape_name, mesh, n_micro=n_micro,
                                    overrides=overrides)
    meta["mesh"] = mesh_kind
    if overrides:
        meta["overrides"] = {k: str(v) for k, v in overrides.items()}
    result = analyze(lowered, meta, cfg, mesh)
    os.makedirs(outdir, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    with open(os.path.join(outdir, name), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (int/float/str), e.g. ssm.chunk=128")
    args = ap.parse_args()

    from repro.configs import cells

    if args.all:
        todo = [(a, s) for a, s, skip in cells() if skip is None]
    else:
        todo = [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    overrides = {}
    for item in args.override:
        k, v = item.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    failures = []
    for arch, shape in todo:
        for mk in meshes:
            t0 = time.time()
            try:
                r = run_cell(arch, shape, mk, args.out,
                             n_micro=args.n_micro, tag=args.tag,
                             overrides=overrides or None)
                roof = r.get("roofline", {})
                print(
                    f"OK  {arch:24s} {shape:12s} {mk:6s} "
                    f"compile={r['compile_s']:7.1f}s "
                    f"dom={roof.get('dominant', '?'):10s} "
                    f"frac={roof.get('roofline_fraction', 0):.3f} "
                    f"mem={r.get('memory', {}).get('peak_per_device_bytes', 0) / 2**30:.2f}GiB",
                    flush=True,
                )
            except Exception as e:
                failures.append((arch, shape, mk, str(e)))
                print(f"FAIL {arch} {shape} {mk}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed")


if __name__ == "__main__":
    main()
