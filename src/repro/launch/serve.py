"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Cold-starts the engine from a block-format checkpoint via the FaaSNet
on-demand path and serves synthetic batched requests.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="smoke config of an assigned arch")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_serve")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import ModelConfig, get_smoke
    from repro.models import model_for
    from repro.serving.engine import ServeEngine

    cfg = get_smoke(args.arch) if args.arch else ModelConfig(
        name="serve_default", family="dense", n_layers=4, d_model=192,
        n_heads=6, n_kv_heads=2, d_ff=512, vocab_size=2048,
        attn_impl="full", remat="none",
    )
    if cfg.family in ("audio",):
        raise SystemExit("enc-dec serving demo requires frames; use the LM archs")
    model = model_for(cfg)
    params = model.init(jax.random.key(0))
    mgr = CheckpointManager(args.ckpt_dir)
    mgr.save(0, params)
    eng = ServeEngine(cfg, max_batch=4)
    eng.start(mgr, 0, params, lazy=True)
    s = eng.cold_start_stats
    print(f"cold start (lazy): first weights {s['t_first_leaves_s']*1e3:.0f} ms, "
          f"full {s['t_full_s']*1e3:.0f} ms, "
          f"amplification {s['read_amplification']:.2f}x")
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                   max_new_tokens=args.max_new_tokens)
    done = []
    while eng.queue:
        done += eng.step_batch()
    lat = [(r.t_done - r.t_submit) * 1e3 for r in done]
    print(f"served {len(done)} requests; latency mean {np.mean(lat):.0f} ms, "
          f"p99 {np.percentile(lat, 99):.0f} ms")


if __name__ == "__main__":
    main()
