"""Roofline accounting from compiled (optimized, scheduled) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-counts scanned-layer / microbatched modules by orders of magnitude.
This module re-derives the roofline terms by walking the HLO call graph:

  * computations are parsed into blocks; ``while`` ops scale everything in
    their body by the loop trip count (recovered from the condition's
    ``compare(iter, constant), direction=LT`` bound);
  * FLOPs   = Σ over reachable ``dot``/``convolution`` ops of
              2 · |result| · |contraction| · scale   (elementwise ignored —
              matmuls dominate every model here);
  * bytes   = Σ over reachable *top-level* instructions (fusion = one
              kernel: operands + result cross HBM; fusion internals do not);
  * collectives = Σ operand bytes of all-gather / all-reduce /
              reduce-scatter / all-to-all / collective-permute, scaled.

All shapes in the text are per-device (post-SPMD), so the derived terms
are per-chip as the roofline needs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_info(type_str: str) -> tuple[int, list[tuple[str, list[int]]]]:
    total = 0
    shapes = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        shapes.append((dtype, ds))
    return total, shapes


@dataclass
class Instr:
    name: str
    result_type: str
    op: str  # opcode-ish token
    rhs: str  # full right-hand side
    operands: list[str]


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)


_COMP_START = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for ln in text.splitlines():
        m = _COMP_START.match(ln.strip())
        if m and "=" not in ln.split("(")[0]:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if ln.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(ln)
        if im is None:
            continue
        name, rhs = im.groups()
        rhs = rhs.strip()
        # result type first: either "(tuple, ...)" or a single "dt[shape]{...}"
        if rhs.startswith("("):
            depth = 0
            end = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            result_type = rhs[: end + 1]
            rest = rhs[end + 1 :].strip()
        else:
            sp = rhs.find(" ")
            result_type = rhs[:sp] if sp > 0 else rhs
            rest = rhs[sp + 1 :].strip() if sp > 0 else ""
        op = rest.split("(")[0].strip()
        paren = rest[rest.find("(") + 1 :] if "(" in rest else ""
        depth, args = 1, ""
        for ch in paren:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        operands = re.findall(r"%[\w.\-]+", args)
        cur.instrs.append(Instr(name, result_type, op, rhs, operands))
    return comps, entry


def _trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    """Largest s32 constant in the condition computation ≈ the LT bound."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    names = {cond_name}
    for ins in cond.instrs:
        m = re.search(r"calls=(%[\w.\-]+)", ins.rhs)
        if m:
            names.add(m.group(1))
    for nm in names:
        c = comps.get(nm)
        if c is None:
            continue
        for ins in c.instrs:
            if ins.op == "constant" and "s32" in ins.result_type:
                m = re.search(r"constant\((-?\d+)\)", ins.rhs)
                if m:
                    best = max(best, int(m.group(1)))
    return max(best, 1)


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0  # innermost loop bodies modeled as kernels
    bytes_raw: float = 0.0  # every fusion boundary counted (CPU-fusion view)
    collective_bytes: float = 0.0
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "bytes_raw": self.bytes_raw,
            "collective_bytes": self.collective_bytes,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def _dot_flops(ins: Instr, sizes: dict[str, tuple[int, list]]) -> float:
    _, res_shapes = _shape_info(ins.result_type)
    if not res_shapes:
        return 0.0
    res_elems = 1
    for d in res_shapes[0][1]:
        res_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    contr = 1
    if m and ins.operands:
        lhs = sizes.get(ins.operands[0])
        if lhs is not None and lhs[1]:
            dims = lhs[1][0][1]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contr *= dims[int(idx)]
    return 2.0 * res_elems * contr


def _conv_flops(ins: Instr, sizes: dict[str, tuple[int, list]]) -> float:
    _, res_shapes = _shape_info(ins.result_type)
    if not res_shapes or len(ins.operands) < 2:
        return 0.0
    res_elems = 1
    for d in res_shapes[0][1]:
        res_elems *= d
    ker = sizes.get(ins.operands[1])
    kelems = 1
    if ker is not None and ker[1]:
        for d in ker[1][0][1]:
            kelems *= d
    return 2.0 * res_elems * kelems  # upper bound (ignores feature groups)


def analyze_hlo(text: str) -> HloStats:
    comps, entry = parse_hlo(text)
    sizes: dict[str, tuple[int, list]] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            sizes[ins.name] = _shape_info(ins.result_type)

    # which computations (transitively) contain a while?
    has_while_cache: dict[str, bool] = {}

    def has_while(comp_name: str) -> bool:
        if comp_name in has_while_cache:
            return has_while_cache[comp_name]
        has_while_cache[comp_name] = False  # cycle guard
        comp = comps.get(comp_name)
        out = False
        if comp is not None:
            for ins in comp.instrs:
                if ins.op == "while":
                    out = True
                    break
                m = re.search(r"calls=(%[\w.\-]+)", ins.rhs)
                if m and has_while(m.group(1)):
                    out = True
                    break
        has_while_cache[comp_name] = out
        return out

    stats = HloStats()

    def kernel_body_bytes(comp_name: str) -> float:
        """Per-iteration HBM bytes if this innermost body were one fused
        kernel (the Pallas view): dynamic-slice tile reads + dynamic-update
        tile writes; carries stay in VMEM."""
        comp = comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            nm = ins.name.lower()
            if ins.op == "dynamic-slice" or (
                ins.op == "fusion" and "dynamic_slice" in nm and "update" not in nm
            ):
                total += sizes.get(ins.name, (0, []))[0]
            elif ins.op == "dynamic-update-slice" or (
                ins.op == "fusion" and "dynamic_update_slice" in nm
            ):
                ob = sorted(
                    (sizes.get(o, (0, []))[0] for o in ins.operands), reverse=True
                )
                total += sum(ob[1:])  # skip the full buffer; count the tile
        return total

    def walk(comp_name: str, scale: float, top_level: bool,
             count_bytes: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                m = re.search(r"condition=(%[\w.\-]+)", ins.rhs)
                b = re.search(r"body=(%[\w.\-]+)", ins.rhs)
                trip = _trip_count(comps, m.group(1)) if m else 1
                if b:
                    body = b.group(1)
                    inner = not has_while(body)
                    if inner and count_bytes:
                        stats.bytes_accessed += scale * trip * kernel_body_bytes(body)
                    # recurse: flops/collectives/raw-bytes always; kernelized
                    # bytes only for non-innermost bodies
                    walk(body, scale * trip, True, count_bytes and not inner)
                continue
            if op in ("fusion", "call", "async-start"):
                m = re.search(r"calls=(%[\w.\-]+)", ins.rhs)
                if m:
                    walk(m.group(1), scale, False, False)
            if op == "conditional":
                for m in re.finditer(
                    r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)",
                    ins.rhs,
                ):
                    walk(m.group(1).strip(), scale, False, False)
            if op == "dot":
                stats.flops += scale * _dot_flops(ins, sizes)
            elif op == "convolution":
                stats.flops += scale * _conv_flops(ins, sizes)
            kind = next(
                (c for c in _COLLECTIVES if op == c or op == c + "-start"), None
            )
            if kind is not None:
                ob = sum(sizes.get(o, (0, []))[0] for o in ins.operands)
                if ob == 0:
                    ob = sizes.get(ins.name, (0, []))[0]
                stats.collective_bytes += scale * ob
                stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + scale * ob
                stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + scale
            if top_level and op not in _SKIP_BYTES:
                ob = sum(sizes.get(o, (0, []))[0] for o in ins.operands)
                rb = sizes.get(ins.name, (0, []))[0]
                stats.bytes_raw += scale * (ob + rb)
                if count_bytes:
                    stats.bytes_accessed += scale * (ob + rb)

    walk(entry, 1.0, True, True)
    return stats


# ----------------------------------------------------------------------
# Roofline terms (TPU v5e constants per the assignment)
# ----------------------------------------------------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    chips: int,
    model_flops: float,
) -> dict:
    """All inputs are PER-DEVICE except model_flops (whole-step ideal)."""
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = model_flops / chips / PEAK_FLOPS  # ideal compute time
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "model_flops_total": model_flops,
        "hlo_flops_per_device": hlo_flops,
        "useful_flops_ratio": (model_flops / chips) / max(hlo_flops, 1.0),
        "roofline_fraction": useful / max(bound, 1e-30),
    }
