"""Distribution topologies: FaaSNet FTs vs the paper's comparison systems.

The simulator (``repro.sim``) is topology-agnostic: each system under test
is described by a :class:`DistributionPlan` — for every node that needs the
payload, *whom* it fetches each piece from and what control-plane overheads
apply.  This module builds plans for:

  * ``faasnet``    — per-function balanced binary FT (this paper);
  * ``baseline``   — every VM pulls the whole image from the central
                     registry (Alibaba's production setup, `docker pull`);
  * ``on_demand``  — like ``baseline`` but fetches only the startup subset
                     of blocks, still from the registry (paper's optimized
                     baseline);
  * ``kraken``     — layer-granularity trees with a dedicated origin/root
                     serving seeding + metadata + coordination (paper §3.4,
                     Figure 10: overlapping layer trees form an all-to-all
                     mesh across VMs);
  * ``dadi_p2p``   — tree-structured P2P with a single resource-constrained
                     root VM that both seeds data and manages topology.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field

from .function_tree import FunctionTree
from .registry import (  # noqa: F401  (REGISTRY re-exported for compat)
    REGISTRY,
    RegistrySpec,
    ShardResolver,
    as_resolver,
)

# Every registry-sourced plan builder takes ``registry`` — a
# :class:`RegistrySpec`, a shared :class:`ShardResolver`, or ``None`` for the
# legacy single-shard registry — and emits flows whose source is a concrete
# shard id (the 1-shard id *is* the legacy ``__registry__`` sentinel, so
# default plans are unchanged).  Pass one resolver across plans when a
# stateful policy (least_loaded / replicated) must see every assignment.


@dataclass(frozen=True)
class Flow:
    """One piece of payload moving src → dst (control plane already resolved)."""

    src: str
    dst: str
    piece: str  # e.g. "img" for whole payload, "layer3", "blk:17"
    bytes: int
    # Leading bytes of this flow that belong to the dst's boot working set
    # (paper §3.2): once they land the engine fires ``on_node_runnable`` for
    # the dst, ahead of full arrival.  0 (the default) means the flow carries
    # no runnable prefix — scalar plans are unchanged.
    runnable_bytes: int = 0


@dataclass
class DistributionPlan:
    """Everything the simulator needs to time one provisioning wave."""

    flows: list[Flow]
    # Per-node extra control-plane latency before its first fetch starts
    # (metadata RPCs, manifest download, coordination with a root).
    control_latency: dict[str, float] = field(default_factory=dict)
    # Nodes whose CPU does coordination work per downstream request (the
    # Kraken origin / DADI root bottleneck): dst-node -> coordinator node.
    coordinator: dict[str, str] = field(default_factory=dict)
    # Whether a node may forward a piece downstream before holding all of it
    # (FaaSNet streams block-by-block; docker-pull systems do not).
    streaming: bool = True


# ----------------------------------------------------------------------
# FaaSNet
# ----------------------------------------------------------------------
def faasnet_plan(
    ft: FunctionTree,
    *,
    image_bytes: int,
    startup_fraction: float = 1.0,
    manifest_latency: float = 0.010,
    piece: str = "img",
    registry: RegistrySpec | ShardResolver | None = None,
) -> DistributionPlan:
    """Blocks stream down FT edges; root fetches from its blob's shard.

    ``startup_fraction`` < 1 models on-demand fetch: only that fraction of
    the payload must arrive before the container can start (§3.5).
    ``piece`` labels the payload — pass the function id when many FTs share
    one simulation so flows stay distinguishable in traces and logs (it is
    also the blob key the shard resolver hashes under ``hash_by_function``).
    """
    need = int(image_bytes * startup_fraction)
    resolver = as_resolver(registry)
    flows = []
    control = {}
    for node in ft.bfs():
        up = ft.parent_of(node.vm_id) or resolver.source_for(piece, nbytes=need)
        flows.append(Flow(up, node.vm_id, piece, need))
        control[node.vm_id] = manifest_latency  # fetch .tar manifest from MDS
    return DistributionPlan(flows=flows, control_latency=control, streaming=True)


# ----------------------------------------------------------------------
# Centralized baselines
# ----------------------------------------------------------------------
def baseline_plan(
    nodes: list[str],
    *,
    image_bytes: int,
    piece: str = "img",
    registry: RegistrySpec | ShardResolver | None = None,
) -> DistributionPlan:
    """docker pull: whole image from its registry shard, no streaming start."""
    resolver = as_resolver(registry)
    flows = [
        Flow(resolver.source_for(piece, nbytes=image_bytes), n, piece, image_bytes)
        for n in nodes
    ]
    return DistributionPlan(flows=flows, streaming=False)


def on_demand_plan(
    nodes: list[str],
    *,
    image_bytes: int,
    startup_fraction: float,
    manifest_latency: float = 0.010,
    piece: str = "img",
    registry: RegistrySpec | ShardResolver | None = None,
) -> DistributionPlan:
    """Registry-served lazy fetch: less data, same per-shard bottleneck."""
    need = int(image_bytes * startup_fraction)
    resolver = as_resolver(registry)
    flows = [
        Flow(resolver.source_for(piece, nbytes=need), n, piece, need)
        for n in nodes
    ]
    control = {n: manifest_latency for n in nodes}
    return DistributionPlan(flows=flows, control_latency=control, streaming=True)


# ----------------------------------------------------------------------
# Kraken-like: layer trees + origin root (paper Figure 10)
# ----------------------------------------------------------------------
def kraken_plan(
    nodes: list[str],
    *,
    layer_bytes: list[int],
    origin: str,
    seed: int = 0,
    max_peers: int = 4,
    manifest_latency: float = 0.010,
) -> DistributionPlan:
    """Each layer forms its own random peer graph rooted at the origin.

    Every node fetches every layer; the source for (node, layer) is a random
    peer among the up-to-``max_peers`` nodes immediately before it in that
    layer's join order (the layer's first joiner seeds from the origin).
    Because layer trees are independent, a node ends
    up with inbound+outbound edges across many trees — the all-to-all mesh
    the paper argues overwhelms 1 Gbps NICs.  The origin additionally
    coordinates every (node, layer) announce — serialized on its CPU by the
    simulator (``SimConfig.coordinator_cost_s``) — so it is both data seeder
    and metadata bottleneck.

    Kraken never touches the registry directly (the origin VM pre-seeds the
    layers), so this builder takes no ``registry`` argument: sharding the
    registry cannot help it — exactly the contrast the shard sweep shows.
    """
    rng = random.Random(seed)
    flows = []
    coordinator = {}
    for li, lb in enumerate(layer_bytes):
        order = list(nodes)
        rng.shuffle(order)  # per-layer join order differs → overlapping trees
        for i, n in enumerate(order):
            if i == 0:
                src = origin
            else:
                src = order[rng.randrange(max(0, i - max_peers), i)]
            flows.append(Flow(src, n, f"layer{li}", lb))
        for n in order:
            coordinator[n] = origin
    control = {n: manifest_latency for n in nodes}
    return DistributionPlan(
        flows=flows, control_latency=control, coordinator=coordinator, streaming=False
    )


# ----------------------------------------------------------------------
# DADI + P2P: single tree, resource-constrained root doing double duty
# ----------------------------------------------------------------------
def dadi_plan(
    nodes: list[str],
    *,
    image_bytes: int,
    root: str,
    fanout: int = 4,
    startup_fraction: float = 1.0,
    manifest_latency: float = 0.010,
    registry: RegistrySpec | ShardResolver | None = None,
) -> DistributionPlan:
    """Static tree rooted at a dedicated VM; root also manages the topology.

    DADI's tree has higher fan-out and is not rebalanced; the root VM pays a
    serialized coordination cost for every joining node (paper §4.3: 'the
    root VM ... is responsible for a series of extra tasks such as
    layer-tree topology establishment and coordination'), applied via
    ``SimConfig.coordinator_cost_s``.
    """
    need = int(image_bytes * startup_fraction)
    resolver = as_resolver(registry)
    flows = [Flow(resolver.source_for("img", nbytes=need), root, "img", need)]
    coordinator = {}
    parents = [root]
    i = 0
    for n in nodes:
        if n == root:
            continue
        parent = parents[i // fanout]
        i += 1
        flows.append(Flow(parent, n, "img", need))
        parents.append(n)
        coordinator[n] = root
    control = {n: manifest_latency for n in nodes}
    return DistributionPlan(
        flows=flows, control_latency=control, coordinator=coordinator, streaming=True
    )


# ----------------------------------------------------------------------
# Block-level plans (paper §3.1–§3.2): per-layer flows, cache-aware
# ----------------------------------------------------------------------
# These builders replace the scalar ``image_bytes * startup_fraction`` model
# with an :class:`~repro.core.image.ImageSpec`: one flow per *missing* layer
# (blocks already resident in the VM's :class:`~repro.core.image.BlockCache`
# are served locally and never travel), with ``Flow.runnable_bytes`` marking
# each flow's share of the boot working set so the engines can fire the
# runnable milestone ahead of full arrival.  Pieces are layer *digests* —
# content-addressed, so shard hashing and streaming chains line up across
# functions sharing base layers.


def _cached_marker_flow(src: str, vm: str, image_name: str) -> Flow:
    """Zero-byte flow for a fully resident node: milestones must still fire."""
    return Flow(src, vm, f"{image_name}:cached", 0)


def faasnet_block_plan(
    ft: FunctionTree,
    *,
    image,
    cache=None,
    manifest_latency: float = 0.010,
    registry: RegistrySpec | ShardResolver | None = None,
) -> DistributionPlan:
    """Per-layer FT streaming with block-cache skips.

    Each missing layer streams down the node's FT edge as its own flow.  A
    parent holding the layer serves it from cache (§3.1) — there is then no
    parent-side flow with that digest, so the child's stream is unchained
    and runs at full NIC rate.  The root sources each missing layer from the
    shard its digest hashes to.
    """
    from .image import BlockCache

    cache = cache if cache is not None else BlockCache()
    resolver = as_resolver(registry)
    flows = []
    control = {}
    for node in ft.bfs():
        vm = node.vm_id
        parent = ft.parent_of(vm)
        n_before = len(flows)
        for la in image.layers:
            need, boot = cache.missing_layer_bytes(vm, image, la.digest)
            if need <= 0:
                continue
            src = parent or resolver.source_for(la.digest, nbytes=need)
            flows.append(Flow(src, vm, la.digest, need, runnable_bytes=boot))
        if len(flows) == n_before:
            src = parent or resolver.source_for(image.name, nbytes=0)
            flows.append(_cached_marker_flow(src, vm, image.name))
        control[vm] = manifest_latency
    return DistributionPlan(flows=flows, control_latency=control, streaming=True)


def on_demand_block_plan(
    nodes: list[str],
    *,
    image,
    cache=None,
    manifest_latency: float = 0.010,
    registry: RegistrySpec | ShardResolver | None = None,
) -> DistributionPlan:
    """Registry-served lazy block fetch: missing layers only, runnable at prefix."""
    from .image import BlockCache

    cache = cache if cache is not None else BlockCache()
    resolver = as_resolver(registry)
    flows = []
    for n in nodes:
        n_before = len(flows)
        for la in image.layers:
            need, boot = cache.missing_layer_bytes(n, image, la.digest)
            if need <= 0:
                continue
            src = resolver.source_for(la.digest, nbytes=need)
            flows.append(Flow(src, n, la.digest, need, runnable_bytes=boot))
        if len(flows) == n_before:
            flows.append(
                _cached_marker_flow(resolver.source_for(image.name, nbytes=0), n, image.name)
            )
    control = {n: manifest_latency for n in nodes}
    return DistributionPlan(flows=flows, control_latency=control, streaming=True)


def baseline_block_plan(
    nodes: list[str],
    *,
    image,
    cache=None,
    registry: RegistrySpec | ShardResolver | None = None,
) -> DistributionPlan:
    """docker pull with a layer cache: whole missing layers, runnable == arrival.

    Docker's cache is layer-granular and all-or-nothing — a partially
    resident layer is re-pulled whole — and a container cannot start before
    the full pull, so every flow's runnable prefix is its entire payload.
    """
    from .image import BlockCache

    cache = cache if cache is not None else BlockCache()
    resolver = as_resolver(registry)
    flows = []
    for n in nodes:
        n_before = len(flows)
        for la in image.layers:
            if cache.resident_blocks(n, la.digest) >= image.layer_blocks(la.digest):
                continue  # fully cached layer: docker skips it
            src = resolver.source_for(la.digest, nbytes=la.size)
            flows.append(Flow(src, n, la.digest, la.size, runnable_bytes=la.size))
        if len(flows) == n_before:
            flows.append(
                _cached_marker_flow(resolver.source_for(image.name, nbytes=0), n, image.name)
            )
    return DistributionPlan(flows=flows, streaming=False)
