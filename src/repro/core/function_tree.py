"""Function Tree (FT): FaaSNet's balanced binary tree overlay (paper §3.2).

A FT is a *keyless* height-balanced binary tree whose nodes are host VMs
(or, in the TPU mapping, hosts / DP replica leaders).  Data flows from the
root — the only node allowed to touch the backing store — down parent→child
edges, so each node has at most one inbound and two outbound streams.

Differences from an AVL tree (and why):
  * Nodes carry no comparable key.  There is no ordering invariant at all —
    only the height invariant |h(left) − h(right)| ≤ 1 at every node.
  * ``insert`` therefore does not descend by key: the FT manager keeps a FIFO
    of nodes with <2 children (paper: "stores all nodes that has 0 or 1 child
    in a queue" discovered via BFS) and attaches the new node under the first.
  * ``delete`` removes an arbitrary node (a reclaimed VM, anywhere in the
    tree); the hole is plugged by promoting the *deepest-last* node (the last
    node in BFS order), which keeps the tree complete-ish and never increases
    any height.  Rebalancing then runs the four classic rotations
    (left_rotate / right_rotate / left_right_rotate / right_left_rotate)
    bottom-up from the modified point.

O(log n) slot discovery — frontier deque + open-depth index
-----------------------------------------------------------
The original implementation discovered both special slots by scanning: the
BFS-first node with <2 children (insert target) and the BFS-last node
(delete filler) were each found with a full breadth-first walk, so standing
up an n-node tree cost O(n²) node visits.  Two structures replace the scans
while producing *bit-identical* tree shapes:

  * **Open-slot frontier** (``_frontier``): while the tree is *complete* —
    i.e. it has only ever been grown by ``insert`` — the nodes with <2
    children form a contiguous suffix of BFS order and behave as a FIFO:
    attach under ``frontier[0]``, pop it once it has two children, append
    the new leaf at the back.  Insert is then O(1) for slot discovery
    (plus an O(log n) height retrace that usually exits after O(1) steps).
    Deleting the BFS-last leaf keeps the tree complete, so that case
    repairs the frontier in O(1) (pop the right end, re-open the parent);
    any other delete — and any rotation — breaks completeness and
    permanently switches the tree to the index below.
  * **Open-depth index** (``FTNode.open_depth``): every node caches the
    minimum depth, relative to itself, of a node with <2 children in its
    subtree (0 if the node itself is open).  The BFS-first open slot is
    found by descending from the root toward the child with the smaller
    ``open_depth`` (ties go left, which is exactly BFS order within a
    level), and the BFS-last node by descending toward the *taller* child
    (ties go right).  Both descents are O(log n); ``open_depth`` is
    maintained on the same bottom-up retrace that already fixes heights,
    so no asymptotic cost is added to mutations.

``on_reparent`` observers receive ``(node, old_parent, new_parent)`` for
every parent-pointer change made by rotations and the delete splice — the
FT manager uses the (old, new) pair to keep per-VM seeding-load counters
exact.  Plain insert attachment and deepest-last unlink stay silent (no
stream needs restarting), which callers rely on.

The implementation is deliberately pure-Python and allocation-light: FTs are
control-plane objects that live in the scheduler, get mutated at VM
join/leave rate, and must support thousands of instances (one per function).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterator, NamedTuple, Optional


@dataclass
class FTNode:
    """A single tree node.  ``vm_id`` identifies the host VM (or TPU host)."""

    vm_id: str
    parent: Optional["FTNode"] = None
    left: Optional["FTNode"] = None
    right: Optional["FTNode"] = None
    height: int = 1  # height of the subtree rooted here (leaf = 1)
    # min depth (relative to this node) of a subtree node with <2 children;
    # 0 whenever this node itself has an open child slot.
    open_depth: int = 0

    # -- helpers ---------------------------------------------------------
    def child_count(self) -> int:
        return (self.left is not None) + (self.right is not None)

    def children(self) -> list["FTNode"]:
        return [c for c in (self.left, self.right) if c is not None]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FTNode({self.vm_id}, h={self.height})"


def _h(node: Optional[FTNode]) -> int:
    return node.height if node is not None else 0


def _balance(node: Optional[FTNode]) -> int:
    if node is None:
        return 0
    return _h(node.left) - _h(node.right)


class DeleteInfo(NamedTuple):
    """Structural summary of one ``delete`` (consumed by FTManager accounting).

    ``parent`` is the removed node's pre-delete parent; ``filler`` is the
    promoted deepest-last node when the hole had to be plugged (None when
    the removed node *was* the deepest-last leaf); ``filler_parent`` is the
    filler's pre-unlink parent.  All are vm_ids, None where absent.
    """

    parent: Optional[str]
    filler: Optional[str]
    filler_parent: Optional[str]


class FunctionTree:
    """A keyless height-balanced binary tree with FaaSNet's insert/delete API.

    Invariants (checked by :meth:`check_invariants`):
      I1  parent/child pointers are mutually consistent;
      I2  every node's cached height equals 1 + max(child heights);
      I3  |balance factor| ≤ 1 at every node;
      I4  ``vm_id`` values are unique within the tree;
      I5  every node's cached ``open_depth`` is consistent with its children;
      I6  while the frontier fast path is active, the frontier deque equals
          the BFS-ordered list of nodes with <2 children.
    """

    def __init__(self, function_id: str = "") -> None:
        self.function_id = function_id
        self.root: Optional[FTNode] = None
        self._nodes: dict[str, FTNode] = {}
        # Open-slot frontier: valid only while the tree is known complete
        # (grown purely by insert / deepest-last delete).  See module doc.
        self._frontier: deque[FTNode] = deque()
        self._frontier_ok: bool = True
        # Observers used by the simulator / provisioning layer to learn about
        # re-parenting events (a node whose parent changed must restart its
        # inbound stream from the new parent).  Signature:
        # ``cb(node, old_parent, new_parent)``.
        self.on_reparent: list[
            Callable[[FTNode, Optional[FTNode], Optional[FTNode]], None]
        ] = []

    # ------------------------------------------------------------------
    # Read API
    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, vm_id: str) -> bool:
        return vm_id in self._nodes

    def get(self, vm_id: str) -> Optional[FTNode]:
        return self._nodes.get(vm_id)

    @property
    def height(self) -> int:
        return _h(self.root)

    def bfs(self) -> Iterator[FTNode]:
        """Breadth-first traversal (paper: the manager tracks child counts via BFS)."""
        if self.root is None:
            return
        q: deque[FTNode] = deque([self.root])
        while q:
            n = q.popleft()
            yield n
            if n.left is not None:
                q.append(n.left)
            if n.right is not None:
                q.append(n.right)

    def vm_ids(self) -> list[str]:
        return [n.vm_id for n in self.bfs()]

    def parent_of(self, vm_id: str) -> Optional[str]:
        """The upstream peer a worker fetches from (None for the root)."""
        node = self._nodes[vm_id]
        return node.parent.vm_id if node.parent is not None else None

    def children_of(self, vm_id: str) -> list[str]:
        return [c.vm_id for c in self._nodes[vm_id].children()]

    def depth_of(self, vm_id: str) -> int:
        """Number of hops from the root (root = 0); O(height) = O(log n)."""
        node = self._nodes[vm_id]
        d = 0
        while node.parent is not None:
            node = node.parent
            d += 1
        return d

    def edges(self) -> list[tuple[str, str]]:
        """(parent, child) pairs — the provisioning flow graph."""
        return [
            (n.vm_id, c.vm_id) for n in self.bfs() for c in n.children()
        ]

    # ------------------------------------------------------------------
    # insert
    # ------------------------------------------------------------------
    def insert(self, vm_id: str) -> FTNode:
        """Attach ``vm_id`` under the first BFS node with <2 children.

        The very first node becomes the root (paper §3.2).  Attaching under
        the BFS-first open slot keeps a complete tree complete, hence
        balanced, so insert never triggers a rotation — but we still fix
        heights (and the open-depth index) upward.
        """
        if vm_id in self._nodes:
            raise ValueError(f"vm {vm_id!r} already in FT {self.function_id!r}")
        node = FTNode(vm_id)
        self._nodes[vm_id] = node
        if self.root is None:
            self.root = node
            if self._frontier_ok:
                self._frontier.append(node)
            return node
        parent = self._take_open_slot()
        node.parent = parent
        if parent.left is None:
            parent.left = node
        else:
            parent.right = node
        if self._frontier_ok:
            self._frontier.append(node)
            if parent.right is not None:  # parent just filled up
                self._frontier.popleft()
        self._retrace(parent)
        return node

    def _take_open_slot(self) -> FTNode:
        """BFS-first node with <2 children: frontier head or index descent."""
        if self._frontier_ok:
            return self._frontier[0]
        n = self.root
        assert n is not None
        while n.left is not None and n.right is not None:
            # Descend toward the shallower open slot; on ties go left, which
            # is the earlier node in BFS order within the level.
            if n.left.open_depth <= n.right.open_depth:
                n = n.left
            else:
                n = n.right
        return n

    # ------------------------------------------------------------------
    # delete
    # ------------------------------------------------------------------
    def delete(self, vm_id: str) -> DeleteInfo:
        """Remove ``vm_id`` (an arbitrary node) and rebalance if needed.

        Strategy: if the node is a leaf, unlink it.  Otherwise promote the
        *last BFS node* (deepest, right-most — always a leaf) into the hole.
        Then retrace from the lowest structurally-modified point, fixing
        heights and applying rotations wherever |balance| > 1.

        Returns a :class:`DeleteInfo` naming the structural roles so that
        the FT manager can maintain per-VM seed-load counters without
        re-walking the tree.
        """
        node = self._nodes.pop(vm_id, None)
        if node is None:
            raise KeyError(f"vm {vm_id!r} not in FT {self.function_id!r}")

        if len(self._nodes) == 0:
            self.root = None
            node.parent = None
            # an empty tree is trivially complete: re-arm the fast path
            self._frontier.clear()
            self._frontier_ok = True
            return DeleteInfo(None, None, None)

        parent_id = node.parent.vm_id if node.parent is not None else None
        filler = self._last_bfs_node()
        if filler is node:
            # node is the deepest-last leaf: plain unlink.  A complete tree
            # stays complete, so the frontier survives with O(1) repair.
            start = node.parent
            if self._frontier_ok:
                assert self._frontier and self._frontier[-1] is node
                self._frontier.pop()
                if start is not None and start.right is node:
                    # parent was full, regains an open slot — and it is the
                    # BFS-first one (everything before it is still full).
                    self._frontier.appendleft(start)
            self._unlink_leaf(node)
            self._retrace(start)
            return DeleteInfo(parent_id, None, None)

        # Interior (or non-last) delete: completeness is lost for good.
        self._frontier_ok = False
        self._frontier.clear()
        # Detach the filler leaf, then splice it into node's position.
        filler_parent = filler.parent
        filler_parent_id = filler_parent.vm_id if filler_parent is not None else None
        self._unlink_leaf(filler)
        start = filler_parent if filler_parent is not node else filler
        self._replace(node, filler)
        self._retrace(start)
        return DeleteInfo(parent_id, filler.vm_id, filler_parent_id)

    def _last_bfs_node(self) -> FTNode:
        """Deepest, BFS-last node via height descent (taller child, ties right)."""
        n = self.root
        assert n is not None
        while True:
            h = n.height
            if n.right is not None and n.right.height == h - 1:
                n = n.right  # right subtree reaches the deepest level
            elif n.left is not None:
                n = n.left
            else:
                return n

    def _unlink_leaf(self, leaf: FTNode) -> None:
        assert leaf.child_count() == 0, "only leaves can be unlinked"
        p = leaf.parent
        if p is None:
            self.root = None
        elif p.left is leaf:
            p.left = None
        else:
            p.right = None
        leaf.parent = None

    def _replace(self, old: FTNode, new: FTNode) -> None:
        """Put ``new`` (a detached node) where ``old`` was."""
        new.parent = old.parent
        new.left = old.left
        new.right = old.right
        if new.left is not None:
            new.left.parent = new
            self._notify_reparent(new.left, old, new)
        if new.right is not None:
            new.right.parent = new
            self._notify_reparent(new.right, old, new)
        if old.parent is None:
            self.root = new
        elif old.parent.left is old:
            old.parent.left = new
        else:
            old.parent.right = new
        new.height = old.height
        new.open_depth = old.open_depth
        self._notify_reparent(new, None, new.parent)
        old.parent = old.left = old.right = None

    # ------------------------------------------------------------------
    # Rebalancing — the four rotations (paper Figures 6 & 7)
    # ------------------------------------------------------------------
    def _retrace(self, node: Optional[FTNode]) -> None:
        """Walk from ``node`` to the root fixing heights/open-depths, rotating.

        Early exit: once a node's height *and* open_depth come out unchanged
        (and its balance is fine), every ancestor — whose cached values
        depend only on its children's — is already consistent.
        """
        while node is not None:
            old_h, old_od = node.height, node.open_depth
            self._fix(node)
            bal = _balance(node)
            if bal > 1:
                # Left-heavy.
                if _balance(node.left) >= 0:
                    node = self.right_rotate(node)
                else:
                    node = self.left_right_rotate(node)
            elif bal < -1:
                # Right-heavy.
                if _balance(node.right) <= 0:
                    node = self.left_rotate(node)
                else:
                    node = self.right_left_rotate(node)
            elif node.height == old_h and node.open_depth == old_od:
                return
            node = node.parent

    @staticmethod
    def _fix(node: FTNode) -> None:
        """Recompute the cached height and open-depth from the children."""
        l, r = node.left, node.right
        if l is None or r is None:
            node.height = 1 + (l.height if l is not None else r.height if r is not None else 0)
            node.open_depth = 0
        else:
            node.height = 1 + (l.height if l.height >= r.height else r.height)
            node.open_depth = 1 + (
                l.open_depth if l.open_depth <= r.open_depth else r.open_depth
            )

    # kept under its historical name for subclasses/tests that poke at it
    _fix_height = _fix

    def _rotate_common(self, old_sub_root: FTNode, new_sub_root: FTNode) -> None:
        """Attach ``new_sub_root`` where ``old_sub_root`` was."""
        self._frontier_ok = False  # rotations break completeness (defensive:
        self._frontier.clear()  # only reachable after a frontier-breaking delete)
        new_sub_root.parent = old_sub_root.parent
        if old_sub_root.parent is None:
            self.root = new_sub_root
        elif old_sub_root.parent.left is old_sub_root:
            old_sub_root.parent.left = new_sub_root
        else:
            old_sub_root.parent.right = new_sub_root
        self._notify_reparent(new_sub_root, old_sub_root, new_sub_root.parent)

    def left_rotate(self, x: FTNode) -> FTNode:
        """Right child ``y`` of ``x`` becomes the subtree root."""
        y = x.right
        assert y is not None
        x_parent = x.parent
        self._rotate_common(x, y)
        x.right = y.left
        if y.left is not None:
            y.left.parent = x
            self._notify_reparent(y.left, y, x)
        y.left = x
        x.parent = y
        self._notify_reparent(x, x_parent, y)
        self._fix(x)
        self._fix(y)
        return y

    def right_rotate(self, x: FTNode) -> FTNode:
        """Left child ``y`` of ``x`` becomes the subtree root (paper Fig. 6)."""
        y = x.left
        assert y is not None
        x_parent = x.parent
        self._rotate_common(x, y)
        x.left = y.right
        if y.right is not None:
            y.right.parent = x
            self._notify_reparent(y.right, y, x)
        y.right = x
        x.parent = y
        self._notify_reparent(x, x_parent, y)
        self._fix(x)
        self._fix(y)
        return y

    def left_right_rotate(self, x: FTNode) -> FTNode:
        """Left-rotate x.left, then right-rotate x."""
        assert x.left is not None
        self.left_rotate(x.left)
        return self.right_rotate(x)

    def right_left_rotate(self, x: FTNode) -> FTNode:
        """Right-rotate x.right, then left-rotate x (paper Fig. 7)."""
        assert x.right is not None
        self.right_rotate(x.right)
        return self.left_rotate(x)

    def _notify_reparent(
        self,
        node: FTNode,
        old_parent: Optional[FTNode],
        new_parent: Optional[FTNode],
    ) -> None:
        for cb in self.on_reparent:
            cb(node, old_parent, new_parent)

    # ------------------------------------------------------------------
    # Invariant checking (used by tests / hypothesis)
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        seen: set[str] = set()
        if self.root is not None and self.root.parent is not None:
            raise AssertionError("root has a parent")
        for n in self.bfs():
            if n.vm_id in seen:
                raise AssertionError(f"duplicate vm_id {n.vm_id}")
            seen.add(n.vm_id)
            for c in n.children():
                if c.parent is not n:
                    raise AssertionError(
                        f"child {c.vm_id} of {n.vm_id} has wrong parent pointer"
                    )
            expect = 1 + max(_h(n.left), _h(n.right))
            if n.height != expect:
                raise AssertionError(
                    f"stale height at {n.vm_id}: {n.height} != {expect}"
                )
            if n.child_count() < 2:
                expect_od = 0
            else:
                expect_od = 1 + min(n.left.open_depth, n.right.open_depth)
            if n.open_depth != expect_od:
                raise AssertionError(
                    f"stale open_depth at {n.vm_id}: {n.open_depth} != {expect_od}"
                )
            if abs(_balance(n)) > 1:
                raise AssertionError(
                    f"imbalance at {n.vm_id}: balance={_balance(n)}"
                )
        if seen != set(self._nodes):
            raise AssertionError("node index out of sync with tree")
        if self._frontier_ok:
            expect_frontier = [n.vm_id for n in self.bfs() if n.child_count() < 2]
            got = [n.vm_id for n in self._frontier]
            if got != expect_frontier:
                raise AssertionError(
                    f"frontier out of sync: {got} != {expect_frontier}"
                )

    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-serializable topology snapshot (for checkpointing the manager)."""

        def rec(n: Optional[FTNode]):
            if n is None:
                return None
            return {"vm": n.vm_id, "l": rec(n.left), "r": rec(n.right)}

        return {"function_id": self.function_id, "tree": rec(self.root)}

    @classmethod
    def from_dict(cls, d: dict) -> "FunctionTree":
        ft = cls(d["function_id"])

        def rec(spec, parent):
            if spec is None:
                return None
            node = FTNode(spec["vm"], parent=parent)
            ft._nodes[node.vm_id] = node
            node.left = rec(spec["l"], node)
            node.right = rec(spec["r"], node)
            ft._fix(node)
            return node

        ft.root = rec(d["tree"], None)
        # A restored tree has arbitrary (balanced) shape: the FIFO frontier
        # is only valid for complete trees, so fall back to index descent.
        ft._frontier_ok = ft.root is None
        return ft
