"""FT manager — the scheduler-side control plane (paper §3.3).

Responsibilities (all control-plane; no payload bytes flow through here):
  * one :class:`FunctionTree` per function id (``insert``/``delete`` API);
  * the VM pool: free pool → active pool reservation, per-instance idle
    reclaim through a pluggable :class:`~repro.core.reclaim.ReclaimPolicy`
    (fixed 15-min lifespan in Alibaba's production config), failure
    detection → tree repair;
  * function→VM placement admitted by **per-function memory** (each function
    charges its ``mem_mb`` requirement against the VM's budget — one VM
    hosts many tenants' functions, paper §3.1) with the ≤
    ``max_functions_per_vm`` production limit (20) retained as a secondary
    cap, and the FT-aware placement refinement of paper §5 (prefer VMs that
    already appear in few trees / as leaves, to balance per-VM in/out
    bandwidth across overlapping FTs);
  * the ``<function_id, FT>`` metadata map, snapshottable to a dict for the
    etcd-style metadata-store sync the paper describes.

Placement is O(log V) amortized per decision: candidates live in a lazily
rebuilt min-heap keyed ``(load, seed_load, mem_used_mb, registration_index)``
(or ``(-load, -mem_used_mb, registration_index)`` for the pure binpack mode)
with stale entries dropped on pop — a VM's entry is re-pushed whenever its
key changes, so the entry matching the current key is always present.
Entries skipped for *per-function* reasons (the VM already hosts the
function, or lacks memory for this function's requirement) are pushed back:
those conditions depend on which function is being placed, so dropping the
entry would lose the VM for every other function even though its key never
changes again.  ``seed_load`` (the VM's total outbound child streams across
all trees) is maintained incrementally from
:attr:`FunctionTree.on_reparent` callbacks plus the
:class:`~repro.core.function_tree.DeleteInfo` record instead of re-walking
trees.  The tie-break by registration index reproduces the original
full-pool stable sort exactly, so placement decisions are bit-identical to
the O(V log V) implementation they replace; with uniform (or zero) memory
requirements the memory key component is monotone in ``load`` and placement
is bit-identical to the pre-memory implementation.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .function_tree import FTNode, FunctionTree
from .reclaim import ReclaimPolicy, resolve_reclaim_policy, restore_reclaim_policy


@dataclass
class VMInfo:
    vm_id: str
    address: str = ""
    port: int = 0
    mem_mb: int = 4096  # paper §4.1: 2-CPU / 4 GB VMs
    functions: set[str] = field(default_factory=set)  # function ids placed here
    last_active: float = 0.0
    alive: bool = True
    # Per-function memory accounting (shared pool: one VM, many tenants).
    func_mem_mb: dict[str, int] = field(default_factory=dict)  # fid -> charged MB
    mem_used_mb: int = 0  # Σ func_mem_mb.values(), maintained incrementally
    # Per-instance activity (reclaim is evaluated per (function, vm) pair).
    func_last_active: dict[str, float] = field(default_factory=dict)

    def load(self) -> int:
        return len(self.functions)

    def mem_free_mb(self) -> int:
        return self.mem_mb - self.mem_used_mb


class FTManager:
    """Per-function tree + VM pool manager embedded in the FaaS scheduler."""

    def __init__(
        self,
        *,
        max_functions_per_vm: int = 20,
        vm_idle_reclaim_s: float = 15 * 60.0,
        ft_aware_placement: bool = True,
        reclaim: "str | ReclaimPolicy | None" = None,
        default_function_mem_mb: int = 0,
    ) -> None:
        self.trees: dict[str, FunctionTree] = {}
        self.vms: dict[str, VMInfo] = {}
        self.free_pool: deque[str] = deque()
        self._free_ids: set[str] = set()  # guards release→reserve→release races
        self.max_functions_per_vm = max_functions_per_vm
        self.vm_idle_reclaim_s = vm_idle_reclaim_s
        self.ft_aware_placement = ft_aware_placement
        # Reclaim policy (per function-instance); the default fixed-TTL
        # policy reproduces the legacy per-VM lifespan behaviour exactly.
        self.reclaim: ReclaimPolicy = resolve_reclaim_policy(
            reclaim, default_ttl_s=vm_idle_reclaim_s
        )
        # Per-function memory requirements (MB charged per placed instance).
        # 0 (the default) leaves placement constrained only by the flat
        # function-count cap — bit-identical to the pre-memory manager.
        self.function_mem: dict[str, int] = {}
        self.default_function_mem_mb = default_function_mem_mb
        # Scale-from-zero wave locks (request serving, cold-start herd
        # control): function id -> containers of its in-flight provisioning
        # wave still awaiting activation.  While an entry exists the serving
        # layer parks further scale-out for that function, so a cold-start
        # request herd triggers exactly ONE wave instead of a reservation
        # per queued request.  Scheduler state: rides the failover snapshot.
        self.wave_locks: dict[str, int] = {}
        # Incremental placement state --------------------------------------
        self._seed_loads: dict[str, int] = {}  # vm_id -> Σ children over trees
        self._vm_order: dict[str, int] = {}  # registration index (sort tie-break)
        self._placement_heap: list[tuple] = []  # (key..., vm_id), lazily pruned
        # Content-aware root election (§3.1): optional data-plane scorer,
        # see set_content_affinity.  Never serialized.
        self._content_affinity = None
        self._content_candidates = None
        # counters for tests / telemetry
        self.stats = {
            "inserts": 0,
            "deletes": 0,
            "repairs": 0,
            "reclaims": 0,
            "reservations": 0,
            "waves": 0,
            "content_roots": 0,
        }

    # ------------------------------------------------------------------
    # VM pool
    # ------------------------------------------------------------------
    def add_free_vm(self, vm: VMInfo) -> None:
        if vm.vm_id in self.vms:
            raise ValueError(f"vm {vm.vm_id!r} already registered")
        self.vms[vm.vm_id] = vm
        self._vm_order[vm.vm_id] = len(self._vm_order)
        self._seed_loads.setdefault(vm.vm_id, 0)
        self.free_pool.append(vm.vm_id)
        self._free_ids.add(vm.vm_id)

    def reserve_vm(self, now: float = 0.0) -> Optional[VMInfo]:
        """Move one VM from the free pool to active (scheduler scale-out)."""
        while self.free_pool:
            vm_id = self.free_pool.popleft()
            self._free_ids.discard(vm_id)
            vm = self.vms[vm_id]
            if vm.alive:
                vm.last_active = now
                self.stats["reservations"] += 1
                return vm
        return None

    def release_vm(self, vm_id: str) -> None:
        """Return an active VM (no functions left) to the free pool.

        Idempotent: a release→reserve→release churn loop (or a double
        release from two reclaim paths) never double-appends the id.
        """
        vm = self.vms[vm_id]
        assert not vm.functions, "cannot release a VM still holding functions"
        if vm.alive and vm_id not in self._free_ids:
            self.free_pool.append(vm_id)
            self._free_ids.add(vm_id)

    # ------------------------------------------------------------------
    # Tree membership (insert / delete drive everything else)
    # ------------------------------------------------------------------
    def tree(self, function_id: str) -> FunctionTree:
        if function_id not in self.trees:
            ft = FunctionTree(function_id)
            ft.on_reparent.append(self._account_reparent)
            self.trees[function_id] = ft
        return self.trees[function_id]

    def _account_reparent(
        self, node: FTNode, old_parent: Optional[FTNode], new_parent: Optional[FTNode]
    ) -> None:
        """Keep per-VM child-stream totals exact across rotations/splices."""
        if old_parent is not None:
            self._seed_load_add(old_parent.vm_id, -1)
        if new_parent is not None:
            self._seed_load_add(new_parent.vm_id, +1)

    def _seed_load_add(self, vm_id: str, delta: int) -> None:
        self._seed_loads[vm_id] = self._seed_loads.get(vm_id, 0) + delta
        self._heap_push(vm_id)

    # ------------------------------------------------------------------
    # Per-function memory requirements
    # ------------------------------------------------------------------
    def set_function_mem(self, function_id: str, mem_mb: int) -> None:
        """Register a function's per-instance memory requirement (MB)."""
        if mem_mb < 0:
            raise ValueError(f"negative memory requirement for {function_id!r}")
        self.function_mem[function_id] = int(mem_mb)

    def mem_need(self, function_id: str) -> int:
        return self.function_mem.get(function_id, self.default_function_mem_mb)

    def insert(self, function_id: str, vm_id: str, now: float = 0.0) -> str | None:
        """Add ``vm_id`` to the function's FT; returns the upstream peer id.

        Admission is by memory: the function's requirement must fit in the
        VM's free memory (the flat ``max_functions_per_vm`` production cap
        is retained as a secondary limit).  Returns ``None`` when the new
        node is the root (it will fetch from the registry / backing store
        instead of a peer).
        """
        vm = self.vms[vm_id]
        if len(vm.functions) >= self.max_functions_per_vm:
            raise RuntimeError(
                f"placement limit: vm {vm_id} already holds "
                f"{len(vm.functions)} functions"
            )
        need = self.mem_need(function_id)
        if vm.mem_used_mb + need > vm.mem_mb:
            raise RuntimeError(
                f"memory limit: vm {vm_id} has {vm.mem_free_mb()} MB free, "
                f"{function_id} needs {need} MB"
            )
        ft = self.tree(function_id)
        ft.insert(vm_id)
        vm.functions.add(function_id)
        vm.func_mem_mb[function_id] = need
        vm.mem_used_mb += need
        vm.func_last_active[function_id] = now
        vm.last_active = now
        self.stats["inserts"] += 1
        up = ft.parent_of(vm_id)
        if up is not None:
            self._seed_load_add(up, +1)  # attach is silent on on_reparent
        self._heap_push(vm_id)
        return up

    def bulk_insert(
        self, function_id: str, vm_ids: list[str], now: float = 0.0
    ) -> FunctionTree:
        """Insert many VMs into one function's FT (burst scale-out).

        Used by the scale harness (``repro.sim.scale``) to stand up the
        paper's §4.2 thousand-VM waves; semantically identical to calling
        :meth:`insert` in a loop, returns the tree for convenience.
        """
        for vm_id in vm_ids:
            self.insert(function_id, vm_id, now)
        return self.trees[function_id]

    def delete(self, function_id: str, vm_id: str) -> None:
        ft = self.trees[function_id]
        info = ft.delete(vm_id)
        # Silent structural changes (see FunctionTree.delete): the victim
        # leaves its parent, and — when a filler was promoted — the filler
        # leaves its own pre-unlink parent.  Rotations/splices already fired
        # on_reparent with exact (old, new) pairs.
        if info.parent is not None:
            self._seed_load_add(info.parent, -1)
        if info.filler is not None and info.filler_parent is not None:
            self._seed_load_add(info.filler_parent, -1)
        vm = self.vms[vm_id]
        vm.functions.discard(function_id)
        vm.mem_used_mb -= vm.func_mem_mb.pop(function_id, 0)
        vm.func_last_active.pop(function_id, None)
        self._heap_push(vm_id)
        self.stats["deletes"] += 1
        if len(ft) == 0:
            del self.trees[function_id]

    def touch_instance(self, function_id: str, vm_id: str, now: float) -> None:
        """An instance served a request: refresh its (and its VM's) clock."""
        vm = self.vms[vm_id]
        if now > vm.last_active:
            vm.last_active = now
        if function_id in vm.functions:
            vm.func_last_active[function_id] = max(
                vm.func_last_active.get(function_id, 0.0), now
            )

    # ------------------------------------------------------------------
    # Placement (paper §3.3 "Function Placement on VMs" + §5 FT-aware)
    # ------------------------------------------------------------------
    def _heap_key(self, vm: VMInfo) -> tuple:
        # The memory component sits after (load, seed_load) so that with
        # uniform requirements (mem_used == load * mem) it is monotone in
        # load and the ordering — hence every placement decision — is
        # bit-identical to the pre-memory key.  With heterogeneous
        # requirements it prefers memory-lighter VMs (FT-aware) or packs
        # memory-fuller ones first (binpack).
        if self.ft_aware_placement:
            return (
                len(vm.functions),
                self._seed_loads.get(vm.vm_id, 0),
                vm.mem_used_mb,
                self._vm_order[vm.vm_id],
            )
        return (  # binpack: fullest first
            -len(vm.functions),
            -vm.mem_used_mb,
            self._vm_order[vm.vm_id],
        )

    def _heap_push(self, vm_id: str) -> None:
        vm = self.vms.get(vm_id)
        if vm is None or not vm.alive or not vm.functions:
            return  # never a placement candidate until its key next changes
        heapq.heappush(self._placement_heap, self._heap_key(vm) + (vm_id,))

    def _rebuild_heap(self) -> None:
        self._placement_heap = [
            self._heap_key(vm) + (vm.vm_id,)
            for vm in self.vms.values()
            if vm.alive and vm.functions
        ]
        heapq.heapify(self._placement_heap)

    def set_content_affinity(self, fn, candidates=None) -> None:
        """Attach a content-residency scorer for root election (§3.1).

        ``fn(function_id, vm_id) -> int`` reports how many bytes of the
        function's image are already resident on a VM (e.g.
        ``BlockCache.resident_bytes``).  When a function's *first* instance
        is placed — root election; the root fetches from the registry, so
        starting it where the base layers already live saves the most
        backbone traffic — :meth:`pick_vm_for` prefers the admissible VM
        with the most resident bytes and falls back to the normal placement
        path when nothing scores above zero.  The scorer is data-plane
        state: it does not ride :meth:`snapshot`, re-attach after restore.

        ``candidates`` (optional, ``() -> iterable[vm_id]``) bounds the
        election scan to VMs that can possibly score above zero — e.g.
        ``BlockCache.vms`` — instead of the whole fleet.  Any VM outside
        the candidate set must score 0 (it would be skipped anyway), so
        the election result is unchanged; on a 100k-VM pool this turns an
        O(fleet) scan per reservation into O(warm VMs).
        """
        self._content_affinity = fn
        self._content_candidates = candidates

    def _content_root_for(self, function_id: str, now: float) -> Optional[VMInfo]:
        """Root election: the admissible VM holding the most image bytes."""
        need = self.mem_need(function_id)
        best: Optional[VMInfo] = None
        best_key: Optional[tuple] = None
        if self._content_candidates is not None:
            vms = self.vms
            scan = [
                (vid, vms[vid]) for vid in self._content_candidates()
                if vid in vms
            ]
        else:
            scan = self.vms.items()
        for vm_id, vm in scan:
            if not vm.alive or function_id in vm.functions:
                continue
            if len(vm.functions) >= self.max_functions_per_vm:
                continue
            if vm.mem_used_mb + need > vm.mem_mb:
                continue
            resident = int(self._content_affinity(function_id, vm_id))
            if resident <= 0:
                continue
            key = (
                -resident,
                len(vm.functions),
                self._seed_loads.get(vm_id, 0),
                self._vm_order[vm_id],
            )
            if best_key is None or key < best_key:
                best_key, best = key, vm
        if best is None:
            return None
        if best.vm_id in self._free_ids:
            # promote the warm-cache VM straight out of the free pool; the
            # deque keeps FIFO order for everyone else
            self.free_pool.remove(best.vm_id)
            self._free_ids.discard(best.vm_id)
            self.stats["reservations"] += 1
        best.last_active = now
        self.stats["content_roots"] += 1
        return best

    def pick_vm_for(self, function_id: str, now: float = 0.0) -> Optional[VMInfo]:
        """Choose a host for a new instance of ``function_id``.

        Admission is by memory: the VM must have ``mem_need(function_id)``
        MB free (plus a spare slot under the flat production cap) and must
        not already host this function.  Binpacking baseline: the fullest
        such VM.  FT-aware refinement (§5): prefer the VM currently
        involved in the fewest trees and, among those, one that is a leaf
        in most of its trees — leaves have zero outbound seeding load, so
        adding an inbound stream there balances bandwidth.  Falls back to
        reserving a free VM.

        Amortized O(log V): pops the lazily pruned candidate heap until an
        entry matches its VM's current key.  Entries skipped for
        *per-function* reasons — the VM already hosts ``function_id``, or
        its free memory is below *this* function's requirement — are pushed
        back afterwards: both conditions can differ for the next function
        placed while the VM's key stays unchanged (so no re-push would ever
        revive a dropped entry).  Entries failing the function-count cap
        may be dropped safely: any change to the count changes the key and
        re-pushes a live entry.
        """
        if self._content_affinity is not None and function_id not in self.trees:
            vm = self._content_root_for(function_id, now)
            if vm is not None:
                return vm
        if len(self._placement_heap) > max(64, 4 * len(self.vms)):
            self._rebuild_heap()  # mostly-stale heap: rebuild and re-amortize
        need = self.mem_need(function_id)
        heap = self._placement_heap
        skipped: list[tuple] = []
        winner: Optional[VMInfo] = None
        seen: set[str] = set()
        while heap:
            entry = heapq.heappop(heap)
            vm_id = entry[-1]
            vm = self.vms[vm_id]
            if (
                not vm.alive
                or not vm.functions
                or len(vm.functions) >= self.max_functions_per_vm
                or entry[:-1] != self._heap_key(vm)
            ):
                continue  # stale or ineligible: the live entry is elsewhere
            if function_id in vm.functions or vm.mem_used_mb + need > vm.mem_mb:
                if vm_id not in seen:  # keep exactly one live entry per VM
                    seen.add(vm_id)
                    skipped.append(entry)
                continue
            winner = vm
            skipped.append(entry)  # picking does not mutate state: keep it live
            break
        for entry in skipped:
            heapq.heappush(heap, entry)
        if winner is not None:
            return winner
        return self.reserve_vm(now)

    def _seed_load(self, vm_id: str) -> int:
        """Total number of downstream children across all trees (outbound streams)."""
        return self._seed_loads.get(vm_id, 0)

    def _seed_load_recompute(self, vm_id: str) -> int:
        """Reference (tree-walking) seed load — used by restore and tests."""
        n = 0
        for fid in self.vms[vm_id].functions:
            ft = self.trees.get(fid)
            if ft is not None and vm_id in ft:
                n += len(ft.children_of(vm_id))
        return n

    # ------------------------------------------------------------------
    # Provisioning-wave locks (request serving: cold-start herd control)
    # ------------------------------------------------------------------
    def wave_active(self, function_id: str) -> bool:
        """True while a provisioning wave is in flight for the function."""
        return function_id in self.wave_locks

    def wave_open(self, function_id: str, n: int) -> None:
        """Open the per-function wave lock: ``n`` containers now in flight.

        The serving layer opens one lock per scale-out decision and parks
        all further scale-out for the function until every container of the
        wave has activated — the thundering-herd gate that turns a
        10k-request cold burst into exactly one wave.  The pending count is
        scheduler state and rides :meth:`snapshot`, so a restored scheduler
        keeps the herd parked until the surviving data-plane streams land.
        """
        if n <= 0:
            raise ValueError(f"wave for {function_id!r} needs n >= 1, got {n}")
        if function_id in self.wave_locks:
            raise RuntimeError(f"wave already in flight for {function_id!r}")
        self.wave_locks[function_id] = n
        self.stats["waves"] += 1

    def wave_landed(self, function_id: str) -> bool:
        """One wave container activated; True when the whole wave landed.

        A no-op (returns False) for functions without an open lock — e.g.
        containers provisioned by the naive per-deficit admission path.
        """
        pending = self.wave_locks.get(function_id)
        if pending is None:
            return False
        if pending <= 1:
            del self.wave_locks[function_id]
            return True
        self.wave_locks[function_id] = pending - 1
        return False

    # ------------------------------------------------------------------
    # Reclaim + failure handling (paper §3.2 delete, §3.3 fault tolerance)
    # ------------------------------------------------------------------
    def reclaim_instance(self, function_id: str, vm_id: str) -> bool:
        """Reclaim ONE function instance; release the VM once it empties.

        The single accounting path for every reclaim decision — the policy
        loop below and the trace replay both route through here, so the
        ``reclaims`` counter can never drift between them.  Returns True
        when the VM was returned to the free pool (no instances left).
        """
        self.delete(function_id, vm_id)
        self.stats["reclaims"] += 1
        vm = self.vms[vm_id]
        if not vm.functions and vm.alive:
            self.release_vm(vm_id)
            return True
        return False

    def reclaim_idle(self, now: float) -> list[str]:
        """Apply the reclaim policy to every idle instance (paper §3.2).

        Each ``(function, vm)`` instance ages independently — on a shared
        pool one VM hosts several tenants' instances and reclaiming one
        must not evict the others.  A VM with no instances left returns to
        the free pool; the list of such fully-released VM ids is returned.
        An instance's clock is its ``func_last_active`` entry (set at insert,
        refreshed by :meth:`touch_instance`); instances restored from legacy
        snapshots without per-instance records fall back to the VM-level
        ``last_active``.
        """
        released = []
        for vm in list(self.vms.values()):
            if not vm.alive or not vm.functions:
                continue
            freed = False
            for fid in sorted(vm.functions):  # deterministic eviction order
                last = vm.func_last_active.get(fid, vm.last_active)
                if self.reclaim.should_reclaim(fid, now - last, now):
                    freed = self.reclaim_instance(fid, vm.vm_id)
            if freed:
                released.append(vm.vm_id)
        return released

    def on_vm_failure(self, vm_id: str) -> list[str]:
        """Heartbeat miss: drop the VM from every tree it belongs to.

        Returns the list of function ids whose trees were repaired — the
        provisioning layer must restart the inbound streams of any node
        whose parent changed (it learns those via FunctionTree.on_reparent).
        """
        vm = self.vms[vm_id]
        vm.alive = False
        repaired = []
        for fid in list(vm.functions):
            self.delete(fid, vm_id)
            self.stats["repairs"] += 1
            repaired.append(fid)
        vm.functions.clear()
        vm.func_mem_mb.clear()
        vm.func_last_active.clear()
        vm.mem_used_mb = 0
        return repaired

    # ------------------------------------------------------------------
    # Telemetry
    # ------------------------------------------------------------------
    def tree_stats(self) -> dict[str, dict[str, int]]:
        """Per-function tree size/height — the scale harness's sanity report."""
        return {
            fid: {"size": len(ft), "height": ft.height}
            for fid, ft in self.trees.items()
        }

    # ------------------------------------------------------------------
    # Metadata-store sync (paper: scheduler shards sync with etcd)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-serializable full control-plane state (scheduler failover).

        Everything a stand-in scheduler shard needs to continue *bit-
        identically* is captured: tree topologies, per-VM records, the free
        pool in FIFO order, the VM registration order (``_vm_order`` is the
        placement tie-break, so it must survive the wire), per-VM memory
        occupancy (charged MB and last-active clock per instance — a shared
        pool restored without them would re-admit functions a live VM has no
        room for), the per-function memory requirements, the reclaim-policy
        state (a predictive policy's learned histograms must keep learning
        from where they stopped), and the telemetry counters (so
        reclaim/repair accounting stays continuous across the failover).
        ``repro.sim.multi_tenant`` round-trips this through ``json.dumps``
        mid-replay and proves the replay stream unchanged.
        """
        order = sorted(self._vm_order, key=self._vm_order.__getitem__)
        return {
            "trees": {fid: ft.to_dict() for fid, ft in self.trees.items()},
            "vms": {
                vid: {
                    "address": vm.address,
                    "port": vm.port,
                    "mem_mb": vm.mem_mb,
                    "functions": sorted(vm.functions),
                    "alive": vm.alive,
                    "last_active": vm.last_active,
                    "func_mem_mb": {
                        fid: vm.func_mem_mb[fid] for fid in sorted(vm.func_mem_mb)
                    },
                    "func_last_active": {
                        fid: vm.func_last_active[fid]
                        for fid in sorted(vm.func_last_active)
                    },
                }
                for vid, vm in self.vms.items()
            },
            "free_pool": list(self.free_pool),
            "vm_order": order,
            "stats": dict(self.stats),
            "function_mem": dict(sorted(self.function_mem.items())),
            "default_function_mem_mb": self.default_function_mem_mb,
            "reclaim": self.reclaim.snapshot(),
            # In-flight provisioning waves (cold-start herd control): a
            # restored scheduler must keep parked request herds parked.
            "wave_locks": {fid: self.wave_locks[fid] for fid in sorted(self.wave_locks)},
        }

    @classmethod
    def restore(cls, snap: dict, **kwargs) -> "FTManager":
        mgr = cls(**kwargs)
        # Legacy snapshots predate per-function memory and pluggable reclaim:
        # missing keys restore the pre-refactor defaults (zero charged
        # memory, fixed-TTL policy from the caller's kwargs).
        mgr.function_mem = {
            fid: int(m) for fid, m in snap.get("function_mem", {}).items()
        }
        mgr.default_function_mem_mb = snap.get(
            "default_function_mem_mb", mgr.default_function_mem_mb
        )
        # Only the snapshot's recorded policy overrides the ctor-built one:
        # a legacy snapshot (no "reclaim" key) restored with an explicit
        # reclaim= kwarg keeps the caller's requested policy.
        if "reclaim" in snap:
            mgr.reclaim = restore_reclaim_policy(
                snap["reclaim"], default_ttl_s=mgr.vm_idle_reclaim_s
            )
        # Legacy (pre-serving) snapshots carry no wave locks: none in flight.
        mgr.wave_locks = {
            fid: int(n) for fid, n in snap.get("wave_locks", {}).items()
        }
        # Registration order is authoritative when recorded; older snapshots
        # fall back to the (insertion-ordered) vms mapping itself.
        for vid in snap.get("vm_order", snap["vms"]):
            mgr._vm_order[vid] = len(mgr._vm_order)
        for vid, v in snap["vms"].items():
            func_mem = {fid: int(m) for fid, m in v.get("func_mem_mb", {}).items()}
            mgr.vms[vid] = VMInfo(
                vm_id=vid,
                address=v["address"],
                port=v["port"],
                mem_mb=v.get("mem_mb", 4096),
                functions=set(v["functions"]),
                last_active=v["last_active"],
                alive=v["alive"],
                func_mem_mb=func_mem,
                mem_used_mb=sum(func_mem.values()),
                func_last_active=dict(v.get("func_last_active", {})),
            )
            mgr._vm_order.setdefault(vid, len(mgr._vm_order))
        mgr.free_pool = deque(snap["free_pool"])
        mgr._free_ids = set(mgr.free_pool)
        mgr.stats.update(snap.get("stats", {}))
        from .function_tree import FunctionTree as FT

        for fid, d in snap["trees"].items():
            ft = FT.from_dict(d)
            ft.on_reparent.append(mgr._account_reparent)
            mgr.trees[fid] = ft
        for vid in mgr.vms:
            mgr._seed_loads[vid] = mgr._seed_load_recompute(vid)
            mgr._heap_push(vid)
        return mgr
