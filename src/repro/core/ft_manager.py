"""FT manager — the scheduler-side control plane (paper §3.3).

Responsibilities (all control-plane; no payload bytes flow through here):
  * one :class:`FunctionTree` per function id (``insert``/``delete`` API);
  * the VM pool: free pool → active pool reservation, idle reclaim after a
    configurable lifespan (15 min in Alibaba's production config), failure
    detection → tree repair;
  * function→VM placement with the ≤ ``max_functions_per_vm`` limit (20 in
    production) and the FT-aware placement refinement of paper §5 (prefer
    VMs that already appear in few trees / as leaves, to balance per-VM
    in/out bandwidth across overlapping FTs);
  * the ``<function_id, FT>`` metadata map, snapshottable to a dict for the
    etcd-style metadata-store sync the paper describes.

Placement is O(log V) amortized per decision: candidates live in a lazily
rebuilt min-heap keyed ``(load, seed_load, registration_index)`` (or
``(-load, registration_index)`` for the pure binpack mode) with stale
entries dropped on pop — a VM's entry is re-pushed whenever its key
changes, so the entry matching the current key is always present.
``seed_load`` (the VM's total outbound child streams across all trees) is
maintained incrementally from :attr:`FunctionTree.on_reparent` callbacks
plus the :class:`~repro.core.function_tree.DeleteInfo` record instead of
re-walking trees.  The tie-break by registration index reproduces the
original full-pool stable sort exactly, so placement decisions are
bit-identical to the O(V log V) implementation they replace.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .function_tree import FTNode, FunctionTree


@dataclass
class VMInfo:
    vm_id: str
    address: str = ""
    port: int = 0
    mem_mb: int = 4096
    functions: set[str] = field(default_factory=set)  # function ids placed here
    last_active: float = 0.0
    alive: bool = True

    def load(self) -> int:
        return len(self.functions)


class FTManager:
    """Per-function tree + VM pool manager embedded in the FaaS scheduler."""

    def __init__(
        self,
        *,
        max_functions_per_vm: int = 20,
        vm_idle_reclaim_s: float = 15 * 60.0,
        ft_aware_placement: bool = True,
    ) -> None:
        self.trees: dict[str, FunctionTree] = {}
        self.vms: dict[str, VMInfo] = {}
        self.free_pool: deque[str] = deque()
        self._free_ids: set[str] = set()  # guards release→reserve→release races
        self.max_functions_per_vm = max_functions_per_vm
        self.vm_idle_reclaim_s = vm_idle_reclaim_s
        self.ft_aware_placement = ft_aware_placement
        # Incremental placement state --------------------------------------
        self._seed_loads: dict[str, int] = {}  # vm_id -> Σ children over trees
        self._vm_order: dict[str, int] = {}  # registration index (sort tie-break)
        self._placement_heap: list[tuple] = []  # (key..., vm_id), lazily pruned
        # counters for tests / telemetry
        self.stats = {
            "inserts": 0,
            "deletes": 0,
            "repairs": 0,
            "reclaims": 0,
            "reservations": 0,
        }

    # ------------------------------------------------------------------
    # VM pool
    # ------------------------------------------------------------------
    def add_free_vm(self, vm: VMInfo) -> None:
        if vm.vm_id in self.vms:
            raise ValueError(f"vm {vm.vm_id!r} already registered")
        self.vms[vm.vm_id] = vm
        self._vm_order[vm.vm_id] = len(self._vm_order)
        self._seed_loads.setdefault(vm.vm_id, 0)
        self.free_pool.append(vm.vm_id)
        self._free_ids.add(vm.vm_id)

    def reserve_vm(self, now: float = 0.0) -> Optional[VMInfo]:
        """Move one VM from the free pool to active (scheduler scale-out)."""
        while self.free_pool:
            vm_id = self.free_pool.popleft()
            self._free_ids.discard(vm_id)
            vm = self.vms[vm_id]
            if vm.alive:
                vm.last_active = now
                self.stats["reservations"] += 1
                return vm
        return None

    def release_vm(self, vm_id: str) -> None:
        """Return an active VM (no functions left) to the free pool.

        Idempotent: a release→reserve→release churn loop (or a double
        release from two reclaim paths) never double-appends the id.
        """
        vm = self.vms[vm_id]
        assert not vm.functions, "cannot release a VM still holding functions"
        if vm.alive and vm_id not in self._free_ids:
            self.free_pool.append(vm_id)
            self._free_ids.add(vm_id)

    # ------------------------------------------------------------------
    # Tree membership (insert / delete drive everything else)
    # ------------------------------------------------------------------
    def tree(self, function_id: str) -> FunctionTree:
        if function_id not in self.trees:
            ft = FunctionTree(function_id)
            ft.on_reparent.append(self._account_reparent)
            self.trees[function_id] = ft
        return self.trees[function_id]

    def _account_reparent(
        self, node: FTNode, old_parent: Optional[FTNode], new_parent: Optional[FTNode]
    ) -> None:
        """Keep per-VM child-stream totals exact across rotations/splices."""
        if old_parent is not None:
            self._seed_load_add(old_parent.vm_id, -1)
        if new_parent is not None:
            self._seed_load_add(new_parent.vm_id, +1)

    def _seed_load_add(self, vm_id: str, delta: int) -> None:
        self._seed_loads[vm_id] = self._seed_loads.get(vm_id, 0) + delta
        self._heap_push(vm_id)

    def insert(self, function_id: str, vm_id: str, now: float = 0.0) -> str | None:
        """Add ``vm_id`` to the function's FT; returns the upstream peer id.

        Returns ``None`` when the new node is the root (it will fetch from
        the registry / backing store instead of a peer).
        """
        vm = self.vms[vm_id]
        if len(vm.functions) >= self.max_functions_per_vm:
            raise RuntimeError(
                f"placement limit: vm {vm_id} already holds "
                f"{len(vm.functions)} functions"
            )
        ft = self.tree(function_id)
        ft.insert(vm_id)
        vm.functions.add(function_id)
        vm.last_active = now
        self.stats["inserts"] += 1
        up = ft.parent_of(vm_id)
        if up is not None:
            self._seed_load_add(up, +1)  # attach is silent on on_reparent
        self._heap_push(vm_id)
        return up

    def bulk_insert(
        self, function_id: str, vm_ids: list[str], now: float = 0.0
    ) -> FunctionTree:
        """Insert many VMs into one function's FT (burst scale-out).

        Used by the scale harness (``repro.sim.scale``) to stand up the
        paper's §4.2 thousand-VM waves; semantically identical to calling
        :meth:`insert` in a loop, returns the tree for convenience.
        """
        for vm_id in vm_ids:
            self.insert(function_id, vm_id, now)
        return self.trees[function_id]

    def delete(self, function_id: str, vm_id: str) -> None:
        ft = self.trees[function_id]
        info = ft.delete(vm_id)
        # Silent structural changes (see FunctionTree.delete): the victim
        # leaves its parent, and — when a filler was promoted — the filler
        # leaves its own pre-unlink parent.  Rotations/splices already fired
        # on_reparent with exact (old, new) pairs.
        if info.parent is not None:
            self._seed_load_add(info.parent, -1)
        if info.filler is not None and info.filler_parent is not None:
            self._seed_load_add(info.filler_parent, -1)
        self.vms[vm_id].functions.discard(function_id)
        self._heap_push(vm_id)
        self.stats["deletes"] += 1
        if len(ft) == 0:
            del self.trees[function_id]

    # ------------------------------------------------------------------
    # Placement (paper §3.3 "Function Placement on VMs" + §5 FT-aware)
    # ------------------------------------------------------------------
    def _heap_key(self, vm: VMInfo) -> tuple:
        if self.ft_aware_placement:
            return (
                len(vm.functions),
                self._seed_loads.get(vm.vm_id, 0),
                self._vm_order[vm.vm_id],
            )
        return (-len(vm.functions), self._vm_order[vm.vm_id])  # binpack: fullest first

    def _heap_push(self, vm_id: str) -> None:
        vm = self.vms.get(vm_id)
        if vm is None or not vm.alive or not vm.functions:
            return  # never a placement candidate until its key next changes
        heapq.heappush(self._placement_heap, self._heap_key(vm) + (vm_id,))

    def _rebuild_heap(self) -> None:
        self._placement_heap = [
            self._heap_key(vm) + (vm.vm_id,)
            for vm in self.vms.values()
            if vm.alive and vm.functions
        ]
        heapq.heapify(self._placement_heap)

    def pick_vm_for(self, function_id: str, now: float = 0.0) -> Optional[VMInfo]:
        """Choose a host for a new instance of ``function_id``.

        Binpacking baseline: any active VM with spare function slots that
        does not already host this function.  FT-aware refinement (§5):
        prefer the VM currently involved in the fewest trees and, among
        those, one that is a leaf in most of its trees — leaves have zero
        outbound seeding load, so adding an inbound stream there balances
        bandwidth.  Falls back to reserving a free VM.

        Amortized O(log V): pops the lazily pruned candidate heap until an
        entry matches its VM's current key; entries skipped only because
        the VM already hosts ``function_id`` are pushed back afterwards.
        """
        if len(self._placement_heap) > max(64, 4 * len(self.vms)):
            self._rebuild_heap()  # mostly-stale heap: rebuild and re-amortize
        heap = self._placement_heap
        skipped: list[tuple] = []
        winner: Optional[VMInfo] = None
        seen: set[str] = set()
        while heap:
            entry = heapq.heappop(heap)
            vm_id = entry[-1]
            vm = self.vms[vm_id]
            if (
                not vm.alive
                or not vm.functions
                or len(vm.functions) >= self.max_functions_per_vm
                or entry[:-1] != self._heap_key(vm)
            ):
                continue  # stale or ineligible: the live entry is elsewhere
            if function_id in vm.functions:
                if vm_id not in seen:  # keep exactly one live entry per VM
                    seen.add(vm_id)
                    skipped.append(entry)
                continue
            winner = vm
            skipped.append(entry)  # picking does not mutate state: keep it live
            break
        for entry in skipped:
            heapq.heappush(heap, entry)
        if winner is not None:
            return winner
        return self.reserve_vm(now)

    def _seed_load(self, vm_id: str) -> int:
        """Total number of downstream children across all trees (outbound streams)."""
        return self._seed_loads.get(vm_id, 0)

    def _seed_load_recompute(self, vm_id: str) -> int:
        """Reference (tree-walking) seed load — used by restore and tests."""
        n = 0
        for fid in self.vms[vm_id].functions:
            ft = self.trees.get(fid)
            if ft is not None and vm_id in ft:
                n += len(ft.children_of(vm_id))
        return n

    # ------------------------------------------------------------------
    # Reclaim + failure handling (paper §3.2 delete, §3.3 fault tolerance)
    # ------------------------------------------------------------------
    def reclaim_idle(self, now: float) -> list[str]:
        """Reclaim VMs idle past the lifespan; their trees rebalance."""
        reclaimed = []
        for vm in list(self.vms.values()):
            if (
                vm.alive
                and vm.functions
                and now - vm.last_active >= self.vm_idle_reclaim_s
            ):
                for fid in list(vm.functions):
                    self.delete(fid, vm.vm_id)
                self.release_vm(vm.vm_id)
                self.stats["reclaims"] += 1
                reclaimed.append(vm.vm_id)
        return reclaimed

    def on_vm_failure(self, vm_id: str) -> list[str]:
        """Heartbeat miss: drop the VM from every tree it belongs to.

        Returns the list of function ids whose trees were repaired — the
        provisioning layer must restart the inbound streams of any node
        whose parent changed (it learns those via FunctionTree.on_reparent).
        """
        vm = self.vms[vm_id]
        vm.alive = False
        repaired = []
        for fid in list(vm.functions):
            self.delete(fid, vm_id)
            self.stats["repairs"] += 1
            repaired.append(fid)
        vm.functions.clear()
        return repaired

    # ------------------------------------------------------------------
    # Telemetry
    # ------------------------------------------------------------------
    def tree_stats(self) -> dict[str, dict[str, int]]:
        """Per-function tree size/height — the scale harness's sanity report."""
        return {
            fid: {"size": len(ft), "height": ft.height}
            for fid, ft in self.trees.items()
        }

    # ------------------------------------------------------------------
    # Metadata-store sync (paper: scheduler shards sync with etcd)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-serializable full control-plane state (scheduler failover).

        Everything a stand-in scheduler shard needs to continue *bit-
        identically* is captured: tree topologies, per-VM records, the free
        pool in FIFO order, the VM registration order (``_vm_order`` is the
        placement tie-break, so it must survive the wire), and the telemetry
        counters (so reclaim/repair accounting stays continuous across the
        failover).  ``repro.sim.multi_tenant`` round-trips this through
        ``json.dumps`` mid-replay and proves the replay stream unchanged.
        """
        order = sorted(self._vm_order, key=self._vm_order.__getitem__)
        return {
            "trees": {fid: ft.to_dict() for fid, ft in self.trees.items()},
            "vms": {
                vid: {
                    "address": vm.address,
                    "port": vm.port,
                    "mem_mb": vm.mem_mb,
                    "functions": sorted(vm.functions),
                    "alive": vm.alive,
                    "last_active": vm.last_active,
                }
                for vid, vm in self.vms.items()
            },
            "free_pool": list(self.free_pool),
            "vm_order": order,
            "stats": dict(self.stats),
        }

    @classmethod
    def restore(cls, snap: dict, **kwargs) -> "FTManager":
        mgr = cls(**kwargs)
        # Registration order is authoritative when recorded; older snapshots
        # fall back to the (insertion-ordered) vms mapping itself.
        for vid in snap.get("vm_order", snap["vms"]):
            mgr._vm_order[vid] = len(mgr._vm_order)
        for vid, v in snap["vms"].items():
            mgr.vms[vid] = VMInfo(
                vm_id=vid,
                address=v["address"],
                port=v["port"],
                mem_mb=v.get("mem_mb", 4096),
                functions=set(v["functions"]),
                last_active=v["last_active"],
                alive=v["alive"],
            )
            mgr._vm_order.setdefault(vid, len(mgr._vm_order))
        mgr.free_pool = deque(snap["free_pool"])
        mgr._free_ids = set(mgr.free_pool)
        mgr.stats.update(snap.get("stats", {}))
        from .function_tree import FunctionTree as FT

        for fid, d in snap["trees"].items():
            ft = FT.from_dict(d)
            ft.on_reparent.append(mgr._account_reparent)
            mgr.trees[fid] = ft
        for vid in mgr.vms:
            mgr._seed_loads[vid] = mgr._seed_load_recompute(vid)
            mgr._heap_push(vid)
        return mgr
