"""FaaSNet core: function trees, FT manager, block store, topologies, protocol.

The blockstore symbols are re-exported lazily (PEP 562) so that importing
``repro.core`` — which every control-plane and simulator module does — never
drags in the compression stack.  ``repro.core.blockstore`` itself degrades
gracefully to a zlib codec when ``zstandard`` is missing, but keeping the
import lazy means a bare interpreter pays nothing unless it actually touches
blockstore functionality.
"""
from .ft_manager import FTManager, VMInfo
from .function_tree import FTNode, FunctionTree
from .provisioning import ProvisionState, ProvisionTask, RPCCosts
from .reclaim import (
    RECLAIM_POLICIES,
    FixedTTLReclaim,
    HistogramReclaim,
    ReclaimPolicy,
    resolve_reclaim_policy,
)
from .registry import (
    PLACEMENT_POLICIES,
    RegistrySpec,
    ShardResolver,
    is_registry_node,
    shard_index,
)
from .topology import (
    REGISTRY,
    DistributionPlan,
    Flow,
    baseline_block_plan,
    baseline_plan,
    dadi_plan,
    faasnet_block_plan,
    faasnet_plan,
    kraken_plan,
    on_demand_block_plan,
    on_demand_plan,
)

_BLOCKSTORE_EXPORTS = (
    "DEFAULT_BLOCK_SIZE",
    "BlockManifest",
    "BlockReader",
    "ReadStats",
    "read_manifest",
    "write_blockstore",
)

# Image/block model symbols are lazy for the same reason as the blockstore:
# ``repro.core.image`` imports the blockstore for its manifest geometry.
_IMAGE_EXPORTS = (
    "BlockCache",
    "ImageSpec",
    "LayerSpec",
    "disjoint_images",
    "shared_base_images",
)


def __getattr__(name: str):
    if name in _BLOCKSTORE_EXPORTS:
        from . import blockstore

        return getattr(blockstore, name)
    if name in _IMAGE_EXPORTS:
        from . import image

        return getattr(image, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__() -> list[str]:
    return sorted(
        list(globals()) + list(_BLOCKSTORE_EXPORTS) + list(_IMAGE_EXPORTS)
    )


__all__ = [
    "DEFAULT_BLOCK_SIZE",
    "BlockManifest",
    "BlockReader",
    "ReadStats",
    "read_manifest",
    "write_blockstore",
    "FTManager",
    "VMInfo",
    "FTNode",
    "FunctionTree",
    "ProvisionState",
    "ProvisionTask",
    "RPCCosts",
    "RECLAIM_POLICIES",
    "ReclaimPolicy",
    "FixedTTLReclaim",
    "HistogramReclaim",
    "resolve_reclaim_policy",
    "REGISTRY",
    "PLACEMENT_POLICIES",
    "RegistrySpec",
    "ShardResolver",
    "is_registry_node",
    "shard_index",
    "DistributionPlan",
    "Flow",
    "baseline_plan",
    "baseline_block_plan",
    "dadi_plan",
    "faasnet_plan",
    "faasnet_block_plan",
    "kraken_plan",
    "on_demand_plan",
    "on_demand_block_plan",
    "BlockCache",
    "ImageSpec",
    "LayerSpec",
    "disjoint_images",
    "shared_base_images",
]
