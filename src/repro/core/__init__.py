"""FaaSNet core: function trees, FT manager, block store, topologies, protocol."""
from .blockstore import (
    DEFAULT_BLOCK_SIZE,
    BlockManifest,
    BlockReader,
    ReadStats,
    read_manifest,
    write_blockstore,
)
from .ft_manager import FTManager, VMInfo
from .function_tree import FTNode, FunctionTree
from .provisioning import ProvisionState, ProvisionTask, RPCCosts
from .topology import (
    REGISTRY,
    DistributionPlan,
    Flow,
    baseline_plan,
    dadi_plan,
    faasnet_plan,
    kraken_plan,
    on_demand_plan,
)

__all__ = [
    "DEFAULT_BLOCK_SIZE",
    "BlockManifest",
    "BlockReader",
    "ReadStats",
    "read_manifest",
    "write_blockstore",
    "FTManager",
    "VMInfo",
    "FTNode",
    "FunctionTree",
    "ProvisionState",
    "ProvisionTask",
    "RPCCosts",
    "REGISTRY",
    "DistributionPlan",
    "Flow",
    "baseline_plan",
    "dadi_plan",
    "faasnet_plan",
    "kraken_plan",
    "on_demand_plan",
]
