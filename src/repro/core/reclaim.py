"""Pluggable VM/instance reclaim policies (paper §3.2 + ROADMAP follow-on).

The paper reclaims a VM after a fixed idle lifespan (15 min in Alibaba's
production config).  Trace-driven serverless work since then — Tomaras et
al., 2024 ("Prediction-driven resource provisioning for serverless container
runtimes"), and the keep-alive histograms of Shahrad et al. ("Serverless in
the Wild") — shows that a per-function *predicted* keep-alive reclaims dead
tenants quickly while keeping bursty ones warm.  This module makes the
policy pluggable so the multi-tenant harness can compare both on one trace
mix:

  * :class:`FixedTTLReclaim` — the paper's fixed idle lifespan (default);
  * :class:`HistogramReclaim` — a per-function idle-gap histogram whose
    keep-alive is a high quantile of the observed gaps (clamped to
    ``[min_ttl_s, max_ttl_s]``, falling back to the fixed TTL until enough
    gaps have been seen).

Policies are evaluated **per function-instance** (a ``(function, vm)``
pair), not per VM — on a shared pool one VM hosts many tenants' instances
and each ages independently.  All policy state is deterministic and
JSON-serializable: it rides the scheduler-failover snapshot
(:meth:`repro.core.ft_manager.FTManager.snapshot`) so a restored scheduler
makes bit-identical reclaim decisions.
"""
from __future__ import annotations

__all__ = [
    "RECLAIM_POLICIES",
    "ReclaimPolicy",
    "FixedTTLReclaim",
    "HistogramReclaim",
    "resolve_reclaim_policy",
    "restore_reclaim_policy",
]

# Config-level shorthand names accepted by resolve_reclaim_policy (the
# authoritative list for CLI ``choices=`` — mirrors registry's
# PLACEMENT_POLICIES and sim's PLACEMENTS).
RECLAIM_POLICIES = ("fixed", "histogram")


class ReclaimPolicy:
    """Decides when an idle function instance should be reclaimed.

    Subclasses are auto-registered by their ``name`` so snapshots restore
    polymorphically; a custom policy must override :meth:`from_snapshot`
    (and :meth:`snapshot`) to survive a scheduler failover — the base
    implementation raises with that instruction rather than silently
    degrading to a built-in policy.
    """

    name = "base"
    _registry: dict[str, type["ReclaimPolicy"]] = {}

    def __init_subclass__(cls, **kwargs) -> None:
        super().__init_subclass__(**kwargs)
        name = cls.__dict__.get("name")
        if name:
            ReclaimPolicy._registry[name] = cls

    def should_reclaim(self, function_id: str, idle_s: float, now: float) -> bool:
        raise NotImplementedError

    def observe_gap(self, function_id: str, gap_s: float) -> None:
        """An instance of ``function_id`` was reused after ``gap_s`` idle.

        Predictive policies learn from this; the fixed policy ignores it.
        """

    # -- failover ------------------------------------------------------
    def snapshot(self) -> dict:
        return {"policy": self.name}

    @classmethod
    def from_snapshot(cls, blob: dict, *, default_ttl_s: float) -> "ReclaimPolicy":
        raise ValueError(
            f"reclaim policy {cls.name!r} does not implement from_snapshot; "
            f"custom policies must override snapshot()/from_snapshot() to "
            f"survive scheduler failover"
        )


class FixedTTLReclaim(ReclaimPolicy):
    """The paper's fixed idle lifespan: reclaim after ``ttl_s`` idle."""

    name = "fixed_ttl"

    def __init__(self, ttl_s: float = 15 * 60.0) -> None:
        self.ttl_s = float(ttl_s)

    def should_reclaim(self, function_id: str, idle_s: float, now: float) -> bool:
        return idle_s >= self.ttl_s

    def snapshot(self) -> dict:
        return {"policy": self.name, "ttl_s": self.ttl_s}

    @classmethod
    def from_snapshot(cls, blob: dict, *, default_ttl_s: float) -> "FixedTTLReclaim":
        return cls(blob.get("ttl_s", default_ttl_s))


class HistogramReclaim(ReclaimPolicy):
    """Keep-alive from a per-function idle-gap histogram.

    Gaps (idle time before an instance is reused) are bucketed at
    ``bucket_s`` resolution up to ``max_ttl_s``.  Once ``min_observations``
    gaps have been seen for a function, its keep-alive becomes the
    ``quantile`` of the histogram plus one safety bucket, clamped to
    ``[min_ttl_s, max_ttl_s]``; before that the policy behaves like the
    fixed ``default_ttl_s`` lifespan.  Functions whose instances are never
    reused (dead tenants) therefore learn nothing and fall back to the
    default — exactly the paper's behaviour — while bursty tenants with
    short observed gaps get reclaimed within a couple of buckets of their
    real reuse pattern.
    """

    name = "histogram"

    def __init__(
        self,
        default_ttl_s: float = 15 * 60.0,
        *,
        bucket_s: float = 15.0,
        min_ttl_s: float = 60.0,
        max_ttl_s: float | None = None,
        quantile: float = 0.99,
        min_observations: int = 12,
    ) -> None:
        if bucket_s <= 0:
            raise ValueError("bucket_s must be positive")
        if not 0.0 < quantile <= 1.0:
            raise ValueError("quantile must be in (0, 1]")
        self.default_ttl_s = float(default_ttl_s)
        self.bucket_s = float(bucket_s)
        self.min_ttl_s = float(min_ttl_s)
        self.max_ttl_s = float(max_ttl_s if max_ttl_s is not None else default_ttl_s)
        self.quantile = float(quantile)
        self.min_observations = int(min_observations)
        self.n_buckets = max(1, int(self.max_ttl_s / self.bucket_s)) + 1
        self.counts: dict[str, list[int]] = {}  # function_id -> bucket counts
        self.totals: dict[str, int] = {}  # function_id -> Σ counts (cached)
        # Learned-TTL memo: should_reclaim runs once per idle instance per
        # tick, but the quantile only moves on observe_gap — derived state,
        # never snapshotted.
        self._ttl_cache: dict[str, float] = {}

    def observe_gap(self, function_id: str, gap_s: float) -> None:
        if gap_s < 0:
            return
        b = min(int(gap_s / self.bucket_s), self.n_buckets - 1)
        hist = self.counts.get(function_id)
        if hist is None:
            hist = self.counts[function_id] = [0] * self.n_buckets
        hist[b] += 1
        self.totals[function_id] = self.totals.get(function_id, 0) + 1
        self._ttl_cache.pop(function_id, None)

    def keep_alive_s(self, function_id: str) -> float:
        """The learned keep-alive for one function (default until warmed up)."""
        cached = self._ttl_cache.get(function_id)
        if cached is not None:
            return cached
        total = self.totals.get(function_id, 0)
        if total < self.min_observations:
            ttl = self.default_ttl_s
        else:
            hist = self.counts[function_id]
            want = self.quantile * total
            acc = 0
            ttl = self.max_ttl_s
            for b, n in enumerate(hist):
                acc += n
                if acc >= want:
                    # one safety bucket past the quantile bucket's upper edge
                    ttl = min(self.max_ttl_s, max(self.min_ttl_s, (b + 2) * self.bucket_s))
                    break
        self._ttl_cache[function_id] = ttl
        return ttl

    def should_reclaim(self, function_id: str, idle_s: float, now: float) -> bool:
        return idle_s >= self.keep_alive_s(function_id)

    def snapshot(self) -> dict:
        return {
            "policy": self.name,
            "default_ttl_s": self.default_ttl_s,
            "bucket_s": self.bucket_s,
            "min_ttl_s": self.min_ttl_s,
            "max_ttl_s": self.max_ttl_s,
            "quantile": self.quantile,
            "min_observations": self.min_observations,
            "counts": {fid: list(h) for fid, h in sorted(self.counts.items())},
        }

    @classmethod
    def from_snapshot(cls, blob: dict, *, default_ttl_s: float) -> "HistogramReclaim":
        pol = cls(
            blob.get("default_ttl_s", default_ttl_s),
            bucket_s=blob.get("bucket_s", 15.0),
            min_ttl_s=blob.get("min_ttl_s", 60.0),
            max_ttl_s=blob.get("max_ttl_s"),
            quantile=blob.get("quantile", 0.99),
            min_observations=blob.get("min_observations", 12),
        )
        for fid, hist in blob.get("counts", {}).items():
            h = [int(n) for n in hist]
            # snapshots from a config with a different bucket count restore
            # by truncation/padding into the last (overflow) bucket
            if len(h) > pol.n_buckets:
                h = h[: pol.n_buckets - 1] + [sum(h[pol.n_buckets - 1 :])]
            elif len(h) < pol.n_buckets:
                h = h + [0] * (pol.n_buckets - len(h))
            pol.counts[fid] = h
            pol.totals[fid] = sum(h)
        return pol


def resolve_reclaim_policy(
    policy: "str | ReclaimPolicy | None", *, default_ttl_s: float
) -> ReclaimPolicy:
    """Config-level shorthand: ``"fixed"`` / ``"histogram"`` / an instance."""
    if policy is None or policy == "fixed" or policy == FixedTTLReclaim.name:
        return FixedTTLReclaim(default_ttl_s)
    if policy == HistogramReclaim.name:
        return HistogramReclaim(default_ttl_s)
    if isinstance(policy, ReclaimPolicy):
        return policy
    raise ValueError(
        f"unknown reclaim policy {policy!r}; one of {RECLAIM_POLICIES} "
        f"or a ReclaimPolicy instance"
    )


def restore_reclaim_policy(blob: "dict | None", *, default_ttl_s: float) -> ReclaimPolicy:
    """Rebuild a policy from :meth:`ReclaimPolicy.snapshot` output.

    Dispatches through the subclass registry keyed by ``policy`` name, so
    custom :class:`ReclaimPolicy` subclasses restore polymorphically (they
    must implement :meth:`ReclaimPolicy.from_snapshot`).  ``None`` (legacy
    snapshots that predate pluggable reclaim) restores the fixed policy
    built from the caller's TTL — the pre-refactor behaviour.
    """
    if blob is None:
        return FixedTTLReclaim(default_ttl_s)
    kind = blob.get("policy", FixedTTLReclaim.name)
    cls = ReclaimPolicy._registry.get(kind)
    if cls is None:
        raise ValueError(f"unknown reclaim policy in snapshot: {kind!r}")
    return cls.from_snapshot(blob, default_ttl_s=default_ttl_s)
