"""I/O-efficient block format with on-demand fetch (paper §3.5).

Original payload (a container image in the paper; a checkpoint shard / code
package here) is split into fixed-size blocks, each compressed *separately*,
and written back-to-back.  An offset table records where each compressed
block begins, so a reader can satisfy an arbitrary ``(offset, length)``
range request by touching only ``ceil`` of the covering blocks — the
on-demand I/O mechanism.  Reads must align to block boundaries, which
causes bounded *read amplification* at the two ends of the range (paper
§4.6); :meth:`BlockReader.read_range` reports both useful and fetched bytes
so benchmarks can reproduce Figure 20.

Layout of a blockstore file::

    [magic u32][version u32][block_size u64][n_blocks u64][raw_size u64]
    [offset table: (n_blocks + 1) * u64]          # offsets into data area
    [compressed block 0][compressed block 1]...

Compression codec: zstd when the ``zstandard`` package is available (the
paper's production choice), with a pure-stdlib ``zlib`` fallback so the
format — and everything layered on it — works on a bare interpreter.  The
codec is encoded in the header ``version`` field (1 = zstd, 2 = zlib), so
readers always know how a file was written; reading a zstd file without
``zstandard`` installed raises a clear error instead of corrupt output.

The format is used by three layers:
  * ``checkpoint/`` — every checkpoint shard is a blockstore file;
  * ``core/provisioning.py`` / ``sim/`` — the unit streamed down an FT edge
    is one (compressed) block;
  * code-package distribution (paper §4.5) — same format, same path.
"""
from __future__ import annotations

import io
import os
import struct
import zlib
from dataclasses import dataclass, field

try:  # optional: zstd is the production codec, zlib the stdlib fallback
    import zstandard as _zstd
except ImportError:  # pragma: no cover - exercised on bare interpreters
    _zstd = None

MAGIC = 0xFAA5_0001
# Header ``version`` doubles as the codec id so old files stay readable.
VERSION_ZSTD = 1
VERSION_ZLIB = 2
VERSION = VERSION_ZSTD  # kept for backwards compatibility of the constant
DEFAULT_BLOCK_SIZE = 512 * 1024  # paper's production setting (512 KB)

_CODEC_BY_VERSION = {VERSION_ZSTD: "zstd", VERSION_ZLIB: "zlib"}
_VERSION_BY_CODEC = {v: k for k, v in _CODEC_BY_VERSION.items()}

_HEADER = struct.Struct("<IIQQQ")


def have_zstd() -> bool:
    return _zstd is not None


def default_codec() -> str:
    return "zstd" if _zstd is not None else "zlib"


class _ZstdCodec:
    name = "zstd"

    def __init__(self, level: int = 3) -> None:
        if _zstd is None:
            raise RuntimeError(
                "file requires the 'zstandard' package (codec zstd), which is "
                "not installed; re-write the payload with codec='zlib'"
            )
        self._c = _zstd.ZstdCompressor(level=level)
        self._d = _zstd.ZstdDecompressor()

    def compress(self, data: bytes) -> bytes:
        return self._c.compress(data)

    def decompress(self, data: bytes, raw_size: int) -> bytes:
        return self._d.decompress(data, max_output_size=raw_size)


class _ZlibCodec:
    name = "zlib"

    def __init__(self, level: int = 6) -> None:
        self._level = min(max(level, 0), 9)

    def compress(self, data: bytes) -> bytes:
        return zlib.compress(data, self._level)

    def decompress(self, data: bytes, raw_size: int) -> bytes:
        out = zlib.decompress(data, bufsize=max(raw_size, 1))
        if len(out) > raw_size:
            raise ValueError(f"block decompressed to {len(out)} > {raw_size} bytes")
        return out


def _make_codec(name: str, level: int | None = None):
    """Build a codec; ``level=None`` means the codec's own default (zstd 3 / zlib 6)."""
    if name == "zstd":
        return _ZstdCodec() if level is None else _ZstdCodec(level)
    if name == "zlib":
        return _ZlibCodec() if level is None else _ZlibCodec(level)
    raise ValueError(f"unknown blockstore codec {name!r}")


@dataclass(frozen=True)
class BlockManifest:
    """The metadata-store entry for one payload (paper: the image manifest).

    The manifest is what a worker downloads first (provisioning protocol
    step 2): it is tiny, and from it the worker derives exactly which blocks
    any byte range needs.
    """

    block_size: int
    n_blocks: int
    raw_size: int
    offsets: tuple[int, ...]  # n_blocks + 1 entries into the data area
    codec: str = field(default="zstd", compare=False)

    def compressed_size(self) -> int:
        return self.offsets[-1]

    def block_range_for(self, offset: int, length: int) -> tuple[int, int]:
        """[first, last] block indices covering raw range [offset, offset+length)."""
        if length <= 0:
            return (0, -1)
        first = offset // self.block_size
        last = (offset + length - 1) // self.block_size
        return first, min(last, self.n_blocks - 1)

    def block_compressed_size(self, i: int) -> int:
        return self.offsets[i + 1] - self.offsets[i]

    def block_raw_size(self, i: int) -> int:
        if i < self.n_blocks - 1:
            return self.block_size
        rem = self.raw_size - self.block_size * (self.n_blocks - 1)
        return rem

    def to_dict(self) -> dict:
        return {
            "block_size": self.block_size,
            "n_blocks": self.n_blocks,
            "raw_size": self.raw_size,
            "offsets": list(self.offsets),
            "codec": self.codec,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "BlockManifest":
        return cls(
            d["block_size"],
            d["n_blocks"],
            d["raw_size"],
            tuple(d["offsets"]),
            d.get("codec", "zstd"),
        )


def write_blockstore(
    payload: bytes,
    path: str,
    *,
    block_size: int = DEFAULT_BLOCK_SIZE,
    level: int | None = None,
    codec: str | None = None,
) -> BlockManifest:
    """Convert ``payload`` into the I/O-efficient format (gateway's job, §3.1).

    ``codec`` defaults to zstd when available, else the stdlib zlib fallback.
    ``level=None`` uses the selected codec's own default (zstd 3, zlib 6) —
    a pinned numeric level applies verbatim to whichever codec is chosen.
    """
    codec = codec or default_codec()
    cctx = _make_codec(codec, level)
    n_blocks = max(1, -(-len(payload) // block_size))
    blocks = [
        cctx.compress(payload[i * block_size : (i + 1) * block_size])
        for i in range(n_blocks)
    ]
    offsets = [0]
    for b in blocks:
        offsets.append(offsets[-1] + len(b))
    manifest = BlockManifest(block_size, n_blocks, len(payload), tuple(offsets), codec)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(
            _HEADER.pack(
                MAGIC, _VERSION_BY_CODEC[codec], block_size, n_blocks, len(payload)
            )
        )
        f.write(struct.pack(f"<{n_blocks + 1}Q", *offsets))
        for b in blocks:
            f.write(b)
    os.replace(tmp, path)  # atomic publish (crash-safe checkpointing relies on it)
    return manifest


def read_manifest(path: str) -> BlockManifest:
    with open(path, "rb") as f:
        magic, version, block_size, n_blocks, raw_size = _HEADER.unpack(
            f.read(_HEADER.size)
        )
        if magic != MAGIC:
            raise ValueError(f"{path}: not a blockstore file (magic {magic:#x})")
        if version not in _CODEC_BY_VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        offsets = struct.unpack(f"<{n_blocks + 1}Q", f.read(8 * (n_blocks + 1)))
    return BlockManifest(
        block_size, n_blocks, raw_size, tuple(offsets), _CODEC_BY_VERSION[version]
    )


@dataclass
class ReadStats:
    """Accounting for the read-amplification analysis (paper Fig. 20)."""

    useful_bytes: int = 0  # bytes the caller asked for
    fetched_compressed: int = 0  # compressed bytes moved over the "network"
    fetched_raw: int = 0  # raw bytes materialized after decompression
    blocks_fetched: int = 0

    def amplification(self) -> float:
        return self.fetched_raw / self.useful_bytes if self.useful_bytes else 0.0


class BlockReader:
    """On-demand reader over a blockstore file with a block cache.

    Models the FaaSNet worker's lazy fetch: a range read touches only the
    covering blocks; previously fetched blocks are served from cache (the
    worker's local storage) without re-counting network bytes.

    I/O discipline: one persistent file handle for the reader's lifetime
    (use :meth:`close` or the context-manager protocol), and
    :meth:`read_range` coalesces runs of contiguous uncached blocks into a
    single seek+read — the compressed blocks are back-to-back on disk, so a
    cold sequential range costs one syscall instead of one per block.
    ``stats`` accounting is unchanged: the same per-block useful/fetched
    byte and block counts as the one-read-per-block implementation.
    """

    def __init__(self, path: str, manifest: BlockManifest | None = None) -> None:
        self.path = path
        self.manifest = manifest or read_manifest(path)
        self._data_start = _HEADER.size + 8 * (self.manifest.n_blocks + 1)
        self._cache: dict[int, bytes] = {}
        self._codec = _make_codec(self.manifest.codec)  # decompress side: level moot
        self.stats = ReadStats()
        self._f = open(path, "rb")
        self.file_reads = 0  # seek+read syscall pairs issued (coalescing telemetry)

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "BlockReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass

    def _read_at(self, data_offset: int, size: int) -> bytes:
        if self._f is None:
            raise ValueError(f"BlockReader for {self.path} is closed")
        self._f.seek(self._data_start + data_offset)
        self.file_reads += 1
        return self._f.read(size)

    # -- block-level -----------------------------------------------------
    def fetch_block_compressed(self, i: int) -> bytes:
        """Raw compressed block i — the unit streamed down FT edges."""
        m = self.manifest
        return self._read_at(m.offsets[i], m.block_compressed_size(i))

    def _ingest(self, i: int, comp: bytes) -> bytes:
        """Decompress + cache block ``i`` and account for the network fetch."""
        raw = self._codec.decompress(comp, self.manifest.block_raw_size(i))
        self._cache[i] = raw
        self.stats.blocks_fetched += 1
        self.stats.fetched_compressed += len(comp)
        self.stats.fetched_raw += len(raw)
        return raw

    def get_block(self, i: int) -> bytes:
        if i in self._cache:
            return self._cache[i]
        return self._ingest(i, self.fetch_block_compressed(i))

    def _fetch_run(self, first: int, last: int) -> None:
        """Fetch uncached blocks [first, last] with one read per contiguous run."""
        m = self.manifest
        i = first
        while i <= last:
            if i in self._cache:
                i += 1
                continue
            j = i
            while j + 1 <= last and (j + 1) not in self._cache:
                j += 1
            span = self._read_at(m.offsets[i], m.offsets[j + 1] - m.offsets[i])
            base = m.offsets[i]
            for k in range(i, j + 1):
                self._ingest(k, span[m.offsets[k] - base : m.offsets[k + 1] - base])
            i = j + 1

    # -- range-level (on-demand I/O) --------------------------------------
    def read_range(self, offset: int, length: int) -> bytes:
        m = self.manifest
        if length < 0:
            raise ValueError(f"negative read length {length}")
        if offset < 0 or offset + length > m.raw_size:
            raise ValueError(
                f"range [{offset}, {offset + length}) outside payload of {m.raw_size}"
            )
        self.stats.useful_bytes += length
        first, last = m.block_range_for(offset, length)
        if first <= last:
            self._fetch_run(first, last)
        out = io.BytesIO()
        for i in range(first, last + 1):
            raw = self.get_block(i)
            lo = max(0, offset - i * m.block_size)
            hi = min(len(raw), offset + length - i * m.block_size)
            out.write(raw[lo:hi])
        return out.getvalue()

    def read_all(self) -> bytes:
        return self.read_range(0, self.manifest.raw_size)
