"""I/O-efficient block format with on-demand fetch (paper §3.5).

Original payload (a container image in the paper; a checkpoint shard / code
package here) is split into fixed-size blocks, each compressed *separately*
with zstd, and written back-to-back.  An offset table records where each
compressed block begins, so a reader can satisfy an arbitrary ``(offset,
length)`` range request by touching only ``ceil`` of the covering blocks —
the on-demand I/O mechanism.  Reads must align to block boundaries, which
causes bounded *read amplification* at the two ends of the range (paper
§4.6); :meth:`BlockReader.read_range` reports both useful and fetched bytes
so benchmarks can reproduce Figure 20.

Layout of a blockstore file::

    [magic u32][version u32][block_size u64][n_blocks u64][raw_size u64]
    [offset table: (n_blocks + 1) * u64]          # offsets into data area
    [compressed block 0][compressed block 1]...

The format is used by three layers:
  * ``checkpoint/`` — every checkpoint shard is a blockstore file;
  * ``core/provisioning.py`` / ``sim/`` — the unit streamed down an FT edge
    is one (compressed) block;
  * code-package distribution (paper §4.5) — same format, same path.
"""
from __future__ import annotations

import io
import os
import struct
from dataclasses import dataclass

import zstandard as zstd

MAGIC = 0xFAA5_0001
VERSION = 1
DEFAULT_BLOCK_SIZE = 512 * 1024  # paper's production setting (512 KB)

_HEADER = struct.Struct("<IIQQQ")


@dataclass(frozen=True)
class BlockManifest:
    """The metadata-store entry for one payload (paper: the image manifest).

    The manifest is what a worker downloads first (provisioning protocol
    step 2): it is tiny, and from it the worker derives exactly which blocks
    any byte range needs.
    """

    block_size: int
    n_blocks: int
    raw_size: int
    offsets: tuple[int, ...]  # n_blocks + 1 entries into the data area

    def compressed_size(self) -> int:
        return self.offsets[-1]

    def block_range_for(self, offset: int, length: int) -> tuple[int, int]:
        """[first, last] block indices covering raw range [offset, offset+length)."""
        if length <= 0:
            return (0, -1)
        first = offset // self.block_size
        last = (offset + length - 1) // self.block_size
        return first, min(last, self.n_blocks - 1)

    def block_compressed_size(self, i: int) -> int:
        return self.offsets[i + 1] - self.offsets[i]

    def block_raw_size(self, i: int) -> int:
        if i < self.n_blocks - 1:
            return self.block_size
        rem = self.raw_size - self.block_size * (self.n_blocks - 1)
        return rem

    def to_dict(self) -> dict:
        return {
            "block_size": self.block_size,
            "n_blocks": self.n_blocks,
            "raw_size": self.raw_size,
            "offsets": list(self.offsets),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "BlockManifest":
        return cls(d["block_size"], d["n_blocks"], d["raw_size"], tuple(d["offsets"]))


def write_blockstore(
    payload: bytes,
    path: str,
    *,
    block_size: int = DEFAULT_BLOCK_SIZE,
    level: int = 3,
) -> BlockManifest:
    """Convert ``payload`` into the I/O-efficient format (gateway's job, §3.1)."""
    cctx = zstd.ZstdCompressor(level=level)
    n_blocks = max(1, -(-len(payload) // block_size))
    blocks = [
        cctx.compress(payload[i * block_size : (i + 1) * block_size])
        for i in range(n_blocks)
    ]
    offsets = [0]
    for b in blocks:
        offsets.append(offsets[-1] + len(b))
    manifest = BlockManifest(block_size, n_blocks, len(payload), tuple(offsets))
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_HEADER.pack(MAGIC, VERSION, block_size, n_blocks, len(payload)))
        f.write(struct.pack(f"<{n_blocks + 1}Q", *offsets))
        for b in blocks:
            f.write(b)
    os.replace(tmp, path)  # atomic publish (crash-safe checkpointing relies on it)
    return manifest


def read_manifest(path: str) -> BlockManifest:
    with open(path, "rb") as f:
        magic, version, block_size, n_blocks, raw_size = _HEADER.unpack(
            f.read(_HEADER.size)
        )
        if magic != MAGIC:
            raise ValueError(f"{path}: not a blockstore file (magic {magic:#x})")
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        offsets = struct.unpack(f"<{n_blocks + 1}Q", f.read(8 * (n_blocks + 1)))
    return BlockManifest(block_size, n_blocks, raw_size, tuple(offsets))


@dataclass
class ReadStats:
    """Accounting for the read-amplification analysis (paper Fig. 20)."""

    useful_bytes: int = 0  # bytes the caller asked for
    fetched_compressed: int = 0  # compressed bytes moved over the "network"
    fetched_raw: int = 0  # raw bytes materialized after decompression
    blocks_fetched: int = 0

    def amplification(self) -> float:
        return self.fetched_raw / self.useful_bytes if self.useful_bytes else 0.0


class BlockReader:
    """On-demand reader over a blockstore file with a block cache.

    Models the FaaSNet worker's lazy fetch: a range read touches only the
    covering blocks; previously fetched blocks are served from cache (the
    worker's local storage) without re-counting network bytes.
    """

    def __init__(self, path: str, manifest: BlockManifest | None = None) -> None:
        self.path = path
        self.manifest = manifest or read_manifest(path)
        self._data_start = _HEADER.size + 8 * (self.manifest.n_blocks + 1)
        self._cache: dict[int, bytes] = {}
        self._dctx = zstd.ZstdDecompressor()
        self.stats = ReadStats()

    # -- block-level -----------------------------------------------------
    def fetch_block_compressed(self, i: int) -> bytes:
        """Raw compressed block i — the unit streamed down FT edges."""
        m = self.manifest
        with open(self.path, "rb") as f:
            f.seek(self._data_start + m.offsets[i])
            return f.read(m.block_compressed_size(i))

    def get_block(self, i: int) -> bytes:
        if i in self._cache:
            return self._cache[i]
        comp = self.fetch_block_compressed(i)
        raw = self._dctx.decompress(
            comp, max_output_size=self.manifest.block_raw_size(i)
        )
        self._cache[i] = raw
        self.stats.blocks_fetched += 1
        self.stats.fetched_compressed += len(comp)
        self.stats.fetched_raw += len(raw)
        return raw

    # -- range-level (on-demand I/O) --------------------------------------
    def read_range(self, offset: int, length: int) -> bytes:
        m = self.manifest
        if offset < 0 or offset + length > m.raw_size:
            raise ValueError(
                f"range [{offset}, {offset + length}) outside payload of {m.raw_size}"
            )
        self.stats.useful_bytes += length
        first, last = m.block_range_for(offset, length)
        out = io.BytesIO()
        for i in range(first, last + 1):
            raw = self.get_block(i)
            lo = max(0, offset - i * m.block_size)
            hi = min(len(raw), offset + length - i * m.block_size)
            out.write(raw[lo:hi])
        return out.getvalue()

    def read_all(self) -> bytes:
        return self.read_range(0, self.manifest.raw_size)
