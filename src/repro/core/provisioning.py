"""Container/weight provisioning protocol (paper §3.3, Figure 9).

The seven-step scheduler ↔ worker RPC exchange, encoded as an explicit state
machine so both the simulator and the tests can drive it and assert on legal
transitions.  The protocol is payload-agnostic: "container" below can be a
function container image, a code package, or (in the TPU mapping) a
checkpoint shard.

  Step 1  scheduler: insert VM into the function's FT; look up upstream peer
  Step 2  scheduler → VM: function metadata + upstream address
          VM: download .tar manifest from metadata store; persist layer URLs
  Step 3  VM → scheduler: ready-to-create
  Step 4  scheduler → VM: create container RPC
  Step 5  VM → upstream: fetch blocks (streamed; on-demand subset)
  Step 6  upstream → VM: block data (pipelined downstream as received)
  Step 7  VM → scheduler: container created
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class ProvisionState(enum.Enum):
    INIT = "init"  # not yet in a tree
    INSERTED = "inserted"  # step 1 done; knows upstream
    MANIFEST_READY = "manifest_ready"  # step 2 done; layer URLs persisted
    READY_TO_CREATE = "ready_to_create"  # step 3 sent
    CREATING = "creating"  # step 4 received; steps 5/6 in flight
    CREATED = "created"  # step 7 sent
    FAILED = "failed"


_LEGAL = {
    ProvisionState.INIT: {ProvisionState.INSERTED, ProvisionState.FAILED},
    ProvisionState.INSERTED: {ProvisionState.MANIFEST_READY, ProvisionState.FAILED},
    ProvisionState.MANIFEST_READY: {
        ProvisionState.READY_TO_CREATE,
        ProvisionState.FAILED,
    },
    ProvisionState.READY_TO_CREATE: {ProvisionState.CREATING, ProvisionState.FAILED},
    ProvisionState.CREATING: {ProvisionState.CREATED, ProvisionState.FAILED},
    ProvisionState.CREATED: set(),
    ProvisionState.FAILED: {ProvisionState.INSERTED},  # retry after tree repair
}


@dataclass
class RPCCosts:
    """Control-plane latency model (seconds). Data-plane time comes from sim."""

    scheduler_rpc: float = 0.001  # one scheduler<->worker round trip
    manifest_fetch: float = 0.010  # metadata-store .tar manifest download
    image_load: float = 0.050  # local `image load` of the manifest
    container_start: float = 0.500  # runc start once enough blocks arrived

    def control_plane_total(self) -> float:
        # steps 1-4 + 7: three scheduler RPCs + manifest fetch + image load
        return 3 * self.scheduler_rpc + self.manifest_fetch + self.image_load


@dataclass
class ProvisionTask:
    """Lifecycle of provisioning one function instance onto one VM."""

    function_id: str
    vm_id: str
    state: ProvisionState = ProvisionState.INIT
    upstream: Optional[str] = None  # None => fetch from registry
    history: list[tuple[ProvisionState, float]] = field(default_factory=list)
    t_started: float = 0.0
    t_created: float = 0.0

    def transition(self, new: ProvisionState, now: float) -> None:
        if new not in _LEGAL[self.state]:
            raise ValueError(
                f"illegal transition {self.state.value} -> {new.value} "
                f"for {self.function_id}@{self.vm_id}"
            )
        self.state = new
        self.history.append((new, now))
        if new is ProvisionState.CREATED:
            self.t_created = now

    # convenience drivers -------------------------------------------------
    def step1_insert(self, upstream: Optional[str], now: float) -> None:
        self.t_started = now
        self.upstream = upstream
        self.transition(ProvisionState.INSERTED, now)

    def step2_manifest(self, now: float) -> None:
        self.transition(ProvisionState.MANIFEST_READY, now)

    def step3_ready(self, now: float) -> None:
        self.transition(ProvisionState.READY_TO_CREATE, now)

    def step4_create(self, now: float) -> None:
        self.transition(ProvisionState.CREATING, now)

    def step7_created(self, now: float) -> None:
        self.transition(ProvisionState.CREATED, now)

    def fail(self, now: float) -> None:
        self.transition(ProvisionState.FAILED, now)

    def retry_with(self, upstream: Optional[str], now: float) -> None:
        """After FT repair: re-enter with a new upstream (step 1 again)."""
        self.upstream = upstream
        self.transition(ProvisionState.INSERTED, now)

    def provisioning_latency(self) -> float:
        assert self.state is ProvisionState.CREATED
        return self.t_created - self.t_started
