"""Block/layer image model for on-demand provisioning (paper §3.1–§3.2).

The paper's I/O claim (§3.2, Fig. 20) has three parts this module makes
first-class:

  * images are stacks of **content-addressed layers** — two functions built
    from the same base image share those layers' blocks byte-for-byte;
  * a container is *runnable* once the **boot working set** — the leading
    prefix of blocks, front-to-back across layers — has landed, long before
    the full image has materialized;
  * every VM keeps a **block cache**: blocks that already landed (for any
    function) are served locally and never re-fetched, and a peer holding
    them can seed them downstream (§3.1).

:class:`ImageSpec` turns a layer stack into block geometry by reusing
:class:`~repro.core.blockstore.BlockManifest` — each layer gets an
identity-offset manifest (block boundaries, covering-range math, tail-block
sizing), so the simulator and the real on-disk format agree on which blocks
a byte range touches.  :class:`BlockCache` tracks per-VM resident block
*prefixes* per layer digest — both boot working sets and fully materialized
layers are prefixes, so residency is a single block count with max-merge
semantics.  The plan builders in :mod:`repro.core.topology` consume both to
emit per-layer flows that skip resident blocks.
"""
from __future__ import annotations

from dataclasses import dataclass

from .blockstore import DEFAULT_BLOCK_SIZE, BlockManifest


@dataclass(frozen=True)
class LayerSpec:
    """One content-addressed layer: the unit of cross-function sharing."""

    digest: str
    size: int

    def __post_init__(self) -> None:
        if self.size < 0:
            raise ValueError(f"layer {self.digest!r} has negative size {self.size}")


@dataclass(frozen=True)
class ImageSpec:
    """A container image: ordered layers + block geometry + boot working set.

    ``boot_fraction`` is the fraction of the image (front-to-back across
    layers, base layers first) that must land before the container is
    *runnable* — the same knob the scalar model calls ``startup_fraction``,
    now resolved to concrete block prefixes per layer.
    """

    name: str
    layers: tuple[LayerSpec, ...]
    block_size: int = DEFAULT_BLOCK_SIZE
    boot_fraction: float = 0.15

    def __post_init__(self) -> None:
        if not self.layers:
            raise ValueError(f"image {self.name!r} has no layers")
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive (got {self.block_size})")
        if not 0.0 < self.boot_fraction <= 1.0:
            raise ValueError(
                f"boot_fraction must be in (0, 1] (got {self.boot_fraction})"
            )
        digests = [la.digest for la in self.layers]
        if len(set(digests)) != len(digests):
            raise ValueError(f"image {self.name!r} repeats a layer digest")
        # Identity-keyed memo caches (object.__setattr__: the dataclass is
        # frozen, and these are derived state, not fields — eq/hash/repr are
        # untouched).  layer()/geometry()/boot_blocks() are pure functions
        # of the frozen spec but sit on the content-affinity hot path —
        # scored once per candidate VM per reservation — where recomputing
        # block geometry dominated giga-replay wall time.
        object.__setattr__(
            self, "_layer_by_digest", {la.digest: la for la in self.layers}
        )
        object.__setattr__(self, "_geom_cache", {})
        object.__setattr__(self, "_boot_blocks_cache", None)

    # -- layer lookup ----------------------------------------------------
    def layer(self, digest: str) -> LayerSpec:
        try:
            return self._layer_by_digest[digest]
        except KeyError:
            raise KeyError(
                f"image {self.name!r} has no layer {digest!r}"
            ) from None

    def total_bytes(self) -> int:
        return sum(la.size for la in self.layers)

    # -- block geometry (BlockManifest reuse) -----------------------------
    def geometry(self, digest: str) -> BlockManifest:
        """Identity-offset manifest for one layer: pure block math.

        Offsets equal the raw block boundaries (no compression modeled at
        this granularity), so ``block_range_for`` / ``block_raw_size`` — the
        exact covering-range arithmetic the on-disk format uses — apply
        verbatim to the simulated layer.
        """
        g = self._geom_cache.get(digest)
        if g is not None:
            return g
        size = self.layer(digest).size
        bs = self.block_size
        n = max(1, -(-size // bs))
        offsets = tuple(min(i * bs, size) for i in range(n)) + (size,)
        g = BlockManifest(bs, n, size, offsets)
        self._geom_cache[digest] = g
        return g

    def layer_blocks(self, digest: str) -> int:
        return self.geometry(digest).n_blocks

    def prefix_bytes(self, digest: str, n_blocks: int) -> int:
        """Raw bytes held by the first ``n_blocks`` blocks of a layer."""
        g = self.geometry(digest)
        if n_blocks <= 0:
            return 0
        if n_blocks >= g.n_blocks:
            return g.raw_size
        return n_blocks * g.block_size

    # -- boot working set -------------------------------------------------
    def boot_bytes(self) -> int:
        """Unaligned boot working-set size (the scalar model's ``need``)."""
        return int(self.total_bytes() * self.boot_fraction)

    def boot_blocks(self) -> dict[str, int]:
        """Per-layer boot-prefix block counts, front-to-back across layers.

        The boot budget is consumed layer by layer (base layers first); the
        blocks *covering* each layer's share are the runnable prefix —
        block alignment is where Fig. 20's read amplification comes from.
        """
        cached = self._boot_blocks_cache
        if cached is not None:
            return dict(cached)
        budget = self.boot_bytes()
        out: dict[str, int] = {}
        for la in self.layers:
            take = min(budget, la.size)
            budget -= take
            if take <= 0:
                out[la.digest] = 0
                continue
            first, last = self.geometry(la.digest).block_range_for(0, take)
            out[la.digest] = last - first + 1
        object.__setattr__(self, "_boot_blocks_cache", dict(out))
        return out

    def boot_prefix_bytes(self, digest: str) -> int:
        """Block-aligned bytes that must land for this layer's boot share."""
        return self.prefix_bytes(digest, self.boot_blocks()[digest])

    def boot_read_amplification(self) -> float:
        """Fetched/useful ratio for the boot working set (paper Fig. 20).

        Block alignment rounds each layer's boot share up to whole blocks;
        bigger blocks waste more bytes past the working-set edge, so this
        grows with ``block_size`` — the sweep ``bench_blocks.py`` plots.
        """
        useful = self.boot_bytes()
        if useful <= 0:
            return 1.0
        fetched = sum(self.boot_prefix_bytes(la.digest) for la in self.layers)
        return fetched / useful


class BlockCache:
    """Per-VM resident block prefixes, keyed by layer digest (§3.1).

    Residency is a *prefix* block count per (vm, digest): boot working sets
    and fully materialized layers are both prefixes, and a parent always
    holds (or is concurrently fetching) any prefix its child needs, so one
    integer with max-merge updates captures the whole state.  This is
    data-plane state — it lives with the VMs, not the scheduler, and
    deliberately does NOT ride the failover snapshot (a restored scheduler
    rediscovers residency exactly like a real one would).
    """

    def __init__(self) -> None:
        self._vm: dict[str, dict[str, int]] = {}

    def resident_blocks(self, vm_id: str, digest: str) -> int:
        return self._vm.get(vm_id, {}).get(digest, 0)

    def add_prefix(self, vm_id: str, digest: str, n_blocks: int) -> None:
        """Record that the first ``n_blocks`` of a layer landed (max-merge)."""
        if n_blocks <= 0:
            return
        d = self._vm.setdefault(vm_id, {})
        if n_blocks > d.get(digest, 0):
            d[digest] = n_blocks

    def add_image(self, vm_id: str, image: ImageSpec) -> None:
        """A full image materialized on the VM: every layer fully resident."""
        for la in image.layers:
            self.add_prefix(vm_id, la.digest, image.layer_blocks(la.digest))

    def evict(self, vm_id: str) -> None:
        """VM reclaimed: its block cache goes with it."""
        self._vm.pop(vm_id, None)

    def vms(self):
        """VM ids holding any resident blocks (content-root candidate set)."""
        return self._vm.keys()

    def resident_bytes(self, vm_id: str, image: ImageSpec) -> int:
        """Bytes of ``image`` already on the VM (content-aware placement score)."""
        held = self._vm.get(vm_id)
        if not held:
            return 0
        total = 0
        for la in image.layers:
            n = held.get(la.digest, 0)
            if n:
                total += image.prefix_bytes(
                    la.digest, min(n, image.layer_blocks(la.digest))
                )
        return total

    def missing_layer_bytes(
        self, vm_id: str, image: ImageSpec, digest: str
    ) -> tuple[int, int]:
        """(full-layer, boot-prefix) bytes a VM still needs of one layer.

        The first element sizes the materialization flow (everything not
        resident); the second is the runnable prefix within that flow —
        boot blocks not yet resident.  Both are 0 for a fully cached layer.
        """
        have = image.prefix_bytes(
            digest,
            min(self.resident_blocks(vm_id, digest), image.layer_blocks(digest)),
        )
        full = image.layer(digest).size - have
        boot = max(0, image.boot_prefix_bytes(digest) - have)
        return full, boot


# ----------------------------------------------------------------------
# Workload builders: the layer-sharing scenarios the ROADMAP names
# ----------------------------------------------------------------------
def shared_base_images(
    n_functions: int,
    n_bases: int,
    *,
    image_bytes: int,
    base_fraction: float = 0.8,
    base_layers: int = 3,
    block_size: int = DEFAULT_BLOCK_SIZE,
    boot_fraction: float = 0.15,
) -> list[ImageSpec]:
    """N functions built from ``n_bases`` shared base images (+1 private layer).

    Function ``i`` stacks the content-addressed base layers of base
    ``i % n_bases`` under a function-private app layer — the "25 functions
    on 3 base images" scenario: base blocks dedup across every function on
    the same base, only the private layer is unique traffic.
    """
    if n_functions < 1 or n_bases < 1:
        raise ValueError("need >= 1 function and >= 1 base")
    base_bytes = int(image_bytes * base_fraction)
    per_layer = base_bytes // base_layers
    sizes = [per_layer] * (base_layers - 1) + [base_bytes - per_layer * (base_layers - 1)]
    private = image_bytes - base_bytes
    images = []
    for i in range(n_functions):
        b = i % n_bases
        layers = tuple(
            LayerSpec(f"base{b}:L{j}", sz) for j, sz in enumerate(sizes)
        ) + (LayerSpec(f"fn{i}:app", private),)
        images.append(
            ImageSpec(f"fn{i}", layers, block_size=block_size, boot_fraction=boot_fraction)
        )
    return images


def disjoint_images(
    n_functions: int,
    *,
    image_bytes: int,
    base_fraction: float = 0.8,
    base_layers: int = 3,
    block_size: int = DEFAULT_BLOCK_SIZE,
    boot_fraction: float = 0.15,
) -> list[ImageSpec]:
    """Same layer structure as :func:`shared_base_images`, zero sharing.

    Every function gets its own "base" (``n_bases == n_functions``), so the
    two builders differ only in digest identity — the clean A/B for how much
    layer sharing is worth.
    """
    return shared_base_images(
        n_functions,
        n_functions,
        image_bytes=image_bytes,
        base_fraction=base_fraction,
        base_layers=base_layers,
        block_size=block_size,
        boot_fraction=boot_fraction,
    )
