"""First-class sharded container registry (paper §4.3, ROADMAP "Registry
sharding").

The paper's central scalability claim is that FaaSNet makes provisioning
latency *insensitive* to registry bandwidth, while ``docker pull`` and
on-demand fetch scale only as fast as the registry does — i.e. baseline
throughput grows ~linearly with registry replicas, FaaSNet's does not move.
Reproducing both directions of that claim needs the registry to be a real
subsystem rather than the single hardcoded ``REGISTRY`` pseudo-node the
simulator started with:

  * :class:`RegistrySpec` — N shards, per-shard egress capacity and QPS
    (optionally heterogeneous per shard, the Function-Delivery-Network
    setting of Jindal et al. 2021), and a blob-placement policy;
  * :class:`ShardResolver` — the stateful shard-assignment policy plan
    builders (:mod:`repro.core.topology`) and the trace replays consult to
    turn "fetch from the registry" into "fetch from shard i";
  * node-id helpers (:func:`is_registry_node`, :func:`shard_index`) the
    engines use to recognize capped registry sources.

Placement policies
------------------
``hash_by_function``
    Each blob (keyed by the flow's ``piece`` — the function/image id) lives
    on exactly one shard, chosen by a stable CRC32 hash.  Models a sharded
    but *unreplicated* registry: one function's wave still hammers one
    shard.
``least_loaded``
    Each assignment goes to the shard with the fewest bytes assigned so
    far (ties break to the lowest index).  Models a load-balancing blob
    placer with global knowledge.
``replicated``
    Every shard holds every blob; fetchers round-robin across shards.
    Models registry *replicas* — the configuration the paper's "baseline
    scales with registry bandwidth" claim is about, and the one
    ``benchmarks/bench_registry_sweep.py`` sweeps.

Naming and backward compatibility
---------------------------------
A 1-shard registry names its only shard ``__registry__`` — the legacy
sentinel — so single-shard simulations are bit-identical to the
pre-sharding engine, event-log strings included (pinned by
``tests/test_registry.py``).  Multi-shard registries name shards
``__registry_shard{i}__``; the bare ``__registry__`` sentinel remains a
valid flow source everywhere and is treated as an alias for shard 0.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass

GBPS = 125e6  # 1 Gbit/s in bytes/s (canonically re-exported by sim.engine)

REGISTRY = "__registry__"  # legacy pseudo-node: the 1-shard registry / shard 0
_SHARD_PREFIX = "__registry_shard"
_SHARD_SUFFIX = "__"

PLACEMENT_POLICIES = ("hash_by_function", "least_loaded", "replicated")


def is_registry_node(node: str) -> bool:
    """True iff ``node`` is the legacy sentinel or a concrete shard id."""
    return node == REGISTRY or (
        node.startswith(_SHARD_PREFIX) and node.endswith(_SHARD_SUFFIX)
    )


def shard_index(node: str) -> int:
    """Shard index encoded in a registry node id (the sentinel is shard 0)."""
    if node == REGISTRY:
        return 0
    if node.startswith(_SHARD_PREFIX) and node.endswith(_SHARD_SUFFIX):
        return int(node[len(_SHARD_PREFIX) : -len(_SHARD_SUFFIX)])
    raise ValueError(f"{node!r} is not a registry node")


@dataclass(frozen=True)
class RegistrySpec:
    """Shape and capacity of the registry: N shards with per-shard caps.

    ``egress_cap`` and ``qps`` are *per shard*: adding shards adds capacity
    (the paper's replica scaling), it does not slice a fixed pool.  The
    optional ``egress_caps`` / ``qps_caps`` tuples override the scalars per
    shard for heterogeneous delivery targets.
    """

    shards: int = 1
    egress_cap: float = 5.0 * GBPS  # per-shard egress (bytes/s)
    qps: float = float("inf")  # per-shard block-request throttle (req/s)
    policy: str = "hash_by_function"
    egress_caps: tuple[float, ...] | None = None  # per-shard overrides
    qps_caps: tuple[float, ...] | None = None

    def __post_init__(self) -> None:
        if self.shards < 1:
            raise ValueError(f"registry needs >= 1 shard (got {self.shards})")
        if self.policy not in PLACEMENT_POLICIES:
            raise ValueError(
                f"unknown placement policy {self.policy!r}; "
                f"one of {PLACEMENT_POLICIES}"
            )
        for name, caps in (("egress_caps", self.egress_caps),
                           ("qps_caps", self.qps_caps)):
            if caps is not None and len(caps) != self.shards:
                raise ValueError(
                    f"{name} must have one entry per shard "
                    f"({len(caps)} != {self.shards})"
                )

    # -- node ids -------------------------------------------------------
    def shard_id(self, i: int) -> str:
        """Concrete node id of shard ``i`` (the sentinel when 1-sharded)."""
        if not 0 <= i < self.shards:
            raise IndexError(f"shard {i} out of range (shards={self.shards})")
        if self.shards == 1:
            return REGISTRY  # bit-compatible with the pre-sharding engine
        return f"{_SHARD_PREFIX}{i}{_SHARD_SUFFIX}"

    def shard_ids(self) -> list[str]:
        return [self.shard_id(i) for i in range(self.shards)]

    def canonical(self, node: str) -> str:
        """Map any registry alias (the legacy sentinel) to its shard id.

        Raises ``ValueError`` for a shard id this registry does not have —
        a plan built against a bigger registry than the engine's spec is a
        config bug that must not silently clamp to one shard's capacity.
        """
        i = shard_index(node)
        if i >= self.shards:
            raise ValueError(
                f"{node!r} does not exist in a {self.shards}-shard registry"
            )
        return self.shard_id(i)

    # -- per-shard capacities ------------------------------------------
    def egress_of(self, i: int) -> float:
        return self.egress_caps[i] if self.egress_caps is not None else self.egress_cap

    def qps_of(self, i: int) -> float:
        return self.qps_caps[i] if self.qps_caps is not None else self.qps

    def aggregate_egress_cap(self) -> float:
        return sum(self.egress_of(i) for i in range(self.shards))

    # -- legacy two-knob configs ----------------------------------------
    @classmethod
    def resolve(
        cls, spec: "RegistrySpec | None", *, egress_cap: float, qps: float
    ) -> "RegistrySpec":
        """``spec`` if given, else a 1-shard spec from the legacy caps.

        The one place the "None means the pre-sharding single registry"
        compat rule lives; every config's ``registry_spec()`` delegates here.
        """
        if spec is not None:
            return spec
        return cls(shards=1, egress_cap=egress_cap, qps=qps)

    # -- wire format (scheduler snapshots) ------------------------------
    def to_json(self) -> dict:
        out: dict = {
            "shards": self.shards,
            "egress_cap": self.egress_cap,
            "qps": self.qps if math.isfinite(self.qps) else None,
            "policy": self.policy,
        }
        if self.egress_caps is not None:
            out["egress_caps"] = list(self.egress_caps)
        if self.qps_caps is not None:
            out["qps_caps"] = [
                q if math.isfinite(q) else None for q in self.qps_caps
            ]
        return out

    @classmethod
    def from_json(cls, blob: dict) -> "RegistrySpec":
        qps = blob.get("qps")
        qps_caps = blob.get("qps_caps")
        return cls(
            shards=int(blob["shards"]),
            egress_cap=float(blob["egress_cap"]),
            qps=float("inf") if qps is None else float(qps),
            policy=blob.get("policy", "hash_by_function"),
            egress_caps=(
                tuple(float(c) for c in blob["egress_caps"])
                if blob.get("egress_caps") is not None
                else None
            ),
            qps_caps=(
                tuple(float("inf") if q is None else float(q) for q in qps_caps)
                if qps_caps is not None
                else None
            ),
        )


class ShardResolver:
    """Stateful shard assignment: plan builders ask it where blobs live.

    The resolver is control-plane state: the multi-tenant replay carries it
    across scheduler failovers via :meth:`snapshot` / :meth:`restore` so a
    restored scheduler keeps assigning shards exactly where the failed one
    would have (``least_loaded`` loads and the ``replicated`` round-robin
    cursor are both part of the wire snapshot).
    """

    def __init__(self, spec: RegistrySpec | None = None) -> None:
        self.spec = spec or RegistrySpec()
        self.loads: list[float] = [0.0] * self.spec.shards  # bytes assigned
        self._rr = 0  # round-robin cursor for the replicated policy

    # ------------------------------------------------------------------
    def shard_for(self, piece: str) -> int:
        """Shard index for one assignment (advances stateful policies)."""
        spec = self.spec
        if spec.policy == "hash_by_function":
            return zlib.crc32(piece.encode("utf-8")) % spec.shards
        if spec.policy == "least_loaded":
            return min(range(spec.shards), key=lambda i: (self.loads[i], i))
        i = self._rr % spec.shards  # replicated: round-robin over replicas
        self._rr += 1
        return i

    def source_for(self, piece: str, *, nbytes: int = 0) -> str:
        """Node id to fetch ``piece`` from; accounts ``nbytes`` to the shard."""
        i = self.shard_for(piece)
        self.loads[i] += nbytes
        return self.spec.shard_id(i)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "spec": self.spec.to_json(),
            "loads": list(self.loads),
            "rr": self._rr,
        }

    @classmethod
    def restore(cls, blob: dict) -> "ShardResolver":
        r = cls(RegistrySpec.from_json(blob["spec"]))
        loads = [float(x) for x in blob.get("loads", [])]
        if len(loads) == r.spec.shards:
            r.loads = loads
        r._rr = int(blob.get("rr", 0))
        return r


def as_resolver(
    registry: "RegistrySpec | ShardResolver | None",
) -> ShardResolver:
    """Coerce a plan builder's ``registry`` argument to a resolver.

    ``None`` means the legacy single-shard registry; a spec gets a fresh
    resolver (fine for one-shot plans); an existing resolver is shared so
    stateful policies see every assignment across plans.
    """
    if registry is None:
        return ShardResolver()
    if isinstance(registry, RegistrySpec):
        return ShardResolver(registry)
    return registry
