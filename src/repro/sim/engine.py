"""Deterministic discrete-event fluid-flow network simulator.

Models a pool of VMs (full-duplex NICs with separate in/out capacity), a
central registry with bounded egress, and a set of data flows produced by a
:class:`repro.core.topology.DistributionPlan`.  Used to time provisioning
waves for FaaSNet and the paper's comparison systems, and to replay the
application-level traces (Figures 11-18).

Rate model (documented approximation)
-------------------------------------
At any instant, an active flow's rate is

    rate(f) = min( per_stream_cap,
                   src_out_cap / #active flows leaving src,
                   dst_in_cap  / #active flows entering dst,
                   rate(parent flow)  if f streams behind a parent )

i.e. equal split at each NIC without redistribution of unused shares.  For
tree topologies every NIC carries ≤1 inbound and ≤2 outbound flows, so the
split is exact; for registry-centric baselines all flows are symmetric so it
is exact as well; for the Kraken all-to-all mesh it is mildly pessimistic,
which matches the paper's qualitative finding.  Streaming children start one
block-time after their parent and are rate-capped by the parent's inbound
rate, which bounds the approximation error at ≤ one block-time per hop.

Events are (time, seq) ordered, so runs are bit-deterministic.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.topology import REGISTRY, DistributionPlan, Flow

GBPS = 125e6  # 1 Gbit/s in bytes/s


@dataclass
class NICConfig:
    in_cap: float = 1.0 * GBPS
    out_cap: float = 1.0 * GBPS


@dataclass
class SimConfig:
    vm_nic: NICConfig = field(default_factory=NICConfig)
    registry_out_cap: float = 5.0 * GBPS  # calibrated to paper §4.3 baselines
    per_stream_cap: float = float("inf")  # app-level throughput cap per stream
    block_size: int = 512 * 1024
    hop_latency: float = 0.0  # store-and-forward + decompress cost per tree hop
    coordinator_cost_s: float = 0.008  # CPU time a root/origin burns per request
    decompress_rate: float = 2e9  # bytes/s; >> network, so rarely binding
    # Registry request throttling (paper §4.3: "image pulls are throttled at
    # the registry").  Block-granular fetchers issue one range request per
    # block; the registry serves at most ``registry_qps`` such requests/s,
    # which caps the aggregate block-mode egress at block_size * qps shared
    # across the streams currently hitting the registry.
    registry_qps: float = float("inf")


@dataclass
class _FlowState:
    flow: Flow
    remaining: float
    total: float
    start_after: float  # control-plane release time
    parent: Optional["_FlowState"] = None  # streaming dependency
    started: bool = False
    done: bool = False
    t_start: float = math.inf
    t_done: float = math.inf
    rate: float = 0.0
    block_mode: bool = False  # block-granular range requests (registry-throttled)
    on_done: Optional[Callable[[float], None]] = None


class FlowSim:
    """Simulate one or more distribution plans sharing the same network."""

    def __init__(self, cfg: SimConfig | None = None) -> None:
        self.cfg = cfg or SimConfig()
        self.now = 0.0
        self._flows: list[_FlowState] = []
        self._events: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self._slow_out: dict[str, float] = {}  # vm_id -> out cap override
        self.trace: list[tuple[float, str]] = []  # (time, event) log

    # ------------------------------------------------------------------
    def set_slow_vm(self, vm_id: str, out_cap: float) -> None:
        """Straggler injection: clamp a VM's egress capacity."""
        self._slow_out[vm_id] = out_cap

    def clear_slow_vm(self, vm_id: str) -> None:
        self._slow_out.pop(vm_id, None)

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, fn))

    # ------------------------------------------------------------------
    def add_plan(
        self,
        plan: DistributionPlan,
        *,
        t0: float = 0.0,
        on_node_done: Optional[Callable[[str, float], None]] = None,
        coordinator_queues: Optional[dict[str, float]] = None,
    ) -> list[_FlowState]:
        """Register a provisioning wave starting at ``t0``.

        ``coordinator_queues`` carries serialization state for root/origin
        coordinators across plans (the Kraken-origin / DADI-root CPU queue).
        """
        cfg = self.cfg
        coordinator_queues = coordinator_queues if coordinator_queues is not None else {}
        by_dst: dict[str, _FlowState] = {}
        states: list[_FlowState] = []
        for fl in plan.flows:
            release = t0 + plan.control_latency.get(fl.dst, 0.0)
            # Coordinator serialization: each request queues on the root's CPU.
            coord = plan.coordinator.get(fl.dst)
            if coord is not None:
                q = max(coordinator_queues.get(coord, t0), release)
                release = q + cfg.coordinator_cost_s
                coordinator_queues[coord] = release
            st = _FlowState(flow=fl, remaining=float(fl.bytes), total=float(fl.bytes),
                            start_after=release,
                            block_mode=plan.streaming and fl.src == REGISTRY)
            states.append(st)
            # streaming dependency: dst of the parent flow == src of this flow
            by_dst.setdefault(fl.dst, st)
        if plan.streaming:
            block_t = cfg.block_size / cfg.vm_nic.in_cap
            for st in states:
                up = by_dst.get(st.flow.src)
                if up is not None:
                    st.parent = up
                    st.start_after = max(st.start_after, t0)  # start gated below
                    # child may begin one block (+hop cost) after the parent
                    st._pipeline_delay = block_t + cfg.hop_latency  # type: ignore[attr-defined]
        for st in states:
            if on_node_done is not None:
                dst, total = st.flow.dst, st.flow.bytes
                st.on_done = (
                    lambda t, dst=dst: on_node_done(dst, t)
                )
            self._flows.append(st)
            self._arm_start(st)
        return states

    def _arm_start(self, st: _FlowState) -> None:
        if st.parent is None:
            self.schedule(max(st.start_after, self.now), lambda: self._start_flow(st))
        else:
            # started when parent starts + one block-time (and own release time)
            def try_start() -> None:
                if st.started or st.done:
                    return
                p = st.parent
                if p.started:
                    delay = getattr(st, "_pipeline_delay", 0.0)
                    t = max(st.start_after, p.t_start + delay, self.now)
                    self.schedule(t, lambda: self._start_flow(st))
                else:
                    self.schedule(self.now + 1e-4, try_start)  # poll cheaply

            self.schedule(max(st.start_after, self.now), try_start)

    def _start_flow(self, st: _FlowState) -> None:
        if st.started or st.done:
            return
        if st.parent is not None and not st.parent.started:
            self._arm_start(st)
            return
        st.started = True
        st.t_start = self.now

    # ------------------------------------------------------------------
    # Rate computation (called after every event)
    # ------------------------------------------------------------------
    def _recompute_rates(self) -> None:
        cfg = self.cfg
        out_count: dict[str, int] = {}
        in_count: dict[str, int] = {}
        active = [f for f in self._flows if f.started and not f.done]
        for f in active:
            out_count[f.flow.src] = out_count.get(f.flow.src, 0) + 1
            in_count[f.flow.dst] = in_count.get(f.flow.dst, 0) + 1

        def out_cap(node: str) -> float:
            if node == REGISTRY:
                return cfg.registry_out_cap
            return self._slow_out.get(node, cfg.vm_nic.out_cap)

        # topological order: parents before children (tree depth is small)
        def depth(f: _FlowState) -> int:
            d, p = 0, f.parent
            while p is not None:
                d += 1
                p = p.parent
            return d

        reg_block_rate = cfg.block_size * cfg.registry_qps  # aggregate bytes/s
        for f in sorted(active, key=depth):
            r = min(
                cfg.per_stream_cap,
                out_cap(f.flow.src) / out_count[f.flow.src],
                cfg.vm_nic.in_cap / in_count[f.flow.dst],
                cfg.decompress_rate,
            )
            if f.flow.src == REGISTRY and f.block_mode:
                r = min(r, reg_block_rate / out_count[REGISTRY])
            if f.parent is not None and not f.parent.done:
                r = min(r, f.parent.rate)
            f.rate = r

    # ------------------------------------------------------------------
    def run(self, until: float = math.inf) -> float:
        """Advance until no events remain (or ``until``); returns final time."""
        while True:
            self._recompute_rates()
            # next flow completion at current rates
            t_next_done = math.inf
            next_flow: Optional[_FlowState] = None
            for f in self._flows:
                if f.started and not f.done and f.rate > 0:
                    t = self.now + f.remaining / f.rate
                    if t < t_next_done:
                        t_next_done, next_flow = t, f
            t_next_evt = self._events[0][0] if self._events else math.inf
            t_next = min(t_next_done, t_next_evt)
            if t_next == math.inf or t_next > until:
                if until != math.inf and until > self.now:
                    dt = until - self.now
                    for f in self._flows:
                        if f.started and not f.done:
                            f.remaining = max(0.0, f.remaining - f.rate * dt)
                    self.now = until
                return self.now
            # advance progress linearly to t_next
            dt = t_next - self.now
            for f in self._flows:
                if f.started and not f.done:
                    f.remaining = max(0.0, f.remaining - f.rate * dt)
            self.now = t_next
            if t_next_done <= t_next_evt and next_flow is not None:
                next_flow.done = True
                next_flow.remaining = 0.0
                next_flow.t_done = self.now
                if next_flow.on_done is not None:
                    next_flow.on_done(self.now)
            else:
                while self._events and self._events[0][0] <= self.now + 1e-12:
                    _, _, fn = heapq.heappop(self._events)
                    fn()

    # ------------------------------------------------------------------
    def completion_times(self) -> dict[str, float]:
        """dst vm_id -> time its payload finished arriving."""
        out: dict[str, float] = {}
        for f in self._flows:
            if f.done:
                out[f.flow.dst] = max(out.get(f.flow.dst, 0.0), f.t_done)
        return out
