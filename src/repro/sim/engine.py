"""Deterministic discrete-event fluid-flow network simulator.

Models a pool of VMs (full-duplex NICs with separate in/out capacity), a
sharded registry (N capped-egress, QPS-throttled sources — see
:class:`repro.core.registry.RegistrySpec`), and a set of data flows produced
by a :class:`repro.core.topology.DistributionPlan`.  Used to time provisioning
waves for FaaSNet and the paper's comparison systems, to replay the
application-level traces (Figures 11-18), and — via ``repro.sim.scale`` —
to reproduce the paper's §4.2 1000-VM burst at full size.

Rate model (documented approximation)
-------------------------------------
At any instant, an active flow's rate is

    rate(f) = min( per_stream_cap,
                   src_out_cap / #active flows leaving src,
                   dst_in_cap  / #active flows entering dst,
                   rate(parent flow)  if f streams behind a parent )

i.e. equal split at each NIC without redistribution of unused shares.  For
tree topologies every NIC carries ≤1 inbound and ≤2 outbound flows, so the
split is exact; for registry-centric baselines all flows are symmetric so it
is exact as well; for the Kraken all-to-all mesh it is mildly pessimistic,
which matches the paper's qualitative finding.  Streaming children start one
block-time after their parent and are rate-capped by the parent's inbound
rate, which bounds the approximation error at ≤ one block-time per hop.

Incremental-rate engine
-----------------------
Under equal split, ``rate(f)`` depends only on (a) the *count* of active
flows on f's source and destination NICs and (b) the parent flow's rate.  So
when a flow starts or completes, only the flows sharing one of its two NICs
— plus, transitively, their streaming descendants — can change rate.  The
engine keeps per-NIC active-flow registries and a completion heap with
lazily-invalidated entries (per-flow epoch counters); each event settles and
re-rates just that dirty closure instead of every active flow, and batches
all same-timestamp completions into a single settle pass.  ``remaining``
bytes are settled lazily (per-flow ``t_last``), and each flow's streaming
depth is cached on its state (maintained by ``set_parent``, which also
refreshes the downstream chain) rather than re-derived by walking parent
chains, so an event costs O(degree · log F) instead of O(F), turning the
previously quadratic run into an ~O(F log F) one.

Determinism: events are (time, seq) ordered and every internal registry is
keyed by a densely-assigned flow id (``fid``), so iteration order — and
therefore the event log — is bit-reproducible across runs.  The original
full-recompute engine survives as :class:`repro.sim.reference.ReferenceFlowSim`
and the two are differential-tested in ``tests/test_scale.py``.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.registry import GBPS, RegistrySpec, is_registry_node, shard_index
from repro.core.topology import DistributionPlan, Flow

__all__ = [
    "GBPS",  # canonical home of the shared bytes/s constant
    "ENGINES",
    "NICConfig",
    "SimConfig",
    "FlowSim",
    "make_sim",
    "plan_releases",
    "wire_runnable",
]

#: Engine backends selectable via :attr:`SimConfig.engine`.  They form an
#: oracle chain — ``reference`` (full recompute, trivially correct) polices
#: ``incremental`` (per-NIC dirty sets), which polices ``vector`` (flat
#: numpy arrays, wide-front recompute), which in turn polices
#: ``vector_jax`` (the same engine with the cap-chain min-kernel from
#: ``repro.kernels.cap_chain`` on its wide fronts; falls back to the numpy
#: path when jax is absent) — all differential-tested to produce
#: bit-identical event logs and rates within 1e-9 (``tests/test_scale.py``,
#: ``tests/test_vector_engine.py``).
ENGINES = ("incremental", "vector", "vector_jax", "reference")


@dataclass
class NICConfig:
    in_cap: float = 1.0 * GBPS
    out_cap: float = 1.0 * GBPS


@dataclass
class SimConfig:
    vm_nic: NICConfig = field(default_factory=NICConfig)
    registry_out_cap: float = 5.0 * GBPS  # calibrated to paper §4.3 baselines
    per_stream_cap: float = float("inf")  # app-level throughput cap per stream
    block_size: int = 512 * 1024
    hop_latency: float = 0.0  # store-and-forward + decompress cost per tree hop
    coordinator_cost_s: float = 0.008  # CPU time a root/origin burns per request
    decompress_rate: float = 2e9  # bytes/s; >> network, so rarely binding
    # Registry request throttling (paper §4.3: "image pulls are throttled at
    # the registry").  Block-granular fetchers issue one range request per
    # block; each registry shard serves at most ``qps`` such requests/s,
    # which caps that shard's block-mode egress at block_size * qps shared
    # across the streams currently hitting it.
    registry_qps: float = float("inf")
    # Sharded registry.  ``None`` builds a 1-shard spec from the two legacy
    # knobs above, which keeps every pre-sharding configuration bit-exact;
    # a multi-shard spec makes each shard an independent capped source.
    registry: Optional[RegistrySpec] = None
    # Engine backend: "incremental" (default), "vector" (flat numpy arrays,
    # the 100k-VM backend) or "reference" (full-recompute oracle).  All
    # three produce identical results; see ``make_sim``.
    engine: str = "incremental"
    # Large fleets can drop the per-event text log (the giga-burst tier
    # would otherwise materialize millions of trace tuples).
    record_trace: bool = True
    # Vector engine only: fronts at or below this width run the scalar
    # fast path (~40 fixed-cost numpy dispatches cost more than a handful
    # of Python-float min chains); wider fronts take the vectorized path.
    # Both paths are bit-identical, so this is purely a performance knob.
    vector_scalar_cutoff: int = 64

    def registry_spec(self) -> RegistrySpec:
        """The effective spec (legacy knobs become a 1-shard registry)."""
        return RegistrySpec.resolve(
            self.registry, egress_cap=self.registry_out_cap, qps=self.registry_qps
        )


def plan_releases(
    plan: DistributionPlan,
    cfg: SimConfig,
    t0: float,
    coordinator_queues: dict[str, float],
) -> list[tuple[Flow, float, bool]]:
    """Shared plan → flow-schedule lowering used by every engine backend.

    For each flow of ``plan`` compute its control-plane release time (plan
    control latency plus, where a coordinator is named, serialization on
    that coordinator's CPU queue — mutated in ``coordinator_queues`` so the
    queue carries across plans) and whether it fetches block-granular from
    the registry (``block_mode``).  Returns ``(flow, release, block_mode)``
    in plan order.  Extracted from the per-engine ``add_plan`` bodies so the
    three backends cannot drift on release semantics.
    """
    out: list[tuple[Flow, float, bool]] = []
    for fl in plan.flows:
        release = t0 + plan.control_latency.get(fl.dst, 0.0)
        # Coordinator serialization: each request queues on the root's CPU.
        coord = plan.coordinator.get(fl.dst)
        if coord is not None:
            q = max(coordinator_queues.get(coord, t0), release)
            release = q + cfg.coordinator_cost_s
            coordinator_queues[coord] = release
        out.append((fl, release, plan.streaming and is_registry_node(fl.src)))
    return out


def wire_runnable(sim, states, on_node_runnable) -> None:
    """Attach runnable-prefix milestones to one wave's flow states (§3.2).

    For every dst with flows carrying a runnable prefix (``runnable_bytes``
    > 0), fire ``on_node_runnable(dst, t)`` the moment the *last* of those
    prefixes lands — ahead of full arrival.  A dst whose flows carry no
    prefix (boot working set fully cached, or only zero-byte marker flows)
    is runnable at its control-plane release and gets a scheduled event
    instead.  Shared by all three engine backends, called at the same point
    of each ``add_plan`` so event ordering cannot drift between them.
    """
    if on_node_runnable is None:
        return
    pending: dict[str, int] = {}
    for st in states:
        nb = min(int(st.flow.runnable_bytes), int(st.flow.bytes))
        if nb > 0:
            st.notify_bytes = float(nb)
            pending[st.flow.dst] = pending.get(st.flow.dst, 0) + 1

    def landed(t: float, dst: str) -> None:
        pending[dst] -= 1
        if pending[dst] == 0:
            on_node_runnable(dst, t)

    release: dict[str, float] = {}
    for st in states:
        dst = st.flow.dst
        if st.notify_bytes > 0.0:
            st.on_notify = lambda t, dst=dst: landed(t, dst)
        r = release.get(dst)
        release[dst] = st.start_after if r is None else min(r, st.start_after)
    for dst, t_rel in release.items():
        if dst not in pending:
            sim.schedule(t_rel, lambda dst=dst: on_node_runnable(dst, sim.now))


def make_sim(cfg: SimConfig | None = None, *, record_rates: bool = False):
    """Build the flow simulator selected by ``cfg.engine``.

    The default ("incremental") is :class:`FlowSim`; "vector" selects the
    array-based :class:`repro.sim.vector_engine.VectorFlowSim` backend,
    "vector_jax" its :class:`~repro.sim.vector_engine.VectorJaxFlowSim`
    subclass (cap-chain min-kernel on wide fronts, numpy fallback when jax
    is absent) and "reference" the full-recompute oracle.  All backends
    share ``SimConfig`` and the public API, and produce identical results
    on the same inputs.
    """
    cfg = cfg or SimConfig()
    if cfg.engine == "incremental":
        return FlowSim(cfg, record_rates=record_rates)
    if cfg.engine == "vector":
        from .vector_engine import VectorFlowSim

        return VectorFlowSim(cfg, record_rates=record_rates)
    if cfg.engine == "vector_jax":
        from .vector_engine import VectorJaxFlowSim

        return VectorJaxFlowSim(cfg, record_rates=record_rates)
    if cfg.engine == "reference":
        from .reference import ReferenceFlowSim

        return ReferenceFlowSim(cfg, record_rates=record_rates)
    raise ValueError(
        f"unknown engine {cfg.engine!r}; expected one of {ENGINES}"
    )


@dataclass(eq=False)
class _FlowState:
    flow: Flow
    remaining: float
    total: float
    start_after: float  # control-plane release time
    parent: Optional["_FlowState"] = None  # streaming dependency
    started: bool = False
    done: bool = False
    t_start: float = math.inf
    t_done: float = math.inf
    rate: float = 0.0
    block_mode: bool = False  # block-granular range requests (registry-throttled)
    pipeline_delay: float = 0.0  # child start lag behind parent start
    on_done: Optional[Callable[[float], None]] = None
    # Runnable-prefix milestone (paper §3.2): once ``notify_bytes`` of this
    # flow have landed, ``on_notify`` fires (at most once) — the dst can boot
    # while the rest of the payload keeps materializing in the background.
    notify_bytes: float = 0.0
    notified: bool = False
    on_notify: Optional[Callable[[float], None]] = None
    fid: int = -1  # dense engine-assigned id; all registries key on it
    t_last: float = 0.0  # time ``remaining`` was last settled
    epoch: int = 0  # bumped on every rate change; stale heap entries skip
    depth: int = 0  # streaming depth (hops behind the chain head); cached,
    # maintained by FlowSim.set_parent — never walk the parent chain for it
    children: list["_FlowState"] = field(default_factory=list)
    waiters: list["_FlowState"] = field(default_factory=list)  # gated on our start


class FlowSim:
    """Simulate one or more distribution plans sharing the same network."""

    def __init__(self, cfg: SimConfig | None = None, *, record_rates: bool = False) -> None:
        self.cfg = cfg or SimConfig()
        self.registry = self.cfg.registry_spec()
        self.now = 0.0
        self._flows: list[_FlowState] = []  # index == fid
        self._events: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self._slow_out: dict[str, float] = {}  # vm_id -> out cap override
        self.trace: list[tuple[float, str]] = []  # (time, event) log
        # Incremental-rate state ------------------------------------------------
        self._out: dict[str, dict[int, _FlowState]] = {}  # node -> active out flows
        self._in: dict[str, dict[int, _FlowState]] = {}  # node -> active in flows
        self._done_heap: list[tuple[float, int, int]] = []  # (t_finish, fid, epoch)
        self._notify_heap: list[tuple[float, int, int]] = []  # (t_prefix, fid, epoch)
        self._n_active = 0  # started-and-not-done flows (heap compaction bound)
        self._pending_dirty: dict[int, _FlowState] = {}
        self._record_trace = self.cfg.record_trace
        # Telemetry -------------------------------------------------------------
        self.events_processed = 0
        self.record_rates = record_rates
        self.rate_log: list[tuple[float, int, float]] = []  # (t, fid, new_rate)
        # Per-shard registry egress accounting: running sums and peaks keyed
        # by canonical shard id, plus the aggregate (sum across shards) peak.
        self._reg_out: dict[str, float] = {}
        self.peak_shard_egress: dict[str, float] = {}
        self.peak_registry_egress = 0.0
        # Per-VM NIC accounting: running out/in rate sums per node and the
        # peak utilization (rate / capacity) any VM NIC reached — the shared
        # pool's co-location pressure metric (cross-tree flows on one host).
        self._vm_out: dict[str, float] = {}
        self._vm_in: dict[str, float] = {}
        self.peak_nic_utilization = 0.0

    # ------------------------------------------------------------------
    def _src_key(self, node: str) -> str:
        """NIC-registry key for a flow source: registry aliases collapse to
        their canonical shard id so the legacy ``__registry__`` sentinel and
        shard 0 contend for (and are accounted against) the same source."""
        if is_registry_node(node):
            return self.registry.canonical(node)
        return node

    # ------------------------------------------------------------------
    def set_slow_vm(self, vm_id: str, out_cap: float) -> None:
        """Straggler injection: clamp a VM's egress capacity."""
        self._slow_out[vm_id] = out_cap
        for f in self._out.get(vm_id, {}).values():
            self._pending_dirty[f.fid] = f

    def clear_slow_vm(self, vm_id: str) -> None:
        self._slow_out.pop(vm_id, None)
        for f in self._out.get(vm_id, {}).values():
            self._pending_dirty[f.fid] = f

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, fn))

    def set_parent(self, st: _FlowState, parent: Optional[_FlowState]) -> None:
        """Attach a streaming dependency, keeping the child index consistent.

        Callers must use this (not ``st.parent = ...``) so that rate changes
        of the parent propagate to ``st`` through the incremental recompute.
        """
        if st.parent is not None:
            try:
                st.parent.children.remove(st)
            except ValueError:  # pragma: no cover - defensive
                pass
        st.parent = parent
        if parent is not None:
            parent.children.append(st)
        # Recompute the cached streaming depth for st and its descendants
        # (re-attachment moves the whole downstream chain).
        st.depth = parent.depth + 1 if parent is not None else 0
        stack = list(st.children)
        while stack:
            c = stack.pop()
            c.depth = c.parent.depth + 1
            stack.extend(c.children)
        if st.started and not st.done:
            # attaching mid-flight changes the parent-rate cap immediately
            self._pending_dirty[st.fid] = st

    # ------------------------------------------------------------------
    def add_plan(
        self,
        plan: DistributionPlan,
        *,
        t0: float = 0.0,
        on_node_done: Optional[Callable[[str, float], None]] = None,
        on_node_runnable: Optional[Callable[[str, float], None]] = None,
        coordinator_queues: Optional[dict[str, float]] = None,
    ) -> list[_FlowState]:
        """Register a provisioning wave starting at ``t0``.

        ``coordinator_queues`` carries serialization state for root/origin
        coordinators across plans (the Kraken-origin / DADI-root CPU queue).
        ``on_node_runnable`` fires per dst when its runnable block prefixes
        land (see :func:`wire_runnable`); with no prefix flows in the plan it
        is equivalent to firing at each dst's control release.
        """
        cfg = self.cfg
        coordinator_queues = coordinator_queues if coordinator_queues is not None else {}
        by_dst: dict[tuple[str, str], _FlowState] = {}
        states: list[_FlowState] = []
        for fl, release, block_mode in plan_releases(plan, cfg, t0, coordinator_queues):
            st = _FlowState(flow=fl, remaining=float(fl.bytes), total=float(fl.bytes),
                            start_after=release, block_mode=block_mode)
            states.append(st)
            # streaming dependency: dst of the parent flow == src of this
            # flow, matched per piece (multi-layer plans chain each layer's
            # stream to the parent's stream of the *same* layer; a parent
            # serving a layer from cache has no such flow → child unchained).
            by_dst.setdefault((fl.dst, fl.piece), st)
        if plan.streaming:
            block_t = cfg.block_size / cfg.vm_nic.in_cap
            for st in states:
                up = by_dst.get((st.flow.src, st.flow.piece))
                if up is not None:
                    self.set_parent(st, up)
                    st.start_after = max(st.start_after, t0)  # start gated below
                    # child may begin one block (+hop cost) after the parent
                    st.pipeline_delay = block_t + cfg.hop_latency
        for st in states:
            if on_node_done is not None:
                dst = st.flow.dst
                st.on_done = (
                    lambda t, dst=dst: on_node_done(dst, t)
                )
            st.fid = len(self._flows)
            self._flows.append(st)
            self._arm_start(st)
        wire_runnable(self, states, on_node_runnable)
        return states

    def _arm_start(self, st: _FlowState) -> None:
        if st.parent is not None and not st.parent.started:
            # Gated on the parent's start: no polling — the parent notifies
            # its waiters the moment it starts.
            st.parent.waiters.append(st)
            return
        t = max(st.start_after, self.now)
        if st.parent is not None:
            t = max(t, st.parent.t_start + st.pipeline_delay)
        self.schedule(t, lambda: self._start_flow(st))

    def _start_flow(self, st: _FlowState) -> None:
        if st.started or st.done:
            return
        if st.parent is not None and not st.parent.started:
            self._arm_start(st)
            return
        st.started = True
        st.t_start = self.now
        st.t_last = self.now
        self._n_active += 1
        f = st.flow
        skey = self._src_key(f.src)
        self._out.setdefault(skey, {})[st.fid] = st
        self._in.setdefault(f.dst, {})[st.fid] = st
        if self._record_trace:
            self.trace.append((self.now, f"start#{st.fid} {f.src}->{f.dst}/{f.piece}"))
        # Counts on both NICs changed: every flow sharing them is dirty.
        for g in self._out[skey].values():
            self._pending_dirty[g.fid] = g
        for g in self._in[f.dst].values():
            self._pending_dirty[g.fid] = g
        # Release children that were waiting for this flow to start.
        for w in st.waiters:
            if not w.started and not w.done:
                t = max(w.start_after, st.t_start + w.pipeline_delay, self.now)
                self.schedule(t, lambda w=w: self._start_flow(w))
        st.waiters.clear()

    # ------------------------------------------------------------------
    # Incremental rate maintenance
    # ------------------------------------------------------------------
    def _settle(self, f: _FlowState) -> None:
        """Bring ``remaining`` up to date at ``self.now`` under the old rate."""
        if self.now > f.t_last:
            if f.rate > 0.0:
                f.remaining = max(0.0, f.remaining - f.rate * (self.now - f.t_last))
            f.t_last = self.now

    def _recompute(self, dirty: dict[int, _FlowState]) -> None:
        """Re-rate the dirty closure, parents before streaming children."""
        cfg = self.cfg
        spec = self.registry
        touched_out: set[str] = set()
        touched_in: set[str] = set()
        wl: list[tuple[int, int]] = []
        queued: set[int] = set()
        for f in dirty.values():
            if f.started and not f.done:
                heapq.heappush(wl, (f.depth, f.fid))
                queued.add(f.fid)
        while wl:
            _, fid = heapq.heappop(wl)
            queued.discard(fid)
            f = self._flows[fid]
            if not f.started or f.done:
                continue
            src, dst = f.flow.src, f.flow.dst
            from_registry = is_registry_node(src)
            skey = spec.canonical(src) if from_registry else src
            n_out = len(self._out[skey])
            if from_registry:
                shard = shard_index(skey)
                cap_out = spec.egress_of(shard)
            else:
                cap_out = self._slow_out.get(src, cfg.vm_nic.out_cap)
            r = min(
                cfg.per_stream_cap,
                cap_out / n_out,
                cfg.vm_nic.in_cap / len(self._in[dst]),
                cfg.decompress_rate,
            )
            if from_registry and f.block_mode:
                # per-shard request throttle shared by the shard's streams
                r = min(r, cfg.block_size * spec.qps_of(shard) / n_out)
            if f.parent is not None and not f.parent.done:
                r = min(r, f.parent.rate)
            if r != f.rate:
                self._settle(f)
                delta = r - f.rate
                if from_registry:
                    self._reg_out[skey] = self._reg_out.get(skey, 0.0) + delta
                else:
                    self._vm_out[skey] = self._vm_out.get(skey, 0.0) + delta
                    touched_out.add(skey)
                self._vm_in[dst] = self._vm_in.get(dst, 0.0) + delta
                touched_in.add(dst)
                f.rate = r
                f.epoch += 1
                if r > 0.0:
                    heapq.heappush(
                        self._done_heap, (f.t_last + f.remaining / r, f.fid, f.epoch)
                    )
                    if f.on_notify is not None and not f.notified:
                        # prefix-landing estimate under the new rate; a
                        # threshold already passed clamps to "due now"
                        pend = f.notify_bytes - (f.total - f.remaining)
                        heapq.heappush(
                            self._notify_heap,
                            (f.t_last + max(0.0, pend) / r, f.fid, f.epoch),
                        )
                if self.record_rates:
                    self.rate_log.append((self.now, f.fid, r))
                # A parent-rate change propagates down the streaming chain.
                for c in f.children:
                    if c.started and not c.done and c.fid not in queued:
                        heapq.heappush(wl, (c.depth, c.fid))
                        queued.add(c.fid)
        if self._reg_out:
            for skey, egress in self._reg_out.items():
                if egress > self.peak_shard_egress.get(skey, 0.0):
                    self.peak_shard_egress[skey] = egress
            total = sum(self._reg_out.values())
            if total > self.peak_registry_egress:
                self.peak_registry_egress = total
        for node in touched_out:
            cap = self._slow_out.get(node, cfg.vm_nic.out_cap)
            if cap > 0 and cap != math.inf:
                u = self._vm_out[node] / cap
                if u > self.peak_nic_utilization:
                    self.peak_nic_utilization = u
        if cfg.vm_nic.in_cap > 0 and cfg.vm_nic.in_cap != math.inf:
            for node in touched_in:
                u = self._vm_in[node] / cfg.vm_nic.in_cap
                if u > self.peak_nic_utilization:
                    self.peak_nic_utilization = u

    # Compact ``_done_heap`` when stale (epoch-superseded or completed)
    # entries outnumber live flows ~4x.  Every rate change pushes a fresh
    # entry and only invalidates the old one lazily, so rate-churny runs
    # (straggler toggling, large shared-NIC fan-in) would otherwise grow the
    # heap without bound; the rebuild keeps only current-epoch entries of
    # active flows and re-heapifies — pop order is unchanged because stale
    # entries were never returned anyway.
    _HEAP_COMPACT_MIN = 64

    def _compact_done_heap(self) -> None:
        heap = [
            e
            for e in self._done_heap
            if not (f := self._flows[e[1]]).done and f.started and e[2] == f.epoch
        ]
        heapq.heapify(heap)
        self._done_heap = heap

    def _next_completion(self) -> float:
        """Earliest valid completion time (lazily dropping stale heap entries)."""
        if len(self._done_heap) > max(self._HEAP_COMPACT_MIN, 4 * self._n_active):
            self._compact_done_heap()
        while self._done_heap:
            t, fid, epoch = self._done_heap[0]
            f = self._flows[fid]
            if f.done or not f.started or epoch != f.epoch:
                heapq.heappop(self._done_heap)
                continue
            return t
        return math.inf

    def _next_notify(self) -> float:
        """Earliest valid runnable-prefix time (same lazy invalidation)."""
        if len(self._notify_heap) > max(
            self._HEAP_COMPACT_MIN, 4 * self._n_active
        ):
            self._notify_heap = [
                e
                for e in self._notify_heap
                if (f := self._flows[e[1]]).started
                and not f.done
                and not f.notified
                and e[2] == f.epoch
            ]
            heapq.heapify(self._notify_heap)
        while self._notify_heap:
            t, fid, epoch = self._notify_heap[0]
            f = self._flows[fid]
            if f.done or not f.started or f.notified or epoch != f.epoch:
                heapq.heappop(self._notify_heap)
                continue
            return t
        return math.inf

    def _complete(self, f: _FlowState) -> None:
        fl = f.flow
        f.done = True
        f.remaining = 0.0
        f.t_done = self.now
        f.t_last = self.now
        self._n_active -= 1
        skey = self._src_key(fl.src)
        del self._out[skey][f.fid]
        del self._in[fl.dst][f.fid]
        if is_registry_node(fl.src):
            self._reg_out[skey] -= f.rate
        else:
            self._vm_out[skey] = self._vm_out.get(skey, 0.0) - f.rate
        self._vm_in[fl.dst] = self._vm_in.get(fl.dst, 0.0) - f.rate
        self.events_processed += 1
        if self._record_trace:
            self.trace.append((self.now, f"done#{f.fid} {fl.src}->{fl.dst}/{fl.piece}"))
        # Freed shares on both NICs + the lifted parent-cap on children.
        for g in self._out[skey].values():
            self._pending_dirty[g.fid] = g
        for g in self._in[fl.dst].values():
            self._pending_dirty[g.fid] = g
        for c in f.children:
            if c.started and not c.done:
                self._pending_dirty[c.fid] = c

    # ------------------------------------------------------------------
    def run(self, until: float = math.inf) -> float:
        """Advance until no events remain (or ``until``); returns final time."""
        while True:
            if self._pending_dirty:
                dirty, self._pending_dirty = self._pending_dirty, {}
                self._recompute(dirty)
            t_done = self._next_completion()
            t_noti = self._next_notify()
            t_evt = self._events[0][0] if self._events else math.inf
            t_next = min(t_done, t_noti, t_evt)
            if t_next == math.inf or t_next > until:
                if until != math.inf and until > self.now:
                    self.now = until
                    for d in self._out.values():
                        for f in d.values():
                            self._settle(f)
                return self.now
            self.now = t_next
            if t_noti <= t_done and t_noti <= t_evt:
                # Runnable prefixes land before (or exactly at) the flow's
                # own completion — fire every notify due at this instant in
                # deterministic (time, fid) order, then loop.
                while self._notify_heap:
                    t, fid, epoch = self._notify_heap[0]
                    f = self._flows[fid]
                    if f.done or not f.started or f.notified or epoch != f.epoch:
                        heapq.heappop(self._notify_heap)
                        continue
                    if t > self.now:
                        break
                    heapq.heappop(self._notify_heap)
                    f.notified = True
                    self.events_processed += 1
                    if f.on_notify is not None:
                        f.on_notify(self.now)
            elif t_done <= t_evt:
                # Batch every completion due at this instant into one settle
                # pass: mark them all done first, then fire callbacks in
                # deterministic (time, fid) order, then re-rate the union of
                # their dirty closures once.
                batch: list[_FlowState] = []
                while self._done_heap:
                    t, fid, epoch = self._done_heap[0]
                    f = self._flows[fid]
                    if f.done or not f.started or epoch != f.epoch:
                        heapq.heappop(self._done_heap)
                        continue
                    if t <= self.now:
                        heapq.heappop(self._done_heap)
                        batch.append(f)
                    else:
                        break
                for f in batch:
                    self._complete(f)
                # A completed flow's prefix landed by definition: fire any
                # notify that has not gone out yet (runnable <= done always),
                # before the done callbacks.
                for f in batch:
                    if f.on_notify is not None and not f.notified:
                        f.notified = True
                        self.events_processed += 1
                        f.on_notify(self.now)
                for f in batch:
                    if f.on_done is not None:
                        f.on_done(self.now)
            else:
                while self._events and self._events[0][0] <= self.now + 1e-12:
                    _, _, fn = heapq.heappop(self._events)
                    self.events_processed += 1
                    fn()

    # ------------------------------------------------------------------
    def completion_times(self) -> dict[str, float]:
        """dst vm_id -> time its payload finished arriving."""
        out: dict[str, float] = {}
        for f in self._flows:
            if f.done:
                out[f.flow.dst] = max(out.get(f.flow.dst, 0.0), f.t_done)
        return out
