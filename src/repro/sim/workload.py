"""Application-level trace replay (paper §4.2, Figures 11-13).

Replays an RPS timeline against a simulated FaaS platform: a free VM pool,
a scheduler with an :class:`FTManager`, per-system provisioning over one
shared :class:`FlowSim` (so overlapping waves contend for the registry and
NICs exactly as in production), warm-instance serving with per-request
FIFO queueing, and idle-VM reclaim.

Resolution is one-second ticks for arrivals/serving; provisioning data
flows evolve in continuous time inside the FlowSim.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.core import FTManager, FunctionTree, VMInfo
from repro.core.topology import REGISTRY, DistributionPlan, Flow

from .cluster import WaveConfig
from .engine import FlowSim, SimConfig
from .traces import arrivals_for_second


@dataclass
class ReplayConfig:
    system: str = "faasnet"  # faasnet | baseline | on_demand
    function_id: str = "app"
    function_duration_s: float = 2.0
    vm_pool_size: int = 1000
    idle_reclaim_s: float = 7 * 60.0  # scaled-down 15-min production policy
    # The trace tests run against the region-scale production registry, not
    # the 128-VM devcluster one the microbenchmarks were calibrated against
    # (paper §4.1 vs §4.2 use different deployments).
    registry_out_cap: float = 6.5e9  # bytes/s (~52 Gbps region registry)
    registry_qps: float = 700.0
    max_reserve_per_tick: int = 64  # scheduler VM-reservation rate limit
    # Scale-out target: reserve until (instances + provisioning) reaches
    # ~target_factor × observed RPS (the paper's scheduler grows the IoT
    # burst to ~82 VMs at 100 RPS rather than one VM per queued request).
    vm_target_factor: float = 1.2
    wave: WaveConfig = field(default_factory=WaveConfig)
    seed: int = 0


@dataclass
class TickStats:
    t: int
    rps: float
    arrivals: int
    completed: int
    mean_response_s: float
    p99_response_s: float
    active_vms: int
    provisioning_vms: int
    ft_height: int


@dataclass
class _Instance:
    vm_id: str
    busy_until: float = 0.0
    idle_since: float = 0.0


class TraceReplay:
    def __init__(self, cfg: ReplayConfig) -> None:
        self.cfg = cfg
        w = cfg.wave
        self.sim = FlowSim(
            SimConfig(
                registry_out_cap=cfg.registry_out_cap,
                registry_qps=cfg.registry_qps,
                per_stream_cap=w.per_stream_cap,
                hop_latency=w.hop_latency,
            )
        )
        self.mgr = FTManager(vm_idle_reclaim_s=cfg.idle_reclaim_s)
        for i in range(cfg.vm_pool_size):
            self.mgr.add_free_vm(VMInfo(f"vm{i}"))
        self.instances: dict[str, _Instance] = {}  # warm, by vm_id
        self.provisioning: dict[str, float] = {}  # vm_id -> request time
        self._flow_of: dict[str, object] = {}  # vm_id -> _FlowState
        self.queue: deque[float] = deque()  # arrival times of waiting requests
        self.responses: list[tuple[float, float]] = []  # (completion_t, latency)
        self.prov_latencies: list[float] = []
        self.timeline: list[TickStats] = []

    # ------------------------------------------------------------------
    def _provision(self, vm_id: str, now: float) -> None:
        """Kick off provisioning of one VM at sim-time ``now``."""
        cfg, w = self.cfg, self.cfg.wave
        payload = int(w.image_bytes * w.startup_fraction)
        control = w.rpc.control_plane_total()
        if cfg.system == "faasnet":
            upstream = self.mgr.insert(cfg.function_id, vm_id, now)
            src = upstream if upstream is not None else REGISTRY
            streaming = True
        elif cfg.system in ("baseline", "on_demand"):
            if cfg.system == "baseline":
                payload = w.image_bytes
            src = REGISTRY
            streaming = cfg.system == "on_demand"
            # keep the FT for height reporting parity even if unused
            self.mgr.insert(cfg.function_id, vm_id, now)
        else:
            raise ValueError(cfg.system)
        plan = DistributionPlan(
            flows=[Flow(src, vm_id, "img", payload)],
            control_latency={vm_id: control},
            streaming=streaming,
        )
        self.provisioning[vm_id] = now

        def on_done(vm: str, t: float) -> None:
            extract = (
                w.image_bytes / w.image_extract_rate
                if cfg.system == "baseline"
                else w.rpc.image_load
            )
            ready = t + extract + w.container_start
            self.sim.schedule(ready, lambda: self._activate(vm, ready))

        states = self.sim.add_plan(plan, t0=now, on_node_done=on_done)
        # streaming dependency on the parent's still-running flow, if any
        if streaming and src != REGISTRY and src in self._flow_of:
            up = self._flow_of[src]
            if not up.done:  # type: ignore[attr-defined]
                # registered via the engine so parent rate changes propagate
                self.sim.set_parent(states[0], up)  # type: ignore[arg-type]
        self._flow_of[vm_id] = states[0]

    def _activate(self, vm_id: str, now: float) -> None:
        t_req = self.provisioning.pop(vm_id, now)
        self.prov_latencies.append(now - t_req)
        self.instances[vm_id] = _Instance(vm_id, busy_until=now, idle_since=now)

    def _reclaim(self, now: float) -> None:
        cfg = self.cfg
        for vm_id, inst in list(self.instances.items()):
            if inst.busy_until <= now and now - inst.idle_since >= cfg.idle_reclaim_s:
                del self.instances[vm_id]
                self._flow_of.pop(vm_id, None)
                self.mgr.delete(cfg.function_id, vm_id)
                self.mgr.release_vm(vm_id)
                self.mgr.stats["reclaims"] += 1

    # ------------------------------------------------------------------
    def run(self, rps_trace: list[float]) -> list[TickStats]:
        cfg = self.cfg
        dur = cfg.function_duration_s
        for t, rps in enumerate(rps_trace):
            now = float(t)
            self.sim.run(until=now)  # advance flows/activations to this tick
            # arrivals
            n_arr = arrivals_for_second(rps, t, cfg.seed)
            for _ in range(n_arr):
                self.queue.append(now)
            # serve from queue with idle instances
            completed = 0
            lat_samples: list[float] = []
            for inst in self.instances.values():
                if not self.queue:
                    break
                if inst.busy_until <= now:
                    arrival = self.queue.popleft()
                    resp = (now - arrival) + dur
                    inst.busy_until = now + dur
                    inst.idle_since = now + dur
                    self.responses.append((now + dur, resp))
                    lat_samples.append(resp)
                    completed += 1
            # scale out if backlog remains: each in-flight provisioning VM
            # will absorb one queued request when it comes up, so the deficit
            # is backlog minus idle capacity minus in-flight reservations.
            deficit = (
                len(self.queue)
                - sum(1 for i in self.instances.values() if i.busy_until <= now)
                - len(self.provisioning)
            )
            # cap total footprint at ~target_factor × concurrency demand
            # (Little's law: rps × service time)
            target = int(cfg.vm_target_factor * max(rps, n_arr) * dur) + 1
            headroom = target - (len(self.instances) + len(self.provisioning))
            deficit = min(deficit, max(0, headroom))
            for _ in range(min(max(0, deficit), cfg.max_reserve_per_tick)):
                vm = self.mgr.reserve_vm(now)
                if vm is None:
                    break
                self._provision(vm.vm_id, now)
            self._reclaim(now)
            ft = self.mgr.trees.get(cfg.function_id)
            lat_samples.sort()
            self.timeline.append(
                TickStats(
                    t=t,
                    rps=rps,
                    arrivals=n_arr,
                    completed=completed,
                    mean_response_s=(
                        sum(lat_samples) / len(lat_samples) if lat_samples else 0.0
                    ),
                    p99_response_s=(
                        lat_samples[int(0.99 * (len(lat_samples) - 1))]
                        if lat_samples
                        else 0.0
                    ),
                    active_vms=len(self.instances) + len(self.provisioning),
                    provisioning_vms=len(self.provisioning),
                    ft_height=ft.height if ft is not None else 0,
                )
            )
        return self.timeline

    # ------------------------------------------------------------------
    def recovery_time(self, burst_t: int, normal_s: float = 3.0) -> float:
        """Seconds after ``burst_t`` until mean response returns ≤ normal_s."""
        for ts in self.timeline:
            if ts.t > burst_t and ts.mean_response_s > 0 and ts.mean_response_s <= normal_s:
                return ts.t - burst_t
        return float("inf")
