"""Single-tenant trace replay (paper §4.2, Figures 11-13).

:class:`TraceReplay` replays one RPS timeline against the simulated FaaS
platform.  It is a thin facade over
:class:`repro.sim.multi_tenant.MultiTenantReplay` with exactly one tenant —
ONE code path implements arrivals, FIFO serving, scale-out, per-system
provisioning over the shared :class:`FlowSim`, and idle reclaim, so the
single-tenant figures and the multi-tenant harness can never diverge.

Resolution is one-second ticks for arrivals/serving; provisioning data
flows evolve in continuous time inside the FlowSim.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.registry import RegistrySpec

from .cluster import WaveConfig
from .engine import GBPS
from .multi_tenant import (
    MultiTenantConfig,
    MultiTenantReplay,
    ServingConfig,
    TenantConfig,
    TickStats,
)

__all__ = ["ReplayConfig", "TickStats", "TraceReplay"]


@dataclass
class ReplayConfig:
    system: str = "faasnet"  # faasnet | baseline | on_demand
    function_id: str = "app"
    function_duration_s: float = 2.0
    vm_pool_size: int = 1000
    idle_reclaim_s: float = 7 * 60.0  # scaled-down 15-min production policy
    # The trace tests run against the region-scale production registry, not
    # the 128-VM devcluster one the microbenchmarks were calibrated against
    # (paper §4.1 vs §4.2 use different deployments).
    registry_out_cap: float = 52 * GBPS  # bytes/s (52 Gbps region registry)
    registry_qps: float = 700.0
    # Sharded registry (None = legacy 1 shard built from the caps above).
    registry: Optional[RegistrySpec] = None
    # Pool placement mode + per-instance memory (MB).  With one tenant,
    # "shared" degenerates to exclusive leasing bit-identically (every warm
    # VM already hosts the function, so pick_vm_for always falls back to a
    # fresh reservation — pinned by tests/test_placement.py), but pays a
    # full heap drain per reservation doing so; a single-tenant replay IS
    # exclusive, so that is the default here (multi-tenant defaults stay
    # "shared").
    placement: str = "exclusive"
    mem_mb: int = 512
    # Reclaim policy: "fixed" (idle_reclaim_s TTL) or "histogram".
    reclaim: str = "fixed"
    max_reserve_per_tick: int = 64  # scheduler VM-reservation rate limit
    # Scale-out target: reserve until (instances + provisioning) reaches
    # ~target_factor × observed RPS (the paper's scheduler grows the IoT
    # burst to ~82 VMs at 100 RPS rather than one VM per queued request).
    vm_target_factor: float = 1.2
    wave: WaveConfig = field(default_factory=WaveConfig)
    # Request-level serving knobs (sub-tick dispatch, CPU slots, herd
    # control); ``None`` keeps the legacy tick-quantized dispatch loop
    # bit-identically — see :class:`repro.sim.multi_tenant.ServingConfig`.
    serving: Optional[ServingConfig] = None
    seed: int = 0


class TraceReplay:
    """Replay one tenant's RPS trace; see :class:`MultiTenantReplay`."""

    def __init__(self, cfg: ReplayConfig) -> None:
        self.cfg = cfg
        self.timeline: list[TickStats] = []
        self.responses: list[tuple[float, float]] = []  # (completion_t, latency)
        self.prov_latencies: list[float] = []
        self._first_req_t: float = float("inf")
        self._last_ready_t: float = float("-inf")
        self.sim = None  # the shared FlowSim, exposed after run()
        self.mgr = None  # the FTManager, exposed after run()

    # ------------------------------------------------------------------
    def run(self, rps_trace: list[float]) -> list[TickStats]:
        cfg = self.cfg
        replay = MultiTenantReplay(
            MultiTenantConfig(
                tenants=[
                    TenantConfig(
                        function_id=cfg.function_id,
                        trace=list(rps_trace),
                        seed=cfg.seed,
                        function_duration_s=cfg.function_duration_s,
                        vm_target_factor=cfg.vm_target_factor,
                        max_reserve_per_tick=cfg.max_reserve_per_tick,
                        mem_mb=cfg.mem_mb,
                    )
                ],
                system=cfg.system,
                vm_pool_size=cfg.vm_pool_size,
                idle_reclaim_s=cfg.idle_reclaim_s,
                registry_out_cap=cfg.registry_out_cap,
                registry_qps=cfg.registry_qps,
                registry=cfg.registry,
                placement=cfg.placement,
                reclaim=cfg.reclaim,
                wave=cfg.wave,
                serving=cfg.serving,
            )
        )
        replay.run()
        tenant = replay.tenants[0]
        self.sim, self.mgr = replay.sim, replay.mgr
        self.timeline = tenant.timeline
        self.responses = tenant.responses
        self.prov_latencies = tenant.prov_latencies
        self._first_req_t = tenant.first_req_t
        self._last_ready_t = tenant.last_ready_t
        return self.timeline

    def prov_makespan_s(self) -> float:
        """First reservation -> last container ready (0 if nothing provisioned)."""
        if not self.prov_latencies:
            return 0.0
        return self._last_ready_t - self._first_req_t

    # ------------------------------------------------------------------
    def recovery_time(self, burst_t: int, normal_s: float = 3.0) -> float:
        """Seconds after ``burst_t`` until mean response returns ≤ normal_s."""
        for ts in self.timeline:
            if ts.t > burst_t and ts.mean_response_s > 0 and ts.mean_response_s <= normal_s:
                return ts.t - burst_t
        return float("inf")
