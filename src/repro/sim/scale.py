"""Cluster-scale scenario driver: N functions × M VMs in one burst (§4.2).

The paper's headline deployment result is provisioning 2500 function
containers across 1000 VMs in 8.3 s.  ``run_scale`` reproduces that shape:

  * an :class:`~repro.core.ft_manager.FTManager` owns the VM pool and one
    FunctionTree per function (placement honours the ≤20 functions/VM
    production limit);
  * optional join/leave churn mutates the trees through the manager before
    the wave is planned (delete → AVL repair → re-insert at the frontier),
    exercising ``on_reparent`` exactly the way the provisioning layer does;
  * every function's :func:`~repro.core.topology.faasnet_plan` is added to
    ONE shared :class:`~repro.sim.engine.FlowSim`, so overlapping FTs
    contend for per-VM NICs and the registry exactly as in production;
  * the result reports provisioning makespan, simulator event throughput
    and peak registry egress — the numbers ``benchmarks/bench_scale_1000.py``
    writes to ``BENCH_scale.json``.

Runs are bit-deterministic for a fixed :class:`ScaleConfig` (seeded RNG +
the engine's (time, seq) ordering); ``tests/test_scale.py`` pins that with
a golden two-run comparison of the full event trace.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field

from repro.core import FTManager, VMInfo
from repro.core.registry import RegistrySpec, ShardResolver
from repro.core.topology import faasnet_block_plan, faasnet_plan

from .cluster import WaveConfig
from .engine import SimConfig, make_sim


@dataclass
class ScaleConfig:
    """Workload shape + calibration for one cluster-scale burst."""

    n_vms: int = 1000
    n_functions: int = 5
    containers_per_function: int = 500  # 5 × 500 = the paper's 2500
    churn_ops: int = 0  # leave/re-join pairs applied before the wave
    stagger_s: float = 0.0  # inter-function wave arrival offset
    seed: int = 0
    max_functions_per_vm: int = 20  # production placement limit
    wave: WaveConfig = field(default_factory=WaveConfig)
    # Block-level provisioning: one ImageSpec per function (len ==
    # n_functions).  Each function's wave fetches its image's missing
    # blocks per layer and reports the runnable-prefix makespan alongside
    # full arrival.  ``None`` (default) keeps the scalar payload model
    # bit-identically.
    images: "list | None" = None  # list[repro.core.image.ImageSpec]

    def total_containers(self) -> int:
        return self.n_functions * min(self.containers_per_function, self.n_vms)


def mega_burst_config(seed: int = 0, churn_ops: int = 200) -> ScaleConfig:
    """10× paper scale: 10k VMs, 25 functions, 100k containers, one burst.

    Exercises the O(log n) control plane (frontier/index FunctionTrees,
    incremental FTManager) far past the paper's §4.2 shape; the seed
    BFS-scan control plane could not stand this scenario up in minutes.
    """
    return ScaleConfig(
        n_vms=10_000,
        n_functions=25,
        containers_per_function=4_000,
        churn_ops=churn_ops,
        seed=seed,
        max_functions_per_vm=25,
    )


def giga_burst_config(
    seed: int = 0, churn_ops: int = 0, engine: str = "vector"
) -> ScaleConfig:
    """100× paper scale: 100k VMs, 25 functions, 1M containers.

    The production-fleet tier the vectorized engine exists for (ROADMAP:
    "100k VMs / 1M containers ... in minutes").  The 25 function waves
    arrive as a burst train (``stagger_s=2.0``) — the §4.2 production
    regime where scale-out requests queue at the scheduler rather than
    landing in one instant — which keeps per-VM tree overlap low and the
    per-instant completion batches wide.  ~2M flow events; the
    per-event-Python incremental engine takes this tier at ~20k events/s
    while the array-based backend batches the same-timestamp waves, so the
    tier defaults to ``engine="vector"`` and drops the per-event text log
    (``record_trace=False`` — two million trace tuples are benchmark
    ballast, and golden hashes are pinned at the smaller tiers).
    """
    return ScaleConfig(
        n_vms=100_000,
        n_functions=25,
        containers_per_function=40_000,
        churn_ops=churn_ops,
        stagger_s=2.0,
        seed=seed,
        max_functions_per_vm=40,
        wave=WaveConfig(engine=engine, record_trace=False),
    )


def multi_tenant_config(
    seed: int = 0,
    *,
    n_tenants: int = 8,
    vm_pool_size: int = 2000,
    minutes: int = 25,
    scale: float = 0.25,
    system: str = "faasnet",
    failover_at: int | None = 12 * 60,
    check_partition: bool = False,
    registry: "RegistrySpec | None" = None,
    placement: str = "shared",
    ft_aware_placement: bool = True,
    reclaim: str = "fixed",
) -> "MultiTenantConfig":
    """The trace-driven companion of :func:`mega_burst_config` (§4.2 waves).

    N tenants cycle through the four trace shapes — IoT, synthetic gaming,
    diurnal (phase-staggered so peaks only partially overlap) and constant
    background — all contending for one 2000-VM pool, one registry and one
    FlowSim, with a scheduler failover mid-wave by default.  Each trace
    shape carries a distinct per-instance memory requirement (gaming 2048 MB
    … constant 256 MB), so under ``placement="shared"`` co-location on the
    4 GB VMs is genuinely memory-constrained; ``placement="exclusive"``
    reproduces the legacy one-VM-one-tenant leasing.  The returned config
    drives :class:`repro.sim.multi_tenant.MultiTenantReplay`;
    ``benchmarks/bench_trace_replay.py`` and
    ``benchmarks/bench_placement.py`` are its CLI twins and the
    ``--runslow`` soak in ``tests/test_multi_tenant.py`` runs it with
    ``check_partition=True``.
    """
    from .multi_tenant import MultiTenantConfig, TenantConfig
    from .traces import (
        constant_trace,
        diurnal_trace,
        iot_trace,
        synthetic_gaming_trace,
    )

    duration = minutes * 60
    tenants: list[TenantConfig] = []
    for i in range(n_tenants):
        kind = i % 4
        if kind == 0:
            trace = iot_trace(scale=scale)[:duration]
            name, mem_mb = "iot", 512
        elif kind == 1:
            trace = synthetic_gaming_trace(scale=4 * scale)[:duration]
            name, mem_mb = "gaming", 2048
        elif kind == 2:
            trace = diurnal_trace(
                duration_s=duration, phase_s=150 * i, scale=4 * scale
            )
            name, mem_mb = "diurnal", 1024
        else:
            trace = constant_trace(duration_s=duration, scale=4 * scale)
            name, mem_mb = "constant", 256
        tenants.append(
            TenantConfig(
                function_id=f"{name}{i}",
                trace=trace,
                seed=seed * 1000 + i,  # decorrelated arrival jitter per tenant
                mem_mb=mem_mb,
            )
        )
    return MultiTenantConfig(
        tenants=tenants,
        system=system,
        vm_pool_size=vm_pool_size,
        idle_reclaim_s=7 * 60.0,
        failover_at=failover_at,
        check_partition=check_partition,
        registry=registry,
        placement=placement,
        ft_aware_placement=ft_aware_placement,
        reclaim=reclaim,
    )


def serving_config(
    seed: int = 0,
    *,
    herd_control: bool = True,
    cpu_slots: int = 2,
    drain_budget_s: float = 15.0,
    rate_window_s: int = 30,
    **kwargs,
) -> "MultiTenantConfig":
    """The request-serving companion of :func:`multi_tenant_config`.

    Same 8-tenant diurnal mix against the shared 2000-VM pool, but with a
    :class:`repro.sim.multi_tenant.ServingConfig` attached: arrivals are
    stamped at sub-second offsets, the per-function FIFO queues drain
    against instance free times (end-to-end p50/p99 response is the
    headline metric instead of provisioning makespan), co-located requests
    contend for per-VM CPU slots and scale-out runs in herd-controlled
    provisioning waves.  ``herd_control=False`` keeps sub-tick dispatch but
    reverts admission to the legacy one-reservation-per-deficit-unit rule —
    the comparison baseline ``benchmarks/bench_serving.py`` measures
    against.  Remaining ``kwargs`` pass through to
    :func:`multi_tenant_config`.
    """
    from .multi_tenant import ServingConfig

    cfg = multi_tenant_config(seed, **kwargs)
    cfg.serving = ServingConfig(
        cpu_slots=cpu_slots,
        herd_control=herd_control,
        drain_budget_s=drain_budget_s,
        rate_window_s=rate_window_s,
    )
    return cfg


def giga_replay_config(
    seed: int = 0,
    *,
    n_tenants: int = 32,
    minutes: int = 8,
    scale: float = 0.5,
    engine: str = "vector",
) -> "MultiTenantConfig":
    """Full trace replay at the giga tier: serving + blocks, 100k-VM pool.

    The end-to-end companion of :func:`giga_burst_config`: where the burst
    tier stresses raw flow-event throughput on isolated waves, this drives
    every subsystem at once through one shared :class:`VectorFlowSim` —
    32 tenants of sub-tick request serving (CPU slots, herd-controlled
    scale-out), block-level on-demand provisioning against four shared base
    images, idle reclaim and a mid-run scheduler failover, all against the
    100k-VM fleet.  Exists to prove the wide-front vector engine under the
    replay loop's interleaved ``run(until=...)`` stepping rather than one
    monolithic ``run()``; recorded as ``giga_replay`` in
    ``BENCH_scale.json`` by ``benchmarks/bench_scale_1000.py --giga``.
    """
    from repro.core.image import shared_base_images

    cfg = serving_config(
        seed,
        n_tenants=n_tenants,
        vm_pool_size=100_000,
        minutes=minutes,
        scale=scale,
        failover_at=(minutes * 60) // 2,  # default 720s outlives short replays
    )
    cfg.wave.engine = engine
    cfg.wave.record_trace = False
    images = shared_base_images(
        n_tenants, 4, image_bytes=cfg.wave.image_bytes
    )
    cfg.images = {
        t.function_id: img for t, img in zip(cfg.tenants, images)
    }
    return cfg


@dataclass
class ScaleResult:
    makespan: float  # sim seconds: last payload fully fetched
    provision_makespan: float  # + container start + image load
    per_function: dict[str, float]  # function id -> fetch makespan
    n_containers: int
    n_flows: int
    events: int  # engine events processed
    wall_s: float  # wall-clock seconds inside FlowSim.run
    events_per_s: float
    peak_registry_egress: float  # bytes/s, aggregate across shards
    reparents: int  # on_reparent notifications during churn
    tree_stats: dict[str, dict[str, int]]
    trace: list  # the engine's (time, event) log — golden-test fodder
    # Control-plane timings (wall-clock, seconds) ----------------------
    build_s: float = 0.0  # stand up VM pool + all FunctionTrees
    churn_s: float = 0.0  # apply_churn total
    churn_op_s: float = 0.0  # mean latency of one delete+reinsert churn op
    # Per-shard peak egress (shard id -> bytes/s); one entry per shard hit.
    peak_shard_egress: dict[str, float] = field(default_factory=dict)
    engine: str = "incremental"  # backend that produced this result
    # Block mode only (cfg.images set): sim time when the last container's
    # boot working set landed — the §3.2 runnable milestone.  0.0 otherwise.
    runnable_makespan: float = 0.0
    # Vector engines only ({} otherwise): per-run recompute dispatch
    # telemetry — scalar-vs-vector front counts, per-front flow totals, the
    # front-width histogram (bucket k = widths [2^(k-1), 2^k)) and the
    # retired per-depth sweep's dispatch count (``legacy_levels``), so
    # BENCH_scale.json can prove the wide-front batching claim from a run's
    # own numbers: ``legacy_levels / (fronts_scalar + fronts_vector)`` is
    # the dispatch-reduction factor.
    dispatch_stats: dict = field(default_factory=dict)


def _function_ids(cfg: ScaleConfig) -> list[str]:
    return [f"fn{i}" for i in range(cfg.n_functions)]


def build_manager(cfg: ScaleConfig) -> tuple[FTManager, dict[str, list[str]]]:
    """Stand up the VM pool and one FT per function via the manager API."""
    if cfg.n_vms < 1 or cfg.n_functions < 1 or cfg.containers_per_function < 1:
        raise ValueError(
            f"scale scenario needs >=1 VM, function and container per function "
            f"(got n_vms={cfg.n_vms}, n_functions={cfg.n_functions}, "
            f"containers_per_function={cfg.containers_per_function})"
        )
    rng = random.Random(cfg.seed)
    mgr = FTManager(max_functions_per_vm=cfg.max_functions_per_vm)
    vms = [f"vm{i:04d}" for i in range(cfg.n_vms)]
    for v in vms:
        mgr.add_free_vm(VMInfo(v))
    for _ in vms:  # whole pool reserved for the burst
        mgr.reserve_vm()
    members: dict[str, list[str]] = {}
    per_fn = min(cfg.containers_per_function, cfg.n_vms)
    for fid in _function_ids(cfg):
        chosen = rng.sample(vms, per_fn)
        mgr.bulk_insert(fid, chosen)
        members[fid] = chosen
    return mgr, members


def apply_churn(mgr: FTManager, members: dict[str, list[str]], cfg: ScaleConfig) -> int:
    """Leave/re-join churn through the manager; returns reparent count.

    Each op deletes a random member of a random tree (AVL repair fires
    ``on_reparent`` for every node whose upstream moved) and re-inserts it
    at the BFS frontier — the paper's VM reclaim + later re-activation.
    """
    if cfg.churn_ops <= 0:
        return 0
    rng = random.Random(cfg.seed + 1)
    reparents = 0

    def count(node, old_parent, new_parent):  # noqa: ANN001 - FT callback
        nonlocal reparents
        reparents += 1

    fids = _function_ids(cfg)
    for ft in mgr.trees.values():
        ft.on_reparent.append(count)
    try:
        for _ in range(cfg.churn_ops):
            fid = fids[rng.randrange(len(fids))]
            vms_in = members[fid]
            victim = vms_in[rng.randrange(len(vms_in))]
            mgr.delete(fid, victim)
            mgr.insert(fid, victim)
    finally:
        for ft in mgr.trees.values():
            if count in ft.on_reparent:
                ft.on_reparent.remove(count)
    return reparents


def run_scale(cfg: ScaleConfig | None = None) -> ScaleResult:
    """Provision ``n_functions`` × ``containers_per_function`` in one burst."""
    cfg = cfg or ScaleConfig()
    w = cfg.wave
    t_build0 = time.perf_counter()
    mgr, members = build_manager(cfg)
    build_s = time.perf_counter() - t_build0
    t_churn0 = time.perf_counter()
    reparents = apply_churn(mgr, members, cfg)
    churn_s = time.perf_counter() - t_churn0

    spec = w.registry_spec()
    # ONE resolver across all per-function plans: stateful placement policies
    # (least_loaded / replicated) see the whole burst's assignments.
    resolver = ShardResolver(spec)
    sim = make_sim(
        SimConfig(
            registry=spec,
            per_stream_cap=w.per_stream_cap,
            hop_latency=w.hop_latency,
            engine=w.engine,
            record_trace=w.record_trace,
            vector_scalar_cutoff=w.vector_scalar_cutoff,
        )
    )
    control = w.rpc.control_plane_total()
    images = cfg.images
    if images is not None and len(images) != cfg.n_functions:
        raise ValueError(
            f"need one ImageSpec per function: {len(images)} images, "
            f"{cfg.n_functions} functions"
        )
    cache = None
    if images is not None:
        from repro.core.image import BlockCache

        cache = BlockCache()
    done_at: dict[tuple[str, str], float] = {}
    runnable_at: dict[tuple[str, str], float] = {}

    def accum_done(fid: str, vm: str, t: float) -> None:
        # block plans fire once per layer flow; the max is full arrival
        key = (fid, vm)
        if t > done_at.get(key, float("-inf")):
            done_at[key] = t

    n_flows = 0
    for i, fid in enumerate(_function_ids(cfg)):
        if images is not None:
            plan = faasnet_block_plan(
                mgr.trees[fid],
                image=images[i],
                cache=cache,
                manifest_latency=w.rpc.manifest_fetch,
                registry=resolver,
            )
            n_flows += len(plan.flows)
            sim.add_plan(
                plan,
                t0=control + i * cfg.stagger_s,
                on_node_done=lambda vm, t, fid=fid: accum_done(fid, vm, t),
                on_node_runnable=lambda vm, t, fid=fid: runnable_at.setdefault(
                    (fid, vm), t
                ),
            )
            continue
        plan = faasnet_plan(
            mgr.trees[fid],
            image_bytes=w.image_bytes,
            startup_fraction=w.startup_fraction,
            manifest_latency=w.rpc.manifest_fetch,
            piece=fid,
            registry=resolver,
        )
        n_flows += len(plan.flows)
        sim.add_plan(
            plan,
            t0=control + i * cfg.stagger_s,
            on_node_done=lambda vm, t, fid=fid: done_at.setdefault((fid, vm), t),
        )

    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0

    expected = cfg.total_containers()
    complete = len(runnable_at) if images is not None else len(done_at)
    if complete != expected:  # pragma: no cover - indicates a sim bug
        raise RuntimeError(
            f"scale wave incomplete: {complete}/{expected} containers done"
        )
    per_function = {fid: 0.0 for fid in _function_ids(cfg)}
    for (fid, _vm), t in done_at.items():
        per_function[fid] = max(per_function[fid], t)
    makespan = max(per_function.values())
    return ScaleResult(
        makespan=makespan,
        provision_makespan=makespan + w.container_start + w.rpc.image_load,
        per_function=per_function,
        n_containers=expected,
        n_flows=n_flows,
        events=sim.events_processed,
        wall_s=wall,
        events_per_s=sim.events_processed / wall if wall > 0 else float("inf"),
        peak_registry_egress=sim.peak_registry_egress,
        peak_shard_egress=dict(sim.peak_shard_egress),
        reparents=reparents,
        tree_stats=mgr.tree_stats(),
        trace=sim.trace,
        build_s=build_s,
        churn_s=churn_s,
        churn_op_s=churn_s / cfg.churn_ops if cfg.churn_ops > 0 else 0.0,
        engine=w.engine,
        runnable_makespan=max(runnable_at.values()) if runnable_at else 0.0,
        dispatch_stats=_snapshot_dispatch_stats(sim),
    )


def _snapshot_dispatch_stats(sim) -> dict:
    """Deep-copied engine dispatch telemetry ({} for non-vector engines)."""
    ds = getattr(sim, "dispatch_stats", None)
    if not ds:
        return {}
    out = dict(ds)
    out["front_width_hist"] = dict(ds.get("front_width_hist", {}))
    fronts = out.get("fronts_scalar", 0) + out.get("fronts_vector", 0)
    out["dispatch_reduction"] = (
        out.get("legacy_levels", 0) / fronts if fronts else 0.0
    )
    return out
