"""Reference full-recompute fluid-flow engine (the pre-incremental design).

This is the original O(F)-work-per-event engine: after *every* event it
recomputes the rate of *every* active flow and linearly advances every
flow's remaining bytes.  It is quadratic in the number of flows and far too
slow for the 1000-VM experiments, but it is trivially correct with respect
to the documented rate model, so it is kept as the oracle the incremental
engine (:class:`repro.sim.engine.FlowSim`) is differential-tested against
(see ``tests/test_scale.py``).

Both engines share :class:`~repro.sim.engine.SimConfig` and expose the same
public API (``add_plan`` / ``set_parent`` / ``run`` / ``completion_times``)
plus an optional per-flow rate log (``record_rates=True``) used by the
equivalence tests, and both account per-registry-shard egress
(``peak_shard_egress`` / ``peak_registry_egress``) — here recomputed from
scratch every event, making this the oracle for the incremental engine's
delta-maintained per-shard sums (``tests/test_registry.py``).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.registry import is_registry_node, shard_index
from repro.core.topology import DistributionPlan, Flow

from .engine import SimConfig, plan_releases, wire_runnable


@dataclass(eq=False)
class _RefFlowState:
    flow: Flow
    remaining: float
    total: float
    start_after: float  # control-plane release time
    parent: Optional["_RefFlowState"] = None  # streaming dependency
    started: bool = False
    done: bool = False
    t_start: float = math.inf
    t_done: float = math.inf
    rate: float = 0.0
    block_mode: bool = False  # block-granular range requests (registry-throttled)
    pipeline_delay: float = 0.0
    on_done: Optional[Callable[[float], None]] = None
    fid: int = -1  # index into the engine's flow list (rate-log key)
    # Runnable-prefix milestone (paper §3.2); see engine._FlowState.
    notify_bytes: float = 0.0
    notified: bool = False
    on_notify: Optional[Callable[[float], None]] = None


class ReferenceFlowSim:
    """Full-recompute oracle: same rate model, O(flows) work per event."""

    def __init__(self, cfg: SimConfig | None = None, *, record_rates: bool = False) -> None:
        self.cfg = cfg or SimConfig()
        self.registry = self.cfg.registry_spec()
        self.now = 0.0
        self._flows: list[_RefFlowState] = []
        self._events: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self._slow_out: dict[str, float] = {}  # vm_id -> out cap override
        self.trace: list[tuple[float, str]] = []  # (time, event) log
        self.record_rates = record_rates
        self.rate_log: list[tuple[float, int, float]] = []  # (t, fid, new_rate)
        # Per-shard egress telemetry, recomputed from scratch every event —
        # the oracle for the incremental engine's per-shard accounting.
        self.peak_shard_egress: dict[str, float] = {}
        self.peak_registry_egress = 0.0

    # ------------------------------------------------------------------
    def set_slow_vm(self, vm_id: str, out_cap: float) -> None:
        """Straggler injection: clamp a VM's egress capacity."""
        self._slow_out[vm_id] = out_cap

    def clear_slow_vm(self, vm_id: str) -> None:
        self._slow_out.pop(vm_id, None)

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, fn))

    def set_parent(self, st: _RefFlowState, parent: Optional[_RefFlowState]) -> None:
        st.parent = parent

    # ------------------------------------------------------------------
    def add_plan(
        self,
        plan: DistributionPlan,
        *,
        t0: float = 0.0,
        on_node_done: Optional[Callable[[str, float], None]] = None,
        on_node_runnable: Optional[Callable[[str, float], None]] = None,
        coordinator_queues: Optional[dict[str, float]] = None,
    ) -> list[_RefFlowState]:
        """Register a provisioning wave starting at ``t0``."""
        cfg = self.cfg
        coordinator_queues = coordinator_queues if coordinator_queues is not None else {}
        by_dst: dict[tuple[str, str], _RefFlowState] = {}
        states: list[_RefFlowState] = []
        for fl, release, block_mode in plan_releases(plan, cfg, t0, coordinator_queues):
            st = _RefFlowState(flow=fl, remaining=float(fl.bytes), total=float(fl.bytes),
                               start_after=release, block_mode=block_mode)
            states.append(st)
            # streaming dependency: dst of the parent flow == src of this
            # flow, matched per piece (see FlowSim.add_plan)
            by_dst.setdefault((fl.dst, fl.piece), st)
        if plan.streaming:
            block_t = cfg.block_size / cfg.vm_nic.in_cap
            for st in states:
                up = by_dst.get((st.flow.src, st.flow.piece))
                if up is not None:
                    st.parent = up
                    st.start_after = max(st.start_after, t0)  # start gated below
                    # child may begin one block (+hop cost) after the parent
                    st.pipeline_delay = block_t + cfg.hop_latency
        for st in states:
            if on_node_done is not None:
                dst = st.flow.dst
                st.on_done = (
                    lambda t, dst=dst: on_node_done(dst, t)
                )
            st.fid = len(self._flows)
            self._flows.append(st)
            self._arm_start(st)
        wire_runnable(self, states, on_node_runnable)
        return states

    def _arm_start(self, st: _RefFlowState) -> None:
        if st.parent is None:
            self.schedule(max(st.start_after, self.now), lambda: self._start_flow(st))
        else:
            # started when parent starts + one block-time (and own release time)
            def try_start() -> None:
                if st.started or st.done:
                    return
                p = st.parent
                if p.started:
                    t = max(st.start_after, p.t_start + st.pipeline_delay, self.now)
                    self.schedule(t, lambda: self._start_flow(st))
                else:
                    self.schedule(self.now + 1e-4, try_start)  # poll cheaply

            self.schedule(max(st.start_after, self.now), try_start)

    def _start_flow(self, st: _RefFlowState) -> None:
        if st.started or st.done:
            return
        if st.parent is not None and not st.parent.started:
            self._arm_start(st)
            return
        st.started = True
        st.t_start = self.now

    # ------------------------------------------------------------------
    # Rate computation (called after every event)
    # ------------------------------------------------------------------
    def _src_key(self, node: str) -> str:
        """Canonical source key: registry aliases collapse to their shard."""
        if is_registry_node(node):
            return self.registry.canonical(node)
        return node

    def _recompute_rates(self) -> None:
        cfg = self.cfg
        spec = self.registry
        out_count: dict[str, int] = {}
        in_count: dict[str, int] = {}
        active = [f for f in self._flows if f.started and not f.done]
        for f in active:
            skey = self._src_key(f.flow.src)
            out_count[skey] = out_count.get(skey, 0) + 1
            in_count[f.flow.dst] = in_count.get(f.flow.dst, 0) + 1

        def out_cap(node: str) -> float:
            if is_registry_node(node):
                return spec.egress_of(shard_index(spec.canonical(node)))
            return self._slow_out.get(node, cfg.vm_nic.out_cap)

        # topological order: parents before children (tree depth is small)
        def depth(f: _RefFlowState) -> int:
            d, p = 0, f.parent
            while p is not None:
                d += 1
                p = p.parent
            return d

        reg_out: dict[str, float] = {}
        for f in sorted(active, key=depth):
            skey = self._src_key(f.flow.src)
            r = min(
                cfg.per_stream_cap,
                out_cap(f.flow.src) / out_count[skey],
                cfg.vm_nic.in_cap / in_count[f.flow.dst],
                cfg.decompress_rate,
            )
            if f.block_mode and is_registry_node(f.flow.src):
                # per-shard request throttle shared by the shard's streams
                shard = shard_index(skey)
                r = min(r, cfg.block_size * spec.qps_of(shard) / out_count[skey])
            if f.parent is not None and not f.parent.done:
                r = min(r, f.parent.rate)
            if r != f.rate:
                f.rate = r
                if self.record_rates:
                    self.rate_log.append((self.now, f.fid, r))
            if is_registry_node(f.flow.src):
                reg_out[skey] = reg_out.get(skey, 0.0) + f.rate
        for skey, egress in reg_out.items():
            if egress > self.peak_shard_egress.get(skey, 0.0):
                self.peak_shard_egress[skey] = egress
        if reg_out:
            total = sum(reg_out.values())
            if total > self.peak_registry_egress:
                self.peak_registry_egress = total

    # ------------------------------------------------------------------
    def run(self, until: float = math.inf) -> float:
        """Advance until no events remain (or ``until``); returns final time."""
        while True:
            self._recompute_rates()
            # next flow completion at current rates
            t_next_done = math.inf
            next_flow: Optional[_RefFlowState] = None
            for f in self._flows:
                if f.started and not f.done and f.rate > 0:
                    t = self.now + f.remaining / f.rate
                    if t < t_next_done:
                        t_next_done, next_flow = t, f
            # next runnable-prefix landing (notify_bytes of a flow arrived)
            t_next_noti = math.inf
            noti_flow: Optional[_RefFlowState] = None
            for f in self._flows:
                if (
                    f.started
                    and not f.done
                    and not f.notified
                    and f.on_notify is not None
                    and f.rate > 0
                ):
                    pend = f.notify_bytes - (f.total - f.remaining)
                    t = self.now + max(0.0, pend) / f.rate
                    if t < t_next_noti:
                        t_next_noti, noti_flow = t, f
            t_next_evt = self._events[0][0] if self._events else math.inf
            t_next = min(t_next_done, t_next_noti, t_next_evt)
            if t_next == math.inf or t_next > until:
                if until != math.inf and until > self.now:
                    dt = until - self.now
                    for f in self._flows:
                        if f.started and not f.done:
                            f.remaining = max(0.0, f.remaining - f.rate * dt)
                    self.now = until
                return self.now
            # advance progress linearly to t_next
            dt = t_next - self.now
            for f in self._flows:
                if f.started and not f.done:
                    f.remaining = max(0.0, f.remaining - f.rate * dt)
            self.now = t_next
            if (
                t_next_noti <= t_next_done
                and t_next_noti <= t_next_evt
                and noti_flow is not None
            ):
                # one notify per iteration, mirroring one completion per
                # iteration (the scan picks the lowest fid at time ties)
                noti_flow.notified = True
                if noti_flow.on_notify is not None:
                    noti_flow.on_notify(self.now)
            elif t_next_done <= t_next_evt and next_flow is not None:
                next_flow.done = True
                next_flow.remaining = 0.0
                next_flow.t_done = self.now
                if next_flow.on_notify is not None and not next_flow.notified:
                    # runnable <= done always: fire a straggling notify first
                    next_flow.notified = True
                    next_flow.on_notify(self.now)
                if next_flow.on_done is not None:
                    next_flow.on_done(self.now)
            else:
                while self._events and self._events[0][0] <= self.now + 1e-12:
                    _, _, fn = heapq.heappop(self._events)
                    fn()

    # ------------------------------------------------------------------
    def completion_times(self) -> dict[str, float]:
        """dst vm_id -> time its payload finished arriving."""
        out: dict[str, float] = {}
        for f in self._flows:
            if f.done:
                out[f.flow.dst] = max(out.get(f.flow.dst, 0.0), f.t_done)
        return out
