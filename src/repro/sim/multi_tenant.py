"""Multi-tenant trace replay: overlapping waves on one shared platform (§4.2).

The paper's end-to-end claim is not a single burst but *trace-driven*
behaviour: FaaSNet sustains the scaled IoT and gaming traces while growing
and reclaiming function trees as load moves between tenants.  This module
drives N tenants — each with its own RPS trace, function id, arrival-jitter
seed and an :class:`~repro.core.ft_manager.FTManager`-owned FunctionTree —
against ONE shared :class:`~repro.sim.engine.FlowSim` and ONE shared VM
pool, so overlapping waves contend for registry egress/QPS and per-VM NICs
exactly as in production.

Shared pool (paper §3.1 + §5)
-----------------------------
With ``placement="shared"`` (the default) the VM pool is genuinely shared
across tenants: scale-out goes through
:meth:`~repro.core.ft_manager.FTManager.pick_vm_for`, which admits a
function onto an already-warm VM by **memory** (each tenant's ``mem_mb``
requirement charged against the VM's 4 GB budget) before falling back to a
fresh reservation.  One VM then participates in several FunctionTrees at
once — exactly the paper's §3.1 design — and its NIC carries cross-tree
flows (fetching one tenant's image while seeding another's), which is the
co-location pressure the §5 FT-aware placement refinement balances.
Reclaim is evaluated per function-instance through the manager's pluggable
:class:`~repro.core.reclaim.ReclaimPolicy` (fixed idle-TTL by default, or
the keep-alive-histogram predictive policy via ``reclaim="histogram"``),
and a VM returns to the free pool only when its *last* instance is
reclaimed.  ``placement="exclusive"`` preserves the legacy one-VM-one-tenant
leasing bit-identically (pinned by ``tests/test_placement.py``).

Scheduler failover (ROADMAP: scheduler-shard metadata sync)
-----------------------------------------------------------
At a configurable tick the replay serializes the whole control plane with
:meth:`MultiTenantReplay.snapshot` — the :meth:`FTManager.snapshot` plus the
registry shard map (:class:`~repro.core.registry.RegistrySpec` and the
:class:`~repro.core.registry.ShardResolver` assignment state) — round-trips
it through ``json.dumps`` (proving it is wire-serializable, the etcd-style
sync the paper describes), discards the manager object and continues on
:meth:`restore_snapshot` (legacy bare-manager snapshots restore with a
1-shard registry).  Because the snapshot captures tree topologies, the free
pool in FIFO order, the VM registration order, the telemetry counters and
the shard map, the failed-over run emits a
**bit-identical** :class:`TickStats` stream versus an uninterrupted run — pinned by ``tests/test_multi_tenant.py`` and the
``scripts/ci.sh`` trace smoke.

Request-level serving (ROADMAP: end-to-end p50/p99 response)
------------------------------------------------------------
Attaching a :class:`ServingConfig` (``serving=``) swaps the tick-quantized
one-request-per-instance-per-tick dispatch loop for a request-level one:
arrivals are stamped at sub-second offsets, a per-function FIFO queue is
drained against instance *free times* (so response latency is continuous,
not a multiple of 1 s), co-located requests contend for per-VM CPU slots,
and scale-out happens in herd-controlled provisioning *waves* — a cold
function hit by a 10k-request burst issues exactly one wave under the
per-function in-flight-wave lock instead of a reservation per queued
request.  ``serving=None`` (the default) keeps the pre-serving path
bit-identically — pinned by the differential goldens in
``tests/test_request_serving.py``.

Determinism: arrivals come from the pure LCG in ``repro.sim.traces``,
tenants are stepped in registration order each tick, and the engine orders
events by (time, seq) — two runs of the same config are bit-identical.

``check_partition=True`` asserts the pool invariant at every tick: in
exclusive mode, free_pool + the per-tenant trees *partition* the pool; in
shared mode, every placed instance's memory fits its VM and the occupancy
sets agree across the FTManager (trees + per-VM records), the replay's
instance/provisioning maps, and — across a failover — the restored
snapshot.  The ``--runslow`` soak runs 8 tenants x 2000 VMs with a mid-wave
failover under that assertion.
"""
from __future__ import annotations

import heapq
import json
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.core import FTManager, VMInfo
from repro.core.reclaim import ReclaimPolicy, resolve_reclaim_policy
from repro.core.registry import RegistrySpec, ShardResolver, is_registry_node
from repro.core.topology import DistributionPlan, Flow

from .cluster import WaveConfig
from .engine import GBPS, SimConfig, make_sim
from .traces import arrival_offsets, arrivals_for_second


@dataclass
class TickStats:
    """One second of one tenant's replay (the golden-pinned stream)."""

    t: int
    rps: float
    arrivals: int
    completed: int
    mean_response_s: float
    p99_response_s: float
    active_vms: int
    provisioning_vms: int
    ft_height: int


@dataclass
class TenantConfig:
    """One tenant: a function id, its RPS trace and its scheduler knobs."""

    function_id: str
    trace: list[float]
    seed: int = 0  # arrival-jitter seed (per tenant, so waves decorrelate)
    function_duration_s: float = 2.0
    vm_target_factor: float = 1.2
    max_reserve_per_tick: int = 64
    # Per-instance memory requirement (MB) charged against the hosting VM's
    # budget under shared placement; must fit a single VM.
    mem_mb: int = 512


PLACEMENTS = ("shared", "exclusive")


@dataclass
class ServingConfig:
    """Request-level serving knobs (``None`` on the config = legacy path).

    With a ``ServingConfig`` attached, the replay measures *end-to-end
    request latency* instead of tick-quantized provisioning echoes:

    * **Sub-tick dispatch** — arrivals are stamped at ``t + offset`` (the
      LCG in :func:`repro.sim.traces.arrival_offsets`) and the per-function
      FIFO queue is drained against instance *free times*, not 1 s quanta,
      so the response-latency distribution is continuous.
    * **Per-VM CPU slots** (``cpu_slots``, paper §4.1: 2-CPU VMs) — a VM
      running ``k`` concurrent requests at dispatch time stretches the new
      request's service time by ``max(1, k / cpu_slots)``: co-located busy
      instances contend for the CPU, not just the NIC.
    * **Cold-start herd control** (``herd_control``) — scale-out happens in
      provisioning *waves* guarded by the per-function in-flight-wave lock
      (:meth:`repro.core.ft_manager.FTManager.wave_open` /
      :meth:`~repro.core.ft_manager.FTManager.wave_landed`; the lock rides
      the failover snapshot).  While a wave is in flight the request herd
      parks in the queue and drains as containers land.  A wave is sized to
      sustain the **median** arrival rate over the trailing
      ``rate_window_s`` (median, not instantaneous: a one-tick 10k-request
      burst must not buy a VM per queued request) plus enough instances to
      drain the current backlog within ``drain_budget_s``.  With
      ``herd_control=False`` admission reproduces the pre-serving
      scheduler's naive one-reservation-per-deficit-unit rule (still with
      sub-tick dispatch) — the bench's comparison baseline.
    """

    cpu_slots: int = 2
    herd_control: bool = True
    drain_budget_s: float = 15.0
    rate_window_s: int = 30

    def __post_init__(self) -> None:
        if self.cpu_slots < 1:
            raise ValueError(f"cpu_slots must be >= 1, got {self.cpu_slots}")
        if self.drain_budget_s <= 0:
            raise ValueError(
                f"drain_budget_s must be > 0, got {self.drain_budget_s}"
            )
        if self.rate_window_s < 1:
            raise ValueError(
                f"rate_window_s must be >= 1, got {self.rate_window_s}"
            )


@dataclass
class MultiTenantConfig:
    tenants: list[TenantConfig] = field(default_factory=list)
    system: str = "faasnet"  # faasnet | baseline | on_demand
    vm_pool_size: int = 2000
    idle_reclaim_s: float = 7 * 60.0
    registry_out_cap: float = 52 * GBPS  # region-scale registry (see workload.py)
    registry_qps: float = 700.0
    # Sharded registry: ``None`` keeps the legacy 1-shard registry built from
    # the two caps above (bit-identical streams); an explicit spec wins.
    registry: Optional[RegistrySpec] = None
    wave: WaveConfig = field(default_factory=WaveConfig)
    # Pool sharing: "shared" admits tenants onto warm VMs by memory through
    # pick_vm_for (one VM, many trees — paper §3.1); "exclusive" reproduces
    # the legacy one-VM-one-tenant leasing bit-identically.
    placement: str = "shared"
    # §5 FT-aware placement refinement (False = pure binpack) — only
    # meaningful under shared placement.
    ft_aware_placement: bool = True
    # Reclaim policy: "fixed" (idle-TTL = idle_reclaim_s, the legacy
    # behaviour), "histogram" (predictive keep-alive), or an instance.
    reclaim: "str | ReclaimPolicy" = "fixed"
    # Request-level serving (sub-tick dispatch, CPU slots, herd control).
    # ``None`` keeps the pre-serving one-request-per-instance-per-tick
    # dispatch loop BIT-identically (goldens pinned in
    # tests/test_request_serving.py + tests/test_placement.py).
    serving: Optional[ServingConfig] = None
    # Block-level provisioning (paper §3.1–§3.2): function_id -> ImageSpec.
    # When set, provisioning emits per-layer flows that skip blocks already
    # resident in the shared per-VM BlockCache, instances activate at the
    # boot-working-set (runnable) milestone instead of full arrival, full
    # materialization lands in the cache for later waves to dedup against,
    # a VM's cache is evicted when it returns to the free pool, and root
    # election prefers VMs already holding the image's blobs.  ``None``
    # (default) keeps the scalar payload model bit-identically.
    images: "Optional[dict]" = None  # dict[str, repro.core.image.ImageSpec]
    # Scheduler failover: snapshot/json-round-trip/restore the FTManager at
    # the *start* of this tick (None = never).  The replay must be
    # bit-identical either way.
    failover_at: Optional[int] = None
    check_partition: bool = False  # assert the pool invariant every tick

    def duration_s(self) -> int:
        return max((len(t.trace) for t in self.tenants), default=0)

    def reclaim_policy(self) -> ReclaimPolicy:
        # A policy *instance* in the config is copied (snapshot round-trip)
        # so each replay owns fresh state — otherwise one run's learned
        # histograms would leak into the next run of the same config and
        # break two-run bit-identity.
        if isinstance(self.reclaim, ReclaimPolicy):
            from repro.core.reclaim import restore_reclaim_policy

            return restore_reclaim_policy(
                self.reclaim.snapshot(), default_ttl_s=self.idle_reclaim_s
            )
        return resolve_reclaim_policy(self.reclaim, default_ttl_s=self.idle_reclaim_s)

    def registry_spec(self) -> RegistrySpec:
        return RegistrySpec.resolve(
            self.registry, egress_cap=self.registry_out_cap, qps=self.registry_qps
        )


@dataclass
class TenantResult:
    function_id: str
    requests: int
    completed: int
    mean_response_s: float
    p99_response_s: float
    mean_prov_s: float
    p99_prov_s: float
    prov_makespan_s: float  # first reservation -> last container ready
    peak_vms: int
    provisioned: int
    # Serving-era telemetry (p50 also populated on legacy runs) ----------
    p50_response_s: float = 0.0
    # Instances whose lifetime service time never paid back the
    # provisioning latency they cost (serving mode only) — the
    # herd-control bench's headline waste metric.
    wasted_provisions: int = 0


@dataclass
class MultiTenantResult:
    system: str
    per_tenant: dict[str, TenantResult]
    timelines: dict[str, list[TickStats]]
    peak_registry_egress: float  # bytes/s, aggregate across shards + tenants
    peak_shard_egress: dict[str, float]  # shard id -> peak egress (bytes/s)
    prov_makespan_s: float  # whole-platform first reservation -> last ready
    total_prov_time_s: float  # sum of all provisioning latencies
    failovers: int
    manager_stats: dict[str, int]
    free_vms: int
    # Shared-pool economics / pressure telemetry ------------------------
    vm_seconds: float = 0.0  # ∫ (VMs out of the free pool) dt over the run
    cold_starts: int = 0  # total provisions (every placement is a cold start)
    peak_nic_utilization: float = 0.0  # peak per-VM NIC rate / capacity

    def vm_hours(self) -> float:
        return self.vm_seconds / 3600.0


@dataclass
class _Instance:
    vm_id: str
    busy_until: float = 0.0
    idle_since: float = 0.0
    served: bool = False  # has handled >=1 request (gates reuse-gap learning)
    prov_cost_s: float = 0.0  # provisioning latency this instance cost
    busy_total_s: float = 0.0  # lifetime service time delivered (serving mode)


class _TenantState:
    """Mutable per-tenant replay state (scheduler side)."""

    def __init__(self, cfg: TenantConfig) -> None:
        self.cfg = cfg
        self.instances: dict[str, _Instance] = {}  # warm, by vm_id
        self.provisioning: dict[str, float] = {}  # vm_id -> request time
        self.flow_of: dict[str, object] = {}  # vm_id -> _FlowState
        # Block mode: vm_id -> {layer digest -> _FlowState} for per-piece
        # cross-wave streaming chains (a child fetches a layer from the
        # parent's in-flight stream of the SAME layer, never another's).
        self.block_flow_of: dict[str, dict[str, object]] = {}
        self.queue: deque[float] = deque()
        self.responses: list[tuple[float, float]] = []  # (completion_t, latency)
        self.prov_latencies: list[float] = []
        self.first_req_t: float = float("inf")
        self.last_ready_t: float = float("-inf")
        self.requests: int = 0
        self.peak_vms: int = 0
        self.timeline: list[TickStats] = []
        # Serving-mode state (unused on the legacy dispatch path) ----------
        self.dispatch_log: list[tuple[float, float]] = []  # (arrival, start)
        self.in_flight: list[float] = []  # min-heap of completion times
        self.completed_done: int = 0  # completions popped from in_flight
        self.wasted: int = 0  # reclaimed instances that never served
        self.arrival_window: deque[int] = deque()  # last rate_window_s counts
        self.stretch_window: deque[float] = deque()  # per-tick mean CPU stretch


def _pctl(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    return sorted_vals[int(q * (len(sorted_vals) - 1))]


class MultiTenantReplay:
    """N tenants replayed against one FlowSim + one FTManager-owned VM pool."""

    def __init__(self, cfg: MultiTenantConfig) -> None:
        if not cfg.tenants:
            raise ValueError("multi-tenant replay needs at least one tenant")
        fids = [t.function_id for t in cfg.tenants]
        if len(set(fids)) != len(fids):
            raise ValueError(f"duplicate tenant function ids: {fids}")
        if cfg.placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {cfg.placement!r}; one of {PLACEMENTS}"
            )
        w = cfg.wave
        for t in cfg.tenants:
            if t.mem_mb > w.vm_mem_mb:
                raise ValueError(
                    f"tenant {t.function_id!r} needs {t.mem_mb} MB but VMs "
                    f"have {w.vm_mem_mb} MB"
                )
        self.cfg = cfg
        spec = cfg.registry_spec()
        self.sim = make_sim(
            SimConfig(
                registry=spec,
                per_stream_cap=w.per_stream_cap,
                hop_latency=w.hop_latency,
                engine=w.engine,
                record_trace=w.record_trace,
                vector_scalar_cutoff=w.vector_scalar_cutoff,
            )
        )
        # Shard assignment is scheduler state (it rides the failover snapshot
        # alongside the FTManager, so a restored scheduler keeps placing
        # blobs exactly where the failed one would have).
        self.resolver = ShardResolver(spec)
        self.mgr = self._new_manager()
        for i in range(cfg.vm_pool_size):
            self.mgr.add_free_vm(VMInfo(f"vm{i}", mem_mb=w.vm_mem_mb))
        for t in cfg.tenants:
            self.mgr.set_function_mem(t.function_id, t.mem_mb)
        self.tenants: list[_TenantState] = [_TenantState(t) for t in cfg.tenants]
        # Block mode: ONE shared per-VM block cache across all tenants —
        # data-plane state (it lives with the VMs, not the scheduler), so it
        # survives failover without riding the snapshot.
        self.block_cache = None
        if cfg.images is not None:
            from repro.core.image import BlockCache

            missing = [
                t.function_id
                for t in cfg.tenants
                if t.function_id not in cfg.images
            ]
            if missing:
                raise ValueError(f"cfg.images missing tenants: {missing}")
            self.block_cache = BlockCache()
            self.mgr.set_content_affinity(
                self._image_affinity, candidates=self.block_cache.vms
            )
        self.failovers = 0
        self.vm_seconds = 0.0
        # Serving mode: per-VM completion times of in-flight requests across
        # ALL tenants (lazily pruned) — the CPU-slot contention denominator.
        self._vm_busy: dict[str, list[float]] = {}

    def _new_manager(self) -> FTManager:
        return FTManager(
            vm_idle_reclaim_s=self.cfg.idle_reclaim_s,
            ft_aware_placement=self.cfg.ft_aware_placement,
            reclaim=self.cfg.reclaim_policy(),
        )

    def _image_affinity(self, function_id: str, vm_id: str) -> int:
        """Content-aware root-election score: image bytes resident on the VM."""
        return self.block_cache.resident_bytes(
            vm_id, self.cfg.images[function_id]
        )

    # ------------------------------------------------------------------
    # Scheduler failover (the tentpole's mid-wave snapshot/restore)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Wire-serializable control-plane state: trees + registry layout.

        The registry spec and the shard resolver's assignment state are part
        of the snapshot so a restored scheduler keeps the same shard map.
        Under request serving (version 3) the per-function FIFO request
        queues cross the wire too — a parked herd must survive the failover
        — alongside the wave locks inside the manager snapshot.
        """
        blob = {
            "version": 2,
            "manager": self.mgr.snapshot(),
            "registry": self.resolver.snapshot(),
        }
        if self.cfg.serving is not None:
            blob["version"] = 3
            blob["serving"] = {
                "queues": {
                    ts.cfg.function_id: list(ts.queue) for ts in self.tenants
                }
            }
        return blob

    def restore_snapshot(self, blob: dict) -> None:
        """Rebuild the control plane from :meth:`snapshot` output.

        Legacy snapshots (a bare pre-sharding ``FTManager.snapshot()`` dict,
        no ``manager``/``registry`` envelope) restore with a 1-shard registry
        built from the config's legacy caps.  Snapshots that predate
        pluggable reclaim / per-function memory restore with the *config's*
        policy and memory requirements re-applied — the snapshot is
        authoritative when it carries that state, the config is when it
        does not (a legacy restore must not silently disable memory
        admission or swap the requested policy for the fixed default).
        """
        if "manager" in blob:
            mgr_blob = blob["manager"]
            self.resolver = ShardResolver.restore(blob["registry"])
        else:  # legacy pre-sharding snapshot: single-shard registry
            mgr_blob = blob
            self.resolver = ShardResolver(
                RegistrySpec.resolve(
                    None,
                    egress_cap=self.cfg.registry_out_cap,
                    qps=self.cfg.registry_qps,
                )
            )
        self.mgr = FTManager.restore(
            mgr_blob,
            vm_idle_reclaim_s=self.cfg.idle_reclaim_s,
            ft_aware_placement=self.cfg.ft_aware_placement,
            # honored only when the snapshot lacks a recorded policy
            reclaim=self.cfg.reclaim_policy(),
        )
        if "function_mem" not in mgr_blob:  # pre-memory snapshot
            for t in self.cfg.tenants:
                self.mgr.set_function_mem(t.function_id, t.mem_mb)
            # re-charge already-placed instances at today's requirements so
            # admission accounting resumes (legacy runs were exclusive —
            # one function per VM — so the budget can never be exceeded)
            for vm in self.mgr.vms.values():
                for fid in vm.functions:
                    if fid not in vm.func_mem_mb:
                        need = self.mgr.mem_need(fid)
                        vm.func_mem_mb[fid] = need
                        vm.mem_used_mb += need
        # Serving snapshots (version 3) carry the parked request queues;
        # a legacy snapshot restored into a serving replay keeps the live
        # queues (nothing recorded, nothing to overwrite).
        if "serving" in blob:
            queues = blob["serving"]["queues"]
            for ts in self.tenants:
                ts.queue = deque(queues.get(ts.cfg.function_id, []))
        # The block cache is data-plane state: it never crossed the wire,
        # but the restored manager needs the scorer re-attached.
        if self.block_cache is not None:
            self.mgr.set_content_affinity(
                self._image_affinity, candidates=self.block_cache.vms
            )

    def _failover(self) -> None:
        """Kill the scheduler: serialize, discard, restore from the wire copy.

        The FlowSim (data plane) keeps running — in production the in-flight
        image streams do not care which scheduler shard owns the metadata.
        Only the control plane (trees, pool, counters, shard map) crosses
        the wire.
        """
        blob = json.dumps(self.snapshot(), sort_keys=True)
        if self.cfg.serving is not None:
            # The parked herd dies with the failed scheduler: only the wire
            # copy can bring the queues back (proves the snapshot complete).
            for ts in self.tenants:
                ts.queue.clear()
        self.restore_snapshot(json.loads(blob))
        self.failovers += 1

    # ------------------------------------------------------------------
    # Provisioning (same per-system behaviour as workload.TraceReplay)
    # ------------------------------------------------------------------
    def _provision(self, ts: _TenantState, vm_id: str, now: float) -> None:
        cfg, w = self.cfg, self.cfg.wave
        fid = ts.cfg.function_id
        if cfg.images is not None:
            self._provision_blocks(ts, vm_id, now, cfg.images[fid])
            return
        payload = int(w.image_bytes * w.startup_fraction)
        control = w.rpc.control_plane_total()
        if cfg.system == "faasnet":
            upstream = self.mgr.insert(fid, vm_id, now)
            src = (
                upstream
                if upstream is not None
                else self.resolver.source_for(fid, nbytes=payload)
            )
            streaming = True
        elif cfg.system in ("baseline", "on_demand"):
            if cfg.system == "baseline":
                payload = w.image_bytes
            src = self.resolver.source_for(fid, nbytes=payload)
            streaming = cfg.system == "on_demand"
            # keep the FT for height reporting + pool-partition parity
            self.mgr.insert(fid, vm_id, now)
        else:
            raise ValueError(cfg.system)
        plan = DistributionPlan(
            flows=[Flow(src, vm_id, fid, payload)],
            control_latency={vm_id: control},
            streaming=streaming,
        )
        ts.provisioning[vm_id] = now
        ts.first_req_t = min(ts.first_req_t, now)

        def on_done(vm: str, t: float) -> None:
            extract = (
                w.image_bytes / w.image_extract_rate
                if cfg.system == "baseline"
                else w.rpc.image_load
            )
            ready = t + extract + w.container_start
            self.sim.schedule(ready, lambda: self._activate(ts, vm, ready))

        states = self.sim.add_plan(plan, t0=now, on_node_done=on_done)
        if streaming and not is_registry_node(src) and src in ts.flow_of:
            up = ts.flow_of[src]
            if not up.done:  # type: ignore[attr-defined]
                self.sim.set_parent(states[0], up)  # type: ignore[arg-type]
        ts.flow_of[vm_id] = states[0]

    def _provision_blocks(
        self, ts: _TenantState, vm_id: str, now: float, img
    ) -> None:
        """Block-granular provisioning: per-layer flows, runnable-driven start.

        The instance activates once the boot working set lands (§3.2); full
        materialization continues in the background and is recorded in the
        shared :class:`~repro.core.image.BlockCache`, where later waves of
        ANY tenant sharing the base layers skip the resident blocks.  The
        VM's cache is evicted when the VM returns to the free pool.
        """
        cfg, w = self.cfg, self.cfg.wave
        fid = ts.cfg.function_id
        cache = self.block_cache
        control = w.rpc.control_plane_total()
        upstream = None
        if cfg.system == "faasnet":
            upstream = self.mgr.insert(fid, vm_id, now)
            streaming = True
        elif cfg.system in ("baseline", "on_demand"):
            streaming = cfg.system == "on_demand"
            # keep the FT for height reporting + pool-partition parity
            self.mgr.insert(fid, vm_id, now)
        else:
            raise ValueError(cfg.system)
        flows: list[Flow] = []
        for la in img.layers:
            if cfg.system == "baseline":
                # docker's layer cache is all-or-nothing; a container cannot
                # start before the full pull
                if cache.resident_blocks(vm_id, la.digest) >= img.layer_blocks(
                    la.digest
                ):
                    continue
                need, boot = la.size, la.size
            else:
                need, boot = cache.missing_layer_bytes(vm_id, img, la.digest)
                if need <= 0:
                    continue
            src = (
                upstream
                if upstream is not None
                else self.resolver.source_for(la.digest, nbytes=need)
            )
            flows.append(Flow(src, vm_id, la.digest, need, runnable_bytes=boot))
        if not flows:
            # fully cached: zero-byte marker so the milestones still fire
            src = (
                upstream
                if upstream is not None
                else self.resolver.source_for(img.name, nbytes=0)
            )
            flows.append(Flow(src, vm_id, f"{img.name}:cached", 0))
        plan = DistributionPlan(
            flows=flows, control_latency={vm_id: control}, streaming=streaming
        )
        ts.provisioning[vm_id] = now
        ts.first_req_t = min(ts.first_req_t, now)
        extract = (
            img.total_bytes() / w.image_extract_rate
            if cfg.system == "baseline"
            else w.rpc.image_load
        )
        pending = len(plan.flows)

        def on_runnable(vm: str, t: float) -> None:
            ready = t + extract + w.container_start
            self.sim.schedule(ready, lambda: self._activate(ts, vm, ready))

        def on_done(vm: str, t: float) -> None:
            nonlocal pending
            pending -= 1
            # last layer landed: the whole image is resident.  Skip the
            # cache write if the instance was reclaimed before the stream
            # finished — eviction wins over a straggling materialization.
            if pending == 0 and (vm in ts.instances or vm in ts.provisioning):
                cache.add_image(vm, img)

        states = self.sim.add_plan(
            plan, t0=now, on_node_done=on_done, on_node_runnable=on_runnable
        )
        if streaming and upstream is not None:
            ups = ts.block_flow_of.get(upstream)
            if ups:
                for st in states:
                    up = ups.get(st.flow.piece)
                    if up is not None and not up.done:  # type: ignore[attr-defined]
                        self.sim.set_parent(st, up)  # type: ignore[arg-type]
        ts.block_flow_of[vm_id] = {st.flow.piece: st for st in states}

    def _activate(self, ts: _TenantState, vm_id: str, now: float) -> None:
        t_req = ts.provisioning.pop(vm_id, now)
        ts.prov_latencies.append(now - t_req)
        ts.last_ready_t = max(ts.last_ready_t, now)
        ts.instances[vm_id] = _Instance(
            vm_id, busy_until=now, idle_since=now, prov_cost_s=now - t_req
        )
        sv = self.cfg.serving
        if sv is not None and sv.herd_control:
            # one container of the function's in-flight wave landed; when
            # the whole wave is down the lock lifts and scale-out may resume
            self.mgr.wave_landed(ts.cfg.function_id)

    def _reclaim(self, ts: _TenantState, now: float) -> None:
        """Ask the manager's ReclaimPolicy about every idle instance.

        Accounting goes through :meth:`FTManager.reclaim_instance` — the
        same path ``FTManager.reclaim_idle`` uses — so the ``reclaims``
        counter (and the release-when-empty rule of the shared pool) cannot
        drift between the replay and the manager's own reclaim loop.
        """
        fid = ts.cfg.function_id
        policy = self.mgr.reclaim
        for vm_id, inst in list(ts.instances.items()):
            if inst.busy_until <= now and policy.should_reclaim(
                fid, now - inst.idle_since, now
            ):
                if (
                    self.cfg.serving is not None
                    and inst.busy_total_s < inst.prov_cost_s
                ):
                    # economically wasted: the instance never served enough
                    # to pay back the provisioning latency it cost
                    ts.wasted += 1
                del ts.instances[vm_id]
                ts.flow_of.pop(vm_id, None)
                ts.block_flow_of.pop(vm_id, None)
                released = self.mgr.reclaim_instance(fid, vm_id)
                if released and self.block_cache is not None:
                    # the VM returned to the free pool: its block cache
                    # (every tenant's layers) goes with it
                    self.block_cache.evict(vm_id)

    # ------------------------------------------------------------------
    def _step_tenant(self, ts: _TenantState, t: int, now: float) -> None:
        if self.cfg.serving is not None:
            self._step_tenant_serving(ts, t, now)
            return
        tc = ts.cfg
        rps = tc.trace[t] if t < len(tc.trace) else 0.0
        dur = tc.function_duration_s
        n_arr = arrivals_for_second(rps, t, tc.seed)
        ts.requests += n_arr
        for _ in range(n_arr):
            ts.queue.append(now)
        completed = 0
        lat_samples: list[float] = []
        fid = tc.function_id
        for inst in ts.instances.values():
            if not ts.queue:
                break
            if inst.busy_until <= now:
                arrival = ts.queue.popleft()
                resp = (now - arrival) + dur
                # a *reused* instance was idle (now - idle_since): predictive
                # reclaim policies learn from this gap.  The first-ever
                # dispatch after a cold start is provisioning slack, not a
                # reuse gap — feeding it to the histogram would teach a
                # bogus ~0 s keep-alive to every freshly provisioned fn.
                if inst.served:
                    self.mgr.reclaim.observe_gap(fid, now - inst.idle_since)
                inst.served = True
                self.mgr.touch_instance(fid, inst.vm_id, now)
                inst.busy_until = now + dur
                inst.idle_since = now + dur
                ts.responses.append((now + dur, resp))
                lat_samples.append(resp)
                completed += 1
        # scale out against the *shared* pool (see workload.TraceReplay.run)
        deficit = (
            len(ts.queue)
            - sum(1 for i in ts.instances.values() if i.busy_until <= now)
            - len(ts.provisioning)
        )
        target = int(tc.vm_target_factor * max(rps, n_arr) * dur) + 1
        headroom = target - (len(ts.instances) + len(ts.provisioning))
        deficit = min(deficit, max(0, headroom))
        shared = self.cfg.placement == "shared"
        for _ in range(min(max(0, deficit), tc.max_reserve_per_tick)):
            # Shared pool: co-locate onto a warm VM with memory headroom
            # (pick_vm_for falls back to reserving a free VM); exclusive
            # leasing always takes a fresh VM.
            vm = self.mgr.pick_vm_for(fid, now) if shared else self.mgr.reserve_vm(now)
            if vm is None:
                break  # pool exhausted and no co-location headroom: wait
            self._provision(ts, vm.vm_id, now)
        self._reclaim(ts, now)
        ts.peak_vms = max(ts.peak_vms, len(ts.instances) + len(ts.provisioning))
        ft = self.mgr.trees.get(tc.function_id)
        lat_samples.sort()
        ts.timeline.append(
            TickStats(
                t=t,
                rps=rps,
                arrivals=n_arr,
                completed=completed,
                mean_response_s=(
                    sum(lat_samples) / len(lat_samples) if lat_samples else 0.0
                ),
                p99_response_s=_pctl(lat_samples, 0.99),
                active_vms=len(ts.instances) + len(ts.provisioning),
                provisioning_vms=len(ts.provisioning),
                ft_height=ft.height if ft is not None else 0,
            )
        )

    # ------------------------------------------------------------------
    # Request-level serving (ServingConfig attached): sub-tick dispatch,
    # per-VM CPU slots and cold-start herd control.
    # ------------------------------------------------------------------
    def _step_tenant_serving(self, ts: _TenantState, t: int, now: float) -> None:
        tc, sv = ts.cfg, self.cfg.serving
        assert sv is not None
        rps = tc.trace[t] if t < len(tc.trace) else 0.0
        n_arr = arrivals_for_second(rps, t, tc.seed)
        ts.requests += n_arr
        # Arrivals are stamped inside the second (sorted offsets keep the
        # FIFO queue globally ordered by arrival time).
        for off in arrival_offsets(n_arr, t, tc.seed):
            ts.queue.append(now + off)
        ts.arrival_window.append(n_arr)
        while len(ts.arrival_window) > sv.rate_window_s:
            ts.arrival_window.popleft()
        # Requests dispatched in earlier ticks whose service finished by now
        # leave the in-flight set (conservation: completed + in_flight +
        # queued == requests, asserted by _check_partition).
        while ts.in_flight and ts.in_flight[0] <= now:
            heapq.heappop(ts.in_flight)
            ts.completed_done += 1
        completed, lat_samples = self._drain_queue(ts, now)
        self._scale_out_serving(ts, t, now, rps, n_arr)
        self._reclaim(ts, now)
        ts.peak_vms = max(ts.peak_vms, len(ts.instances) + len(ts.provisioning))
        ft = self.mgr.trees.get(tc.function_id)
        lat_samples.sort()
        ts.timeline.append(
            TickStats(
                t=t,
                rps=rps,
                arrivals=n_arr,
                completed=completed,
                mean_response_s=(
                    sum(lat_samples) / len(lat_samples) if lat_samples else 0.0
                ),
                p99_response_s=_pctl(lat_samples, 0.99),
                active_vms=len(ts.instances) + len(ts.provisioning),
                provisioning_vms=len(ts.provisioning),
                ft_height=ft.height if ft is not None else 0,
            )
        )

    def _drain_queue(self, ts: _TenantState, now: float) -> tuple[int, list[float]]:
        """FIFO-dispatch queued requests against instance *free times*.

        Each instance serves one request at a time; the earliest-free
        instance takes the head of the queue at ``start = max(arrival,
        free)`` (a start inside the previous second is a request the
        scheduler would have dispatched between ticks — the discrete replay
        settles it here, retroactively but deterministically).  Service
        time stretches by the hosting VM's CPU-slot contention: ``k``
        requests already running on the VM (across ALL tenants) at start
        time make the new one take ``dur * max(1, (k+1)/cpu_slots)``.
        Dispatch stops at the tick horizon — an instance not free before
        ``now + 1`` parks the rest of the queue for the next tick.
        """
        sv = self.cfg.serving
        assert sv is not None
        tc = ts.cfg
        fid, dur = tc.function_id, tc.function_duration_s
        if not ts.instances or not ts.queue:
            return 0, []
        horizon = now + 1.0
        # (free_time, insertion_order, vm_id): insertion order breaks ties
        # deterministically and matches the legacy scan order.
        heap: list[tuple[float, int, str]] = [
            (inst.busy_until, i, vm_id)
            for i, (vm_id, inst) in enumerate(ts.instances.items())
        ]
        heapq.heapify(heap)
        completed = 0
        stretch_sum = 0.0
        lat_samples: list[float] = []
        while ts.queue:
            free, order, vm_id = heap[0]
            if free >= horizon:
                break  # nobody frees up inside this tick: herd stays parked
            arrival = ts.queue.popleft()
            start = max(arrival, free)
            busy = self._vm_busy.setdefault(vm_id, [])
            # lazily prune requests that finished before this start
            if busy:
                busy[:] = [f for f in busy if f > start]
            stretch = max(1.0, (len(busy) + 1) / sv.cpu_slots)
            finish = start + dur * stretch
            busy.append(finish)
            inst = ts.instances[vm_id]
            if inst.served:
                # reuse gap for predictive reclaim (same gating as legacy:
                # the first post-cold-start dispatch is provisioning slack)
                self.mgr.reclaim.observe_gap(
                    fid, max(0.0, start - inst.idle_since)
                )
            inst.served = True
            self.mgr.touch_instance(fid, vm_id, start)
            inst.busy_until = finish
            inst.idle_since = finish
            inst.busy_total_s += finish - start
            ts.responses.append((finish, finish - arrival))
            ts.dispatch_log.append((arrival, start))
            heapq.heappush(ts.in_flight, finish)
            lat_samples.append(finish - arrival)
            completed += 1
            stretch_sum += stretch
            heapq.heapreplace(heap, (finish, order, vm_id))
        if completed:
            # Feed the observed contention back to the admission gate: wave
            # sizing uses the *effective* service time (nominal duration x
            # median observed stretch), so a tenant squeezed by a
            # neighbour's burst provisions its way back to stability.
            ts.stretch_window.append(stretch_sum / completed)
            while len(ts.stretch_window) > sv.rate_window_s:
                ts.stretch_window.popleft()
        return completed, lat_samples

    def _scale_out_serving(
        self, ts: _TenantState, t: int, now: float, rps: float, n_arr: int
    ) -> None:
        """Admission gate: wave-sized scale-out under the in-flight-wave lock.

        ``herd_control=False`` reproduces the pre-serving scheduler's naive
        one-reservation-per-deficit-unit rule verbatim (the bench baseline).
        With herd control, a cold function hit by a 10k-request burst issues
        exactly ONE provisioning wave: while the wave is in flight
        (:meth:`FTManager.wave_active`) no further reservations happen — the
        herd parks in the FIFO queue and drains as containers land.  The
        wave is sized for the *median* arrival rate over the trailing
        window (spike-immune) plus enough instances to drain the current
        backlog within ``drain_budget_s``, capped by the contention-adjusted
        target (legacy target scaled by the median observed CPU stretch).
        """
        tc, sv = ts.cfg, self.cfg.serving
        assert sv is not None
        fid, dur = tc.function_id, tc.function_duration_s
        shared = self.cfg.placement == "shared"
        target = int(tc.vm_target_factor * max(rps, n_arr) * dur) + 1
        if not sv.herd_control:
            deficit = (
                len(ts.queue)
                - sum(1 for i in ts.instances.values() if i.busy_until <= now)
                - len(ts.provisioning)
            )
            headroom = target - (len(ts.instances) + len(ts.provisioning))
            deficit = min(deficit, max(0, headroom))
            for _ in range(min(max(0, deficit), tc.max_reserve_per_tick)):
                vm = (
                    self.mgr.pick_vm_for(fid, now)
                    if shared
                    else self.mgr.reserve_vm(now)
                )
                if vm is None:
                    break
                self._provision(ts, vm.vm_id, now)
            return
        if self.mgr.wave_active(fid):
            return  # one wave at a time: the herd stays parked
        window = sorted(ts.arrival_window)
        median = float(window[len(window) // 2]) if window else 0.0
        # Size by the effective service time: co-located busy instances
        # stretch execution, so nominal-duration capacity math undershoots
        # exactly when a neighbouring tenant bursts onto shared VMs.  The
        # median observed per-tick stretch (1.0 when nothing has been
        # dispatched yet — a cold burst sizes its one wave unstretched)
        # scales sustain, backlog AND the target cap; without the last one
        # the legacy cap would pin a squeezed tenant below offered load
        # forever.
        sw = sorted(ts.stretch_window)
        eff_dur = dur * (sw[len(sw) // 2] if sw else 1.0)
        sustain = (
            int(tc.vm_target_factor * median * eff_dur) + 1 if median > 0 else 0
        )
        backlog = math.ceil(len(ts.queue) * eff_dur / sv.drain_budget_s)
        desired = min(
            max(sustain, backlog),
            int(tc.vm_target_factor * max(rps, n_arr) * eff_dur) + 1,
        )
        current = len(ts.instances) + len(ts.provisioning)
        issued = 0
        for _ in range(max(0, desired - current)):
            vm = (
                self.mgr.pick_vm_for(fid, now)
                if shared
                else self.mgr.reserve_vm(now)
            )
            if vm is None:
                break  # pool exhausted: the wave is what we could get
            self._provision(ts, vm.vm_id, now)
            issued += 1
        if issued:
            self.mgr.wave_open(fid, issued)

    def _check_partition(self) -> None:
        """Per-tick pool invariant (mode-dispatched).

        Exclusive mode: free_pool + per-tenant {warm, provisioning} sets
        partition the VM pool (legacy leasing — a VM belongs to at most one
        tenant).  Shared mode: tenants may overlap on a VM, so the
        invariant becomes memory-fit + occupancy consistency — see
        :meth:`check_shared_invariants`.
        """
        free = list(self.mgr.free_pool)
        free_set = set(free)
        if len(free) != len(free_set):
            raise AssertionError("duplicate vm ids in free_pool")
        owned: set[str] = set()
        for ts in self.tenants:
            mine = set(ts.instances) | set(ts.provisioning)
            if self.cfg.placement == "exclusive":
                overlap = mine & owned
                if overlap:
                    raise AssertionError(
                        f"vm owned by two tenants: {sorted(overlap)}"
                    )
            ft = self.mgr.trees.get(ts.cfg.function_id)
            members = set(ft.vm_ids()) if ft is not None else set()
            if members != mine:
                raise AssertionError(
                    f"{ts.cfg.function_id}: tree/{{warm,provisioning}} mismatch: "
                    f"tree-only={sorted(members - mine)} "
                    f"tenant-only={sorted(mine - members)}"
                )
            owned |= mine
        leak = owned & free_set
        if leak:
            raise AssertionError(f"vm both free and tenant-owned: {sorted(leak)}")
        missing = set(self.mgr.vms) - owned - free_set
        if missing:
            raise AssertionError(f"vm lost (neither free nor owned): {sorted(missing)}")
        if self.cfg.placement == "shared":
            self.check_shared_invariants()
        if self.cfg.serving is not None:
            self._check_conservation()

    def _check_conservation(self) -> None:
        """Serving-mode request conservation (per tenant, every tick).

        Every request ever admitted is exactly one of: dispatched (it has a
        response record) or still queued — and every dispatched request is
        either done or in flight.  A dropped or double-counted request
        breaks one of the two equalities.
        """
        for ts in self.tenants:
            fid = ts.cfg.function_id
            if ts.requests != len(ts.responses) + len(ts.queue):
                raise AssertionError(
                    f"{fid}: requests={ts.requests} != dispatched="
                    f"{len(ts.responses)} + queued={len(ts.queue)}"
                )
            if ts.completed_done + len(ts.in_flight) != len(ts.responses):
                raise AssertionError(
                    f"{fid}: completed={ts.completed_done} + in_flight="
                    f"{len(ts.in_flight)} != dispatched={len(ts.responses)}"
                )

    def check_shared_invariants(self) -> None:
        """Shared-pool invariant: memory fits and occupancy is consistent.

        For every VM: the charged per-function memory sums to
        ``mem_used_mb`` and fits the budget; the manager's per-VM function
        set, the per-function trees and the replay's instance/provisioning
        maps all name exactly the same occupancy.  A VM holding instances
        must not sit in the free pool.
        """
        mgr = self.mgr
        # replay-side occupancy: fid -> vms (instances ∪ provisioning)
        replay_occ: dict[str, set[str]] = {
            ts.cfg.function_id: set(ts.instances) | set(ts.provisioning)
            for ts in self.tenants
        }
        vm_occ: dict[str, set[str]] = {}  # vm -> fids per the replay
        for fid, vms in replay_occ.items():
            for v in vms:
                vm_occ.setdefault(v, set()).add(fid)
        for vm in mgr.vms.values():
            if set(vm.func_mem_mb) != vm.functions:
                raise AssertionError(
                    f"{vm.vm_id}: charged-memory keys {sorted(vm.func_mem_mb)} "
                    f"!= functions {sorted(vm.functions)}"
                )
            if vm.mem_used_mb != sum(vm.func_mem_mb.values()):
                raise AssertionError(
                    f"{vm.vm_id}: mem_used_mb={vm.mem_used_mb} drifted from "
                    f"Σ charges {sum(vm.func_mem_mb.values())}"
                )
            if vm.mem_used_mb > vm.mem_mb:
                raise AssertionError(
                    f"{vm.vm_id}: {vm.mem_used_mb} MB placed on a "
                    f"{vm.mem_mb} MB VM"
                )
            for fid, charged in vm.func_mem_mb.items():
                if charged != mgr.mem_need(fid):
                    raise AssertionError(
                        f"{vm.vm_id}/{fid}: charged {charged} MB, "
                        f"requirement is {mgr.mem_need(fid)} MB"
                    )
            if vm.functions != vm_occ.get(vm.vm_id, set()):
                raise AssertionError(
                    f"{vm.vm_id}: manager hosts {sorted(vm.functions)}, replay "
                    f"has {sorted(vm_occ.get(vm.vm_id, set()))}"
                )

    # ------------------------------------------------------------------
    def run(self) -> MultiTenantResult:
        cfg = self.cfg
        for t in range(cfg.duration_s()):
            now = float(t)
            if cfg.failover_at is not None and t == cfg.failover_at:
                self._failover()
            self.sim.run(until=now)  # advance flows/activations to this tick
            for ts in self.tenants:  # fixed registration order: deterministic
                self._step_tenant(ts, t, now)
            # VM-hours: one second per VM currently out of the free pool
            self.vm_seconds += float(cfg.vm_pool_size - len(self.mgr.free_pool))
            if cfg.check_partition:
                self._check_partition()
        return self._result()

    def _result(self) -> MultiTenantResult:
        per_tenant: dict[str, TenantResult] = {}
        first_req = float("inf")
        last_ready = float("-inf")
        total_prov = 0.0
        for ts in self.tenants:
            resp = sorted(lat for _, lat in ts.responses)
            prov = sorted(ts.prov_latencies)
            total_prov += sum(prov)
            first_req = min(first_req, ts.first_req_t)
            last_ready = max(last_ready, ts.last_ready_t)
            per_tenant[ts.cfg.function_id] = TenantResult(
                function_id=ts.cfg.function_id,
                requests=ts.requests,
                completed=len(resp),
                mean_response_s=sum(resp) / len(resp) if resp else 0.0,
                p99_response_s=_pctl(resp, 0.99),
                mean_prov_s=sum(prov) / len(prov) if prov else 0.0,
                p99_prov_s=_pctl(prov, 0.99),
                prov_makespan_s=(
                    ts.last_ready_t - ts.first_req_t if prov else 0.0
                ),
                peak_vms=ts.peak_vms,
                provisioned=len(prov),
                p50_response_s=_pctl(resp, 0.50),
                wasted_provisions=ts.wasted
                + (
                    sum(
                        1
                        for i in ts.instances.values()
                        if i.busy_total_s < i.prov_cost_s
                    )
                    if self.cfg.serving is not None
                    else 0
                ),
            )
        return MultiTenantResult(
            system=self.cfg.system,
            per_tenant=per_tenant,
            timelines={ts.cfg.function_id: ts.timeline for ts in self.tenants},
            peak_registry_egress=self.sim.peak_registry_egress,
            peak_shard_egress=dict(self.sim.peak_shard_egress),
            prov_makespan_s=(
                last_ready - first_req if last_ready > float("-inf") else 0.0
            ),
            total_prov_time_s=total_prov,
            failovers=self.failovers,
            manager_stats=dict(self.mgr.stats),
            free_vms=len(self.mgr.free_pool),
            vm_seconds=self.vm_seconds,
            cold_starts=sum(len(ts.prov_latencies) for ts in self.tenants),
            peak_nic_utilization=self.sim.peak_nic_utilization,
        )


def run_multi_tenant(cfg: MultiTenantConfig) -> MultiTenantResult:
    """One-shot convenience wrapper (mirrors ``repro.sim.scale.run_scale``)."""
    return MultiTenantReplay(cfg).run()
