"""Vectorized array-based fluid-flow engine (the 100k-VM backend).

Third member of the engine oracle chain (``reference`` → ``incremental`` →
``vector``): the same rate model and event semantics as
:class:`repro.sim.engine.FlowSim`, but live flows are flat numpy arrays —
src-node index, dst-node index, streaming depth, parent index, remaining
bytes, rate, last-settle time, epoch — instead of per-flow Python objects
chained through dict registries.  The incremental engine's per-flow
``(depth, fid)`` heap walk becomes vectorized passes:

* per-node active-flow counts are maintained as int arrays (the bincount of
  the per-NIC registries), so the equal-split denominators come from two
  gathers;
* the out-cap / in-cap / per-stream / decompress / QPS-throttle minimum is
  one elementwise ``np.minimum`` chain over the dirty candidates;
* parent-chain rate propagation is a **wide-front sweep**: each round
  re-rates every pending flow with no pending ancestor — across all trees
  and tenants at once — so independent subtrees at different streaming
  depths collapse into one dispatch instead of one per depth.  A flow is
  rated exactly once per recompute, after its parent's rate is final, so
  the rate *values* are the ones the incremental engine's ``(depth, fid)``
  worklist pops compute; the order-sensitive accounting (registry/NIC
  running sums, the rate log) is deferred to a single ``(depth, fid)``-
  sorted pass at the end of the call, which reproduces the incremental
  engine's add sequence bit-for-bit;
* completion times are batch-computed as ``t_last + remaining / rate`` over
  the changed slice and fed to the same lazily-invalidated epoch heap, with
  all same-timestamp completions extracted in one batch.

Determinism and bit-identity: every arithmetic step mirrors the incremental
engine's operand order (IEEE-754 double ops on the same operands give the
same bits whether they come from a Python float or a float64 array), event
and completion ordering reuse the same ``(time, seq)`` / ``(t, fid)``
tie-breaks, and per-shard registry egress is accumulated per-flow in the
same ``(depth, fid)`` order so the running sums — not just the results —
match.  The differential suite (``tests/test_vector_engine.py``) pins event
logs SHA-identical and rates to 1e-9 against both other engines.

Trace strings are materialized lazily (the raw log stores ``(t, kind,
fid)`` tuples) so the hot loop never formats text; ``sim.trace`` renders
the identical strings on first access.
"""
from __future__ import annotations

import heapq
import math
from typing import Callable, Optional

import numpy as np

from repro.core.registry import is_registry_node, shard_index
from repro.core.topology import DistributionPlan, Flow

from .engine import SimConfig, plan_releases, wire_runnable

__all__ = ["VectorFlowSim", "VectorJaxFlowSim"]

_F64 = np.float64
_I64 = np.int64
_EMPTY_I64 = np.empty(0, dtype=_I64)  # shared read-only seed for node fid bases


class _VFlowState:
    """Per-flow handle exposing the FlowSim flow-state API over the arrays.

    Scheduling topology (parent / children / waiters) and lifecycle flags
    stay on the object — they drive Python-side event wiring — while the
    numeric hot fields (``remaining`` / ``rate`` / ``t_last`` / ``epoch``)
    live only in the engine arrays and are exposed as read-only properties.
    """

    __slots__ = (
        "flow", "total", "start_after", "block_mode", "pipeline_delay",
        "on_done", "on_notify", "parent", "children", "waiters", "started",
        "done", "t_start", "t_done", "depth", "fid", "_eng",
    )

    def __init__(self, flow: Flow, total: float, start_after: float,
                 block_mode: bool, eng: "VectorFlowSim") -> None:
        self.flow = flow
        self.total = total
        self.start_after = start_after
        self.block_mode = block_mode
        self.pipeline_delay = 0.0
        self.on_done: Optional[Callable[[float], None]] = None
        self.on_notify: Optional[Callable[[float], None]] = None
        self.parent: Optional["_VFlowState"] = None
        self.children: list["_VFlowState"] = []
        self.waiters: list["_VFlowState"] = []
        self.started = False
        self.done = False
        self.t_start = math.inf
        self.t_done = math.inf
        self.depth = 0
        self.fid = -1
        self._eng = eng

    @property
    def remaining(self) -> float:
        return float(self._eng._rem[self.fid])

    @property
    def rate(self) -> float:
        return float(self._eng._rate[self.fid])

    @property
    def t_last(self) -> float:
        return float(self._eng._tlast[self.fid])

    @property
    def epoch(self) -> int:
        return int(self._eng._epoch[self.fid])

    # Runnable-prefix milestone (paper §3.2): the threshold and its pending
    # flag live in the engine arrays so the vectorized recompute can batch
    # over them; ``wire_runnable`` writes through this property.
    @property
    def notify_bytes(self) -> float:
        return float(self._eng._fnoti[self.fid])

    @notify_bytes.setter
    def notify_bytes(self, v: float) -> None:
        eng = self._eng
        eng._fnoti[self.fid] = v
        armed = v > 0.0
        eng._fhasnoti[self.fid] = armed
        if armed:
            eng._any_noti = True

    @property
    def notified(self) -> bool:
        return bool(
            self.on_notify is not None and not self._eng._fhasnoti[self.fid]
        )


def _grown(arr: np.ndarray, cap: int) -> np.ndarray:
    out = np.zeros(cap, dtype=arr.dtype)
    out[: len(arr)] = arr
    return out


class VectorFlowSim:
    """Array-based engine; drop-in for FlowSim via ``SimConfig.engine``."""

    def __init__(self, cfg: SimConfig | None = None, *, record_rates: bool = False) -> None:
        self.cfg = cfg or SimConfig()
        self.registry = self.cfg.registry_spec()
        self.now = 0.0
        self._flows: list[_VFlowState] = []  # index == fid
        self._seq = 0
        # Event queue (payloads are fids or callables).  ``schedule`` only
        # appends to ``_ev_pending``; bulk-scheduled events are folded into a
        # (t, seq)-sorted snapshot consumed by index (``_sptr``) so the run
        # loop never heappops a million-entry heap, while events scheduled
        # mid-run drain into a small heap merged with the snapshot head.
        self._ev_pending: list[tuple[float, int, object]] = []
        self._ev_heap: list[tuple[float, int, object]] = []
        self._sts: list[float] = []  # snapshot times
        self._sseq: list[int] = []  # snapshot sequence numbers
        self._spay: list[object] = []  # snapshot payloads
        self._sptr = 0
        self._in_run = False
        self._slow_out: dict[str, float] = {}  # vm_id -> out cap override
        self._record_trace = self.cfg.record_trace
        self._trace_raw: list[tuple[float, int, int]] = []  # (t, 1=start/0=done, fid)
        self._trace_cache: list[tuple[float, str]] = []
        # Flow arrays (capacity-doubled; rows live at index == fid) ------------
        cap = 1024
        self._fcap = cap
        self._fsrc = np.zeros(cap, dtype=_I64)  # node index of (canonical) src
        self._fdst = np.zeros(cap, dtype=_I64)
        self._fdep = np.zeros(cap, dtype=_I64)  # cached streaming depth
        self._fpar = np.full(cap, -1, dtype=_I64)  # parent fid or -1
        self._fblk = np.zeros(cap, dtype=bool)  # block-granular registry fetch
        self._rem = np.zeros(cap, dtype=_F64)
        self._rate = np.zeros(cap, dtype=_F64)
        self._tlast = np.zeros(cap, dtype=_F64)
        self._epoch = np.zeros(cap, dtype=_I64)
        self._fstarted = np.zeros(cap, dtype=bool)
        self._fdone = np.zeros(cap, dtype=bool)
        self._ftot = np.zeros(cap, dtype=_F64)  # total bytes (notify math)
        self._fnoti = np.zeros(cap, dtype=_F64)  # runnable-prefix threshold
        self._fhasnoti = np.zeros(cap, dtype=bool)  # notify armed + unfired
        # Scratch: "scheduled, not yet processed" marks for one _recompute
        # call (always all-False between calls — every scheduled front is
        # processed before the call returns).
        self._fsched = np.zeros(cap, dtype=bool)
        # Node arrays ----------------------------------------------------------
        ncap = 256
        self._ncap = ncap
        self._node_id: dict[str, int] = {}
        self._nname: list[str] = []
        self._nout_cnt = np.zeros(ncap, dtype=_I64)  # active out flows per node
        self._nin_cnt = np.zeros(ncap, dtype=_I64)
        self._nout_cap = np.zeros(ncap, dtype=_F64)  # egress cap (slow-VM aware)
        self._nqps = np.zeros(ncap, dtype=_F64)
        self._nreg = np.zeros(ncap, dtype=bool)  # node is a registry shard
        # node -> fids touching the node (both directions), append-only with
        # lazy compaction: completions leave stale entries behind (dropped
        # by _recompute's done filter) instead of paying a hashed discard
        # per flow.  ``_nlive`` tracks the live flow count per node as plain
        # ints (read once per dirty-node visit, where a numpy scalar read
        # would dominate); a list compacts against the done flags when it
        # outgrows twice its live count — amortized O(1) per completion.
        self._nfids: list[list[int]] = []
        self._nlive: list[int] = []
        self._vm_out = np.zeros(ncap, dtype=_F64)  # running out-rate sums
        self._vm_in = np.zeros(ncap, dtype=_F64)
        # Completion heap + dirty state ---------------------------------------
        self._done_heap: list[tuple[float, int, int]] = []  # (t_finish, fid, epoch)
        self._notify_heap: list[tuple[float, int, int]] = []  # (t_prefix, fid, epoch)
        self._any_noti = False  # any runnable-prefix notify ever armed
        self._n_active = 0
        self._dirty_nodes: set[int] = set()
        self._dirty_fids: set[int] = set()
        # Telemetry ------------------------------------------------------------
        self.events_processed = 0
        self.record_rates = record_rates
        self.rate_log: list[tuple[float, int, float]] = []  # (t, fid, new_rate)
        self._reg_out: dict[str, float] = {}  # shard key -> running egress sum
        self.peak_shard_egress: dict[str, float] = {}
        self.peak_registry_egress = 0.0
        self.peak_nic_utilization = 0.0
        # Dispatch telemetry: wide-front recompute counters.  ``legacy_levels``
        # counts the per-depth sweeps the retired depth-level algorithm would
        # have dispatched on the same closures (one per distinct streaming
        # depth per call), so ``legacy_levels / (fronts_scalar +
        # fronts_vector)`` is the front-widening factor BENCH_scale.json
        # records.  ``front_width_hist`` keys are ``width.bit_length()``
        # (i.e. bucket k holds fronts of width [2^(k-1), 2^k)).
        self.dispatch_stats: dict = {
            "recompute_calls": 0,
            "fronts_scalar": 0,
            "fronts_vector": 0,
            "flows_scalar": 0,
            "flows_vector": 0,
            "legacy_levels": 0,
            "peak_active": 0,
            "front_width_hist": {},
        }

    # ------------------------------------------------------------------
    @property
    def trace(self) -> list[tuple[float, str]]:
        """The (time, event) log, rendered lazily from the raw tuples."""
        raw, cache = self._trace_raw, self._trace_cache
        if len(cache) < len(raw):
            flows = self._flows
            for t, kind, fid in raw[len(cache):]:
                f = flows[fid].flow
                word = "start" if kind else "done"
                cache.append((t, f"{word}#{fid} {f.src}->{f.dst}/{f.piece}"))
        return cache

    # ------------------------------------------------------------------
    def _grow_flows(self, need: int) -> None:
        if need <= self._fcap:
            return
        cap = max(need, self._fcap * 2)
        self._fcap = cap
        self._fsrc = _grown(self._fsrc, cap)
        self._fdst = _grown(self._fdst, cap)
        self._fdep = _grown(self._fdep, cap)
        par = np.full(cap, -1, dtype=_I64)
        par[: len(self._fpar)] = self._fpar
        self._fpar = par
        self._fblk = _grown(self._fblk, cap)
        self._rem = _grown(self._rem, cap)
        self._rate = _grown(self._rate, cap)
        self._tlast = _grown(self._tlast, cap)
        self._epoch = _grown(self._epoch, cap)
        self._fstarted = _grown(self._fstarted, cap)
        self._fdone = _grown(self._fdone, cap)
        self._ftot = _grown(self._ftot, cap)
        self._fnoti = _grown(self._fnoti, cap)
        self._fhasnoti = _grown(self._fhasnoti, cap)
        self._fsched = _grown(self._fsched, cap)

    def _grow_nodes(self, need: int) -> None:
        if need <= self._ncap:
            return
        cap = max(need, self._ncap * 2)
        self._ncap = cap
        self._nout_cnt = _grown(self._nout_cnt, cap)
        self._nin_cnt = _grown(self._nin_cnt, cap)
        self._nout_cap = _grown(self._nout_cap, cap)
        self._nqps = _grown(self._nqps, cap)
        self._nreg = _grown(self._nreg, cap)
        self._vm_out = _grown(self._vm_out, cap)
        self._vm_in = _grown(self._vm_in, cap)

    def _node_idx(self, name: str) -> int:
        """Dense node index; registry names must already be canonical."""
        i = self._node_id.get(name)
        if i is not None:
            return i
        i = len(self._nname)
        self._grow_nodes(i + 1)
        self._node_id[name] = i
        self._nname.append(name)
        self._nfids.append([])
        self._nlive.append(0)
        if is_registry_node(name):
            shard = shard_index(name)
            self._nout_cap[i] = self.registry.egress_of(shard)
            self._nqps[i] = self.registry.qps_of(shard)
            self._nreg[i] = True
        else:
            self._nout_cap[i] = self._slow_out.get(name, self.cfg.vm_nic.out_cap)
            self._nqps[i] = math.inf
        return i

    # ------------------------------------------------------------------
    def set_slow_vm(self, vm_id: str, out_cap: float) -> None:
        """Straggler injection: clamp a VM's egress capacity."""
        self._slow_out[vm_id] = out_cap
        i = self._node_id.get(vm_id)
        if i is not None and not self._nreg[i]:
            self._nout_cap[i] = out_cap
            if self._nlive[i]:
                self._dirty_nodes.add(i)

    def clear_slow_vm(self, vm_id: str) -> None:
        self._slow_out.pop(vm_id, None)
        i = self._node_id.get(vm_id)
        if i is not None and not self._nreg[i]:
            self._nout_cap[i] = self.cfg.vm_nic.out_cap
            if self._nlive[i]:
                self._dirty_nodes.add(i)

    def schedule(self, t: float, fn) -> None:
        """Queue a timed event; ``fn`` is a callable or an internal fid."""
        self._seq += 1
        self._ev_pending.append((t, self._seq, fn))

    def _fold_events(self) -> None:
        """Merge all outstanding events into one (t, seq)-sorted snapshot.

        Pops then cost a list-index bump instead of an O(log n) sift on a
        heap the size of the whole burst.  The (t, seq) key is the exact
        tuple order ``heapq`` would impose (seq is unique), so the global
        event order is bit-identical to the incremental engine's heap.
        """
        evs: list[tuple[float, int, object]] = []
        p = self._sptr
        if p < len(self._spay):
            evs.extend(zip(self._sts[p:], self._sseq[p:], self._spay[p:]))
        evs.extend(self._ev_heap)
        evs.extend(self._ev_pending)
        del self._ev_heap[:]
        del self._ev_pending[:]
        if not evs:
            self._sts, self._sseq, self._spay, self._sptr = [], [], [], 0
            return
        n = len(evs)
        ts = np.fromiter((e[0] for e in evs), dtype=_F64, count=n)
        seqs = np.fromiter((e[1] for e in evs), dtype=_I64, count=n)
        order = np.lexsort((seqs, ts))
        self._sts = ts[order].tolist()
        self._sseq = seqs[order].tolist()
        self._spay = [evs[i][2] for i in order.tolist()]
        self._sptr = 0

    def set_parent(self, st: _VFlowState, parent: Optional[_VFlowState]) -> None:
        """Attach a streaming dependency (see FlowSim.set_parent)."""
        if st.parent is not None:
            try:
                st.parent.children.remove(st)
            except ValueError:  # pragma: no cover - defensive
                pass
        st.parent = parent
        if parent is not None:
            parent.children.append(st)
        st.depth = parent.depth + 1 if parent is not None else 0
        if st.fid >= 0:
            self._fpar[st.fid] = parent.fid if parent is not None else -1
            self._fdep[st.fid] = st.depth
        stack = list(st.children)
        while stack:
            c = stack.pop()
            c.depth = c.parent.depth + 1
            if c.fid >= 0:
                self._fdep[c.fid] = c.depth
            stack.extend(c.children)
        if st.started and not st.done:
            # attaching mid-flight changes the parent-rate cap immediately
            self._dirty_fids.add(st.fid)

    # ------------------------------------------------------------------
    def add_plan(
        self,
        plan: DistributionPlan,
        *,
        t0: float = 0.0,
        on_node_done: Optional[Callable[[str, float], None]] = None,
        on_node_runnable: Optional[Callable[[str, float], None]] = None,
        coordinator_queues: Optional[dict[str, float]] = None,
    ) -> list[_VFlowState]:
        """Register a provisioning wave starting at ``t0``."""
        cfg = self.cfg
        coordinator_queues = coordinator_queues if coordinator_queues is not None else {}
        by_dst: dict[tuple[str, str], _VFlowState] = {}
        states: list[_VFlowState] = []
        for fl, release, block_mode in plan_releases(plan, cfg, t0, coordinator_queues):
            st = _VFlowState(fl, float(fl.bytes), release, block_mode, self)
            states.append(st)
            # streaming dependency: dst of the parent flow == src of this
            # flow, matched per piece (see FlowSim.add_plan)
            by_dst.setdefault((fl.dst, fl.piece), st)
        if plan.streaming:
            block_t = cfg.block_size / cfg.vm_nic.in_cap
            for st in states:
                up = by_dst.get((st.flow.src, st.flow.piece))
                if up is not None:
                    self.set_parent(st, up)
                    st.start_after = max(st.start_after, t0)  # start gated below
                    # child may begin one block (+hop cost) after the parent
                    st.pipeline_delay = block_t + cfg.hop_latency
        self._grow_flows(len(self._flows) + len(states))
        for st in states:
            if on_node_done is not None:
                dst = st.flow.dst
                st.on_done = (
                    lambda t, dst=dst: on_node_done(dst, t)
                )
            fid = len(self._flows)
            st.fid = fid
            self._flows.append(st)
            self._register_flow(st)
        for st in states:
            # parent fids are only all assigned once the loop above finishes
            if st.parent is not None:
                self._fpar[st.fid] = st.parent.fid
        for st in states:
            self._arm_start(st)
        wire_runnable(self, states, on_node_runnable)
        if not self._in_run and len(self._ev_pending) > 2048:
            self._fold_events()  # sort bulk releases outside the timed run
        return states

    def _register_flow(self, st: _VFlowState) -> None:
        fid = st.fid
        fl = st.flow
        src = fl.src
        skey = self.registry.canonical(src) if is_registry_node(src) else src
        self._fsrc[fid] = self._node_idx(skey)
        self._fdst[fid] = self._node_idx(fl.dst)
        self._fdep[fid] = st.depth
        self._fblk[fid] = st.block_mode
        self._rem[fid] = st.total
        self._ftot[fid] = st.total

    def _arm_start(self, st: _VFlowState) -> None:
        if st.parent is not None and not st.parent.started:
            # Gated on the parent's start: no polling — the parent notifies
            # its waiters the moment it starts.
            st.parent.waiters.append(st)
            return
        t = max(st.start_after, self.now)
        if st.parent is not None:
            t = max(t, st.parent.t_start + st.pipeline_delay)
        self.schedule(t, st.fid)

    def _flush_starts(self, fids: list[int]) -> None:
        """Array/registry side of a batch of flows that just started.

        The object-side lifecycle (``started`` flags, waiter releases) runs
        per-flow in event order inside the run loop; everything batchable —
        NIC counts, per-node fid sets, dirty marks, trace — lands here in
        the same order, so the observable state matches flow-at-a-time
        processing exactly.
        """
        now = self.now
        fa = np.asarray(fids, dtype=_I64)
        self._fstarted[fa] = True
        self._tlast[fa] = now
        self._n_active += len(fids)
        sk = self._fsrc[fa]
        dk = self._fdst[fa]
        np.add.at(self._nout_cnt, sk, 1)
        np.add.at(self._nin_cnt, dk, 1)
        sk_l = sk.tolist()
        dk_l = dk.tolist()
        dn = self._dirty_nodes
        nf = self._nfids
        nlive = self._nlive
        for i, fid in enumerate(fids):
            s, d = sk_l[i], dk_l[i]
            nf[s].append(fid)
            nf[d].append(fid)
            nlive[s] += 1
            nlive[d] += 1
        # Counts on both NICs changed: every flow sharing them is dirty.
        dn.update(sk_l)
        dn.update(dk_l)
        if self._record_trace:
            tr = self._trace_raw
            for fid in fids:
                tr.append((now, 1, fid))

    # ------------------------------------------------------------------
    # Vectorized rate maintenance
    # ------------------------------------------------------------------
    def _recompute(self) -> None:
        """Re-rate the dirty closure as wide-front array passes.

        Instead of sweeping the closure one streaming depth at a time (so
        25 trees' level-k flows cost 25 tiny dispatches), each round
        processes the whole **ready front**: every pending flow with no
        pending ancestor, across all trees and tenants at once.  A flow's
        rate depends only on its NIC counts (constant during a recompute)
        and its parent's final rate, so each flow is rated exactly once,
        with the same value the incremental engine's ``(depth, fid)``
        worklist pop computes.  Independent subtrees at different depths
        collapse into one dispatch, and the number of rounds is bounded by
        the number of distinct depths (the min-depth pending flow is always
        ready), so fronts never exceed the retired per-depth sweep count.

        Bit-identity of the running accounting sums is preserved by
        *deferring* the per-shard registry / per-VM NIC delta accumulation
        (and the rate log) to a single ``(depth, fid)``-sorted pass at the
        end of the call: the incremental engine's worklist pops are
        globally ``(depth, fid)``-ascending, so applying the same float64
        deltas in that order reproduces its running sums bit-for-bit.
        Settles, rate/epoch writes and heap pushes are per-flow independent
        and stay inline with each front.
        """
        dn, df = self._dirty_nodes, self._dirty_fids
        self._dirty_nodes, self._dirty_fids = set(), set()
        fdone = self._fdone
        nf, nlive = self._nfids, self._nlive
        buf: list[int] = list(df)
        ext = buf.extend
        for n in dn:
            lst = nf[n]
            if lst:
                if len(lst) > (nlive[n] << 1) + 4:
                    # compaction removes at least half the list, so the work
                    # is amortized O(1) per completed flow; big lists (hot
                    # registry shards) drop their dead weight vectorized
                    if len(lst) > 256:
                        a = np.asarray(lst, dtype=_I64)
                        lst = a[~fdone[a]].tolist()
                    else:
                        lst = [f for f in lst if not fdone[f]]
                    nf[n] = lst
                ext(lst)
        if not buf:
            return
        cfg = self.cfg
        cutoff = cfg.vector_scalar_cutoff
        stats = self.dispatch_stats
        if len(buf) <= 48:
            # Small closure: dedup/sort/filter in plain Python, and when the
            # survivor set is small enough route the whole closure through
            # the scalar mirror — a handful of flows cannot amortize the
            # ~30 fixed-cost numpy dispatches of the array path below, and
            # at mega/giga scale most recompute calls look exactly like this.
            fstarted = self._fstarted
            fs = sorted({f for f in buf if fstarted[f] and not fdone[f]})
            if not fs:
                return
            if len(fs) <= 32:
                stats["recompute_calls"] += 1
                if self._n_active > stats["peak_active"]:
                    stats["peak_active"] = self._n_active
                self._recompute_small(fs, self.now)
                return
            arr = np.asarray(fs, dtype=_I64)
        else:
            # unique() both dedups (a fid sits on two NICs, both may be
            # dirty) and sorts — fronts stay fid-ascending as subsets of
            # this sorted array; stale entries (completed flows) wash out
            # in the filter.
            arr = np.unique(np.asarray(buf, dtype=_I64))
            keep = self._fstarted[arr] & ~fdone[arr]
            if not keep.all():
                arr = arr[keep]
            if arr.size == 0:
                return
        now = self.now
        flows = self._flows
        stats["recompute_calls"] += 1
        if self._n_active > stats["peak_active"]:
            stats["peak_active"] = self._n_active
        hist = stats["front_width_hist"]
        # --- Round assignment (one pass, mostly vectorized) ---------------
        # Round 0 is the wide front: every candidate with no pending
        # ancestor, across all trees and depths at once.  A candidate with
        # a pending ancestor anywhere up its *live* chain is deferred to
        # round ``depth - min_depth`` — a conservative slot that keeps every
        # ancestor (including settled intermediates that may re-join via
        # cascade) strictly earlier: a flow at round r can only be affected
        # by flows at rounds < r, so each flow is rated exactly once, after
        # its parent's rate is final.  Empty rounds cost nothing (dict).
        fdep = self._fdep
        mask = self._fsched  # scheduled-not-yet-processed marks (all-False
        mask[arr] = True  # between calls; every front clears its slice)
        blocked_any = False
        dep_arr = None
        par_in = None
        if arr.size > 1:
            dep_arr = fdep[arr]
            par_arr = self._fpar[arr]
            pos = np.searchsorted(arr, par_arr)
            par_in = arr[np.minimum(pos, arr.size - 1)] == par_arr
            # (par_arr == -1 never matches: fids are non-negative)
            maybe = np.flatnonzero(~par_in & (par_arr >= 0))
            if maybe.size:
                # Gap scan: a settled (non-candidate) parent can hide a
                # pending grandparent whose change will cascade back through
                # it — those flows must wait too.  Lane-parallel up-walk:
                # every undecided lane ascends one ancestor per step,
                # dropping out when it hits a pending candidate (blocked),
                # the root, or a done ancestor (a done flow no longer
                # transmits rate changes downward); steps are bounded by the
                # deepest live chain, with every step fully vectorized.
                fpar = self._fpar
                idx = maybe
                cur = par_arr[maybe]
                live = ~fdone[cur]
                if not live.all():
                    idx = idx[live]
                    cur = cur[live]
                while idx.size:
                    hit = mask[cur]
                    if hit.any():
                        par_in[idx[hit]] = True
                        miss = ~hit
                        idx = idx[miss]
                        if not idx.size:
                            break
                        cur = cur[miss]
                    cur = fpar[cur]
                    live = cur >= 0
                    if not live.all():
                        idx = idx[live]
                        if not idx.size:
                            break
                        cur = cur[live]
                    live = ~fdone[cur]
                    if not live.all():
                        idx = idx[live]
                        cur = cur[live]
            blocked_any = bool(par_in.any())
        # Deferred (depth, fid)-ordered accounting (see docstring) ---------
        acc_fids: list[np.ndarray] = []
        acc_old: list[np.ndarray] = []
        acc_new: list[np.ndarray] = []
        sc_fids: list[int] = []
        sc_old: list[float] = []
        sc_new: list[float] = []
        dseen: set[int] = set()  # distinct depths the retired sweep would pay
        scalar_front = self._scalar_front
        vector_front = self._vector_front

        def _front(fids: np.ndarray) -> list[int]:
            mask[fids] = False
            w = fids.size
            hist_b = w.bit_length()
            hist[hist_b] = hist.get(hist_b, 0) + 1
            if w <= 64:
                dseen.update(fdep[fids].tolist())
            else:
                dseen.update(np.unique(fdep[fids]).tolist())
            if w <= cutoff:
                stats["fronts_scalar"] += 1
                stats["flows_scalar"] += w
                return scalar_front(fids, now, flows, mask, sc_fids, sc_old, sc_new)
            stats["fronts_vector"] += 1
            stats["flows_vector"] += w
            return vector_front(fids, now, flows, mask, acc_fids, acc_old, acc_new)

        if not blocked_any:
            # Fast path (the common case): nothing in the closure waits on
            # anything else in it — the whole closure is round 0, and each
            # cascade generation is the next front.  Fronts are disjoint
            # (every flow has one parent, processed exactly once).
            kids = _front(arr)
            while kids:
                ka = np.asarray(kids, dtype=_I64)
                ka.sort()
                kids = _front(ka)
        else:
            rounds = np.zeros(arr.size, dtype=_I64)
            dmin = int(dep_arr.min())
            bi = np.flatnonzero(par_in)
            rounds[bi] = dep_arr[bi] - dmin
            order = np.lexsort((arr, rounds))
            sarr = arr[order]
            srnd = rounds[order]
            cuts = np.flatnonzero(np.diff(srnd)) + 1
            sched: dict[int, list[np.ndarray]] = {}
            for rv, chunk in zip(
                srnd[np.concatenate(([0], cuts))].tolist(), np.split(sarr, cuts)
            ):
                sched[rv] = [chunk]
            while sched:
                cur = min(sched)
                chunks = sched.pop(cur)
                if len(chunks) == 1:
                    fids = chunks[0]
                else:
                    # chunks are disjoint: cascade kids come via their single
                    # parent and the mask filter keeps already-scheduled
                    # closure members in their own (later) slot
                    fids = np.concatenate(chunks)
                    fids.sort()
                kids = _front(fids)
                if kids:
                    # Cascade: changed parents re-rate their live children
                    # next round (a child still scheduled later keeps its
                    # own slot).
                    sched.setdefault(cur + 1, []).append(
                        np.asarray(kids, dtype=_I64)
                    )
        # The retired depth-sweep dispatched one pass per distinct streaming
        # depth over the exact same processed set; count what it would have
        # cost on this closure so one run yields the honest reduction ratio.
        stats["legacy_levels"] += len(dseen)
        if sc_fids:
            acc_fids.append(np.asarray(sc_fids, dtype=_I64))
            acc_old.append(np.asarray(sc_old, dtype=_F64))
            acc_new.append(np.asarray(sc_new, dtype=_F64))
        if not acc_fids:
            return
        if len(acc_fids) == 1:
            allf, allo, alln = acc_fids[0], acc_old[0], acc_new[0]
        else:
            allf = np.concatenate(acc_fids)
            allo = np.concatenate(acc_old)
            alln = np.concatenate(acc_new)
        order = np.lexsort((allf, self._fdep[allf]))
        allf = allf[order]
        alln = alln[order]
        delta = alln - allo[order]
        srcc = self._fsrc[allf]
        dstc = self._fdst[allf]
        isreg = self._nreg[srcc]
        vm_nodes = None
        if isreg.any():
            # per-flow dict accumulation in (depth, fid) order — the running
            # per-shard sums must match the incremental engine bit-for-bit,
            # so mirror its add sequence exactly
            names = self._nname
            reg = self._reg_out
            dl = delta.tolist()
            for k in np.flatnonzero(isreg).tolist():
                skey = names[srcc[k]]
                reg[skey] = reg.get(skey, 0.0) + dl[k]
            vm = ~isreg
            if vm.any():
                vi = np.flatnonzero(vm)
                vm_nodes = srcc[vi]
                np.add.at(self._vm_out, vm_nodes, delta[vi])
        else:
            vm_nodes = srcc
            np.add.at(self._vm_out, srcc, delta)
        np.add.at(self._vm_in, dstc, delta)
        if self.record_rates:
            rl = self.rate_log
            for fid, rn in zip(allf.tolist(), alln.tolist()):
                rl.append((now, fid, rn))
        # Peak telemetry (identical comparison sequence to the incremental
        # engine; peaks are max-folds, so ordering cannot change the result).
        if self._reg_out:
            pse = self.peak_shard_egress
            for skey, egress in self._reg_out.items():
                if egress > pse.get(skey, 0.0):
                    pse[skey] = egress
            total = sum(self._reg_out.values())
            if total > self.peak_registry_egress:
                self.peak_registry_egress = total
        if vm_nodes is not None and vm_nodes.size:
            nodes = np.unique(vm_nodes)
            caps = self._nout_cap[nodes]
            valid = (caps > 0) & np.isfinite(caps)
            if valid.any():
                u = float((self._vm_out[nodes[valid]] / caps[valid]).max())
                if u > self.peak_nic_utilization:
                    self.peak_nic_utilization = u
        in_cap = cfg.vm_nic.in_cap
        if in_cap > 0 and in_cap != math.inf:
            nodes = np.unique(dstc)
            u = float((self._vm_in[nodes] / in_cap).max())
            if u > self.peak_nic_utilization:
                self.peak_nic_utilization = u

    def _recompute_small(self, fs: list[int], now: float) -> None:
        """Whole-closure scalar mirror for small dirty closures.

        Identical round assignment, fid-sorted fronts and deferred
        (depth, fid)-sorted accounting as :meth:`_recompute`'s array path,
        executed per flow in plain Python: every float64 operation runs on
        the same values in the same order, so rates, heap keys, running
        registry/NIC sums and peak telemetry are all bit-identical.  Fronts
        are routed by the same ``vector_scalar_cutoff`` rule — a wide
        cascade generation still goes through :meth:`_vector_front` — so
        the dispatch telemetry (front counts, width histogram, legacy-level
        equivalents) matches what the array path would record.
        """
        flows = self._flows
        fdep = self._fdep
        fpar_a = self._fpar
        fdone = self._fdone
        mask = self._fsched
        stats = self.dispatch_stats
        hist = stats["front_width_hist"]
        cfg = self.cfg
        psc = cfg.per_stream_cap
        icap = cfg.vm_nic.in_cap
        dec = cfg.decompress_rate
        bsz = cfg.block_size
        rate_a, rem_a, tlast_a, ep_a = self._rate, self._rem, self._tlast, self._epoch
        no_cnt, ni_cnt = self._nout_cnt, self._nin_cnt
        no_cap, qps_a = self._nout_cap, self._nqps
        blk_a = self._fblk
        fsrc_a, fdst_a = self._fsrc, self._fdst
        heap = self._done_heap
        nheap = self._notify_heap
        hasn, fnoti, ftot = self._fhasnoti, self._fnoti, self._ftot
        # Round assignment (scalar mirror): round 0 unless a live-chain
        # ancestor is also a candidate, else the conservative depth slot.
        sched: dict[int, list[int]] = {}
        if len(fs) == 1:
            mask[fs[0]] = True
            sched[0] = fs
        else:
            cand = set(fs)
            deps = [int(fdep[f]) for f in fs]
            dmin = min(deps)
            sget = sched.setdefault
            for i, fid in enumerate(fs):
                mask[fid] = True
                r = 0
                p = fpar_a[fid]
                while p >= 0 and not fdone[p]:
                    if p in cand:
                        r = deps[i] - dmin
                        break
                    p = fpar_a[p]
                sget(r, []).append(fid)
        cutoff = cfg.vector_scalar_cutoff
        sc_fids: list[int] = []
        sc_old: list[float] = []
        sc_new: list[float] = []
        acc_fids: list[np.ndarray] = []
        acc_old: list[np.ndarray] = []
        acc_new: list[np.ndarray] = []
        dseen: set = set()
        while sched:
            cur = min(sched)
            front = sched.pop(cur)
            front.sort()
            w = len(front)
            hist_b = w.bit_length()
            hist[hist_b] = hist.get(hist_b, 0) + 1
            if w > cutoff:
                # A wide cascade generation (a changed parent fanning out)
                # still goes through the array front, exactly as the array
                # path would route it; its (fid, old, new) triples merge
                # into the same sorted accounting tail below.
                fa = np.asarray(front, dtype=_I64)
                dseen.update(fdep[fa].tolist())
                stats["fronts_vector"] += 1
                stats["flows_vector"] += w
                mask[fa] = False
                kids = self._vector_front(
                    fa, now, flows, mask, acc_fids, acc_old, acc_new
                )
                if kids:
                    sched.setdefault(cur + 1, []).extend(kids)
                continue
            stats["fronts_scalar"] += 1
            stats["flows_scalar"] += w
            kids = []
            for fid in front:
                mask[fid] = False
                dseen.add(int(fdep[fid]))
                s = fsrc_a[fid]
                n_out = float(no_cnt[s])
                r = min(psc, float(no_cap[s]) / n_out)
                r = min(r, icap / float(ni_cnt[fdst_a[fid]]))
                r = min(r, dec)
                if blk_a[fid]:
                    r = min(r, bsz * float(qps_a[s]) / n_out)
                p = fpar_a[fid]
                if p >= 0 and not fdone[p]:
                    r = min(r, float(rate_a[p]))
                old = float(rate_a[fid])
                if r == old:
                    continue
                tl = float(tlast_a[fid])
                rem = float(rem_a[fid])
                if now > tl:
                    if old > 0.0:
                        rem = max(0.0, rem - old * (now - tl))
                        rem_a[fid] = rem
                    tlast_a[fid] = now
                    tl = now
                rate_a[fid] = r
                e = int(ep_a[fid]) + 1
                ep_a[fid] = e
                if r > 0.0:
                    heapq.heappush(heap, (tl + rem / r, fid, e))
                    if hasn[fid]:
                        pend = float(fnoti[fid]) - (float(ftot[fid]) - rem)
                        heapq.heappush(nheap, (tl + max(0.0, pend) / r, fid, e))
                sc_fids.append(fid)
                sc_old.append(old)
                sc_new.append(r)
                cs = flows[fid].children
                if cs:
                    for c in cs:
                        if c.started and not c.done and not mask[c.fid]:
                            kids.append(c.fid)
            if kids:
                sched.setdefault(cur + 1, []).extend(kids)
        stats["legacy_levels"] += len(dseen)
        if acc_fids:
            for a_f, a_o, a_n in zip(acc_fids, acc_old, acc_new):
                sc_fids.extend(a_f.tolist())
                sc_old.extend(a_o.tolist())
                sc_new.extend(a_n.tolist())
        if not sc_fids:
            return
        # Deferred accounting, (depth, fid)-sorted — the same running-sum
        # add sequence as the array path's lexsorted tail.
        names = self._nname
        reg = self._reg_out
        nreg = self._nreg
        vm_out, vm_in = self._vm_out, self._vm_in
        rl = self.rate_log if self.record_rates else None
        vm_nodes: list[int] = []
        dst_nodes: list[int] = []
        for _, fid, old, new in sorted(
            zip((int(fdep[f]) for f in sc_fids), sc_fids, sc_old, sc_new)
        ):
            delta = new - old
            s = int(fsrc_a[fid])
            d = int(fdst_a[fid])
            if nreg[s]:
                skey = names[s]
                reg[skey] = reg.get(skey, 0.0) + delta
            else:
                vm_out[s] = vm_out[s] + delta
                vm_nodes.append(s)
            vm_in[d] = vm_in[d] + delta
            dst_nodes.append(d)
            if rl is not None:
                rl.append((now, fid, new))
        # Peak telemetry (same max-folds as the array path).
        if reg:
            pse = self.peak_shard_egress
            for skey, egress in reg.items():
                if egress > pse.get(skey, 0.0):
                    pse[skey] = egress
            total = sum(reg.values())
            if total > self.peak_registry_egress:
                self.peak_registry_egress = total
        if vm_nodes:
            u = -math.inf
            for nid in set(vm_nodes):
                cap = float(no_cap[nid])
                if cap > 0.0 and cap != math.inf:
                    un = float(vm_out[nid]) / cap
                    if un > u:
                        u = un
            if u > self.peak_nic_utilization:
                self.peak_nic_utilization = u
        if icap > 0.0 and icap != math.inf:
            u = -math.inf
            for nid in set(dst_nodes):
                un = float(vm_in[nid]) / icap
                if un > u:
                    u = un
            if u > self.peak_nic_utilization:
                self.peak_nic_utilization = u

    def _front_rates(
        self, fids: np.ndarray, src: np.ndarray, dst: np.ndarray
    ) -> np.ndarray:
        """Elementwise min-cap chain over one ready front (numpy path).

        Seam for the accelerator tier: :class:`VectorJaxFlowSim` overrides
        this with the fused jax/pallas kernel; everything around it (fronts,
        settles, heaps, deferred accounting) is shared.
        """
        cfg = self.cfg
        n_out = self._nout_cnt[src]
        r = np.minimum(cfg.per_stream_cap, self._nout_cap[src] / n_out)
        np.minimum(r, cfg.vm_nic.in_cap / self._nin_cnt[dst], out=r)
        np.minimum(r, cfg.decompress_rate, out=r)
        blk = self._fblk[fids]
        if blk.any():
            # per-shard request throttle shared by the shard's streams
            bi = np.flatnonzero(blk)
            r[bi] = np.minimum(
                r[bi], cfg.block_size * self._nqps[src[bi]] / n_out[bi]
            )
        par = self._fpar[fids]
        pm = par >= 0
        if pm.any():
            pi = np.flatnonzero(pm)
            live = ~self._fdone[par[pi]]
            if not live.all():
                pi = pi[live]
            if pi.size:
                r[pi] = np.minimum(r[pi], self._rate[par[pi]])
        return r

    def _vector_front(
        self,
        fids: np.ndarray,
        now: float,
        flows: list[_VFlowState],
        mask: np.ndarray,
        acc_fids: list[np.ndarray],
        acc_old: list[np.ndarray],
        acc_new: list[np.ndarray],
    ) -> list[int]:
        """One wide front, vectorized; returns cascade children.

        Rates, settles, epochs and heap entries update inline (per-flow
        independent); the order-sensitive delta accounting is *collected*
        as (fid, old, new) triples for ``_recompute``'s deferred sorted
        pass.
        """
        src = self._fsrc[fids]
        dst = self._fdst[fids]
        r = self._front_rates(fids, src, dst)
        changed = r != self._rate[fids]
        if not changed.any():
            return []
        ci = np.flatnonzero(changed)
        ch = fids[ci]  # fid-ascending (fids sorted)
        r_new = r[ci]
        old = self._rate[ch]
        # settle under the old rate (mirror of FlowSim._settle)
        tl = self._tlast[ch]
        adv = now > tl
        if adv.any():
            ai = np.flatnonzero(adv)
            aj = ch[ai]
            pos = old[ai] > 0.0
            if pos.any():
                ak = aj[pos]
                self._rem[ak] = np.maximum(
                    0.0, self._rem[ak] - self._rate[ak] * (now - self._tlast[ak])
                )
            self._tlast[aj] = now
        self._rate[ch] = r_new
        self._epoch[ch] += 1
        pos_r = r_new > 0.0
        est = np.zeros(ch.size, dtype=_F64)
        if pos_r.any():
            pj = np.flatnonzero(pos_r)
            est[pj] = self._tlast[ch[pj]] + self._rem[ch[pj]] / r_new[pj]
        ch_l = ch.tolist()
        ep_l = self._epoch[ch].tolist()
        entries = [
            (t, fid, e)
            for t, fid, e, p in zip(est.tolist(), ch_l, ep_l, pos_r.tolist())
            if p
        ]
        nmask = self._fhasnoti[ch] & pos_r
        if nmask.any():
            # prefix-landing estimate under the new rate; a threshold
            # already passed clamps to "due now" (mirror of FlowSim)
            nj = np.flatnonzero(nmask)
            chn = ch[nj]
            pend = self._fnoti[chn] - (self._ftot[chn] - self._rem[chn])
            nt = self._tlast[chn] + np.maximum(0.0, pend) / r_new[nj]
            nheap = self._notify_heap
            for t, fid, e in zip(
                nt.tolist(), chn.tolist(), self._epoch[chn].tolist()
            ):
                heapq.heappush(nheap, (t, fid, e))
        if entries:
            heap = self._done_heap
            if len(entries) > 1024 and 2 * len(entries) > len(heap):
                # bulk path: drop stale entries while we rebuild anyway
                fdone, fstarted, ep = self._fdone, self._fstarted, self._epoch
                heap = [
                    e for e in heap
                    if fstarted[e[1]] and not fdone[e[1]] and e[2] == ep[e[1]]
                ]
                heap.extend(entries)
                heapq.heapify(heap)
                self._done_heap = heap
            else:
                for e in entries:
                    heapq.heappush(heap, e)
        acc_fids.append(ch)
        acc_old.append(old)
        acc_new.append(r_new)
        # A parent-rate change propagates down the streaming chain.  A child
        # already pending stays where it is; a child already *processed* is
        # impossible (it was ancestor-blocked while this parent was pending).
        kids: list[int] = []
        for fid in ch_l:
            cs = flows[fid].children
            if cs:
                for c in cs:
                    if c.started and not c.done and not mask[c.fid]:
                        kids.append(c.fid)
        return kids

    def _scalar_front(
        self,
        fids: np.ndarray,
        now: float,
        flows: list[_VFlowState],
        mask: np.ndarray,
        sc_fids: list[int],
        sc_old: list[float],
        sc_new: list[float],
    ) -> list[int]:
        """One narrow front as scalar math; returns cascade children.

        Gathers each array once, then runs the per-flow min-cap chain /
        settle in plain Python — the exact operations the vectorized path
        performs, on the same float64 values in the same order, so results
        are bit-identical while skipping ~40 fixed-cost numpy dispatches on
        a handful of flows.  Changed flows are appended to the ``sc_*``
        lists for the deferred sorted accounting pass.
        """
        cfg = self.cfg
        psc = cfg.per_stream_cap
        icap = cfg.vm_nic.in_cap
        dec = cfg.decompress_rate
        bsz = cfg.block_size
        rate_a, rem_a, tlast_a, ep_a = self._rate, self._rem, self._tlast, self._epoch
        fdone = self._fdone
        heap = self._done_heap
        nheap = self._notify_heap
        hasn, fnoti, ftot = self._fhasnoti, self._fnoti, self._ftot
        kids: list[int] = []
        fl = fids.tolist()
        if len(fl) <= 4:
            # Tiny front: a handful of scalar reads per flow beats ten
            # whole-front fancy gathers whose fixed dispatch cost dominates
            # at this width.  Same float64 reads, same op order —
            # bit-identical to the gather path below.
            fsrc_a, fdst_a = self._fsrc, self._fdst
            no_cnt, ni_cnt = self._nout_cnt, self._nin_cnt
            no_cap, qps_a = self._nout_cap, self._nqps
            blk_a, par_a = self._fblk, self._fpar
            for fid in fl:
                s = fsrc_a[fid]
                n_out = float(no_cnt[s])
                r = min(psc, float(no_cap[s]) / n_out)
                r = min(r, icap / float(ni_cnt[fdst_a[fid]]))
                r = min(r, dec)
                if blk_a[fid]:
                    r = min(r, bsz * float(qps_a[s]) / n_out)
                p = par_a[fid]
                if p >= 0 and not fdone[p]:
                    r = min(r, float(rate_a[p]))
                old = float(rate_a[fid])
                if r == old:
                    continue
                tl = float(tlast_a[fid])
                rem = float(rem_a[fid])
                if now > tl:
                    if old > 0.0:
                        rem = max(0.0, rem - old * (now - tl))
                        rem_a[fid] = rem
                    tlast_a[fid] = now
                    tl = now
                rate_a[fid] = r
                e = int(ep_a[fid]) + 1
                ep_a[fid] = e
                if r > 0.0:
                    heapq.heappush(heap, (tl + rem / r, fid, e))
                    if hasn[fid]:
                        pend = float(fnoti[fid]) - (float(ftot[fid]) - rem)
                        heapq.heappush(nheap, (tl + max(0.0, pend) / r, fid, e))
                sc_fids.append(fid)
                sc_old.append(old)
                sc_new.append(r)
                cs = flows[fid].children
                if cs:
                    for c in cs:
                        if c.started and not c.done and not mask[c.fid]:
                            kids.append(c.fid)
            return kids
        src = self._fsrc[fids]
        dst = self._fdst[fids]
        no_l = self._nout_cnt[src].tolist()
        ni_l = self._nin_cnt[dst].tolist()
        oc_l = self._nout_cap[src].tolist()
        qps_l = self._nqps[src].tolist()
        blk_l = self._fblk[fids].tolist()
        par_l = self._fpar[fids].tolist()
        old_l = self._rate[fids].tolist()
        tl_l = self._tlast[fids].tolist()
        rem_l = self._rem[fids].tolist()
        for i, fid in enumerate(fl):
            n_out = no_l[i]
            r = min(psc, oc_l[i] / n_out)
            r = min(r, icap / ni_l[i])
            r = min(r, dec)
            if blk_l[i]:
                r = min(r, bsz * qps_l[i] / n_out)
            p = par_l[i]
            if p >= 0 and not fdone[p]:
                r = min(r, float(rate_a[p]))
            old = old_l[i]
            if r == old:
                continue
            tl = tl_l[i]
            if now > tl:
                if old > 0.0:
                    rem = max(0.0, rem_l[i] - old * (now - tl))
                    rem_a[fid] = rem
                    rem_l[i] = rem
                tlast_a[fid] = now
                tl = now
            rate_a[fid] = r
            e = int(ep_a[fid]) + 1
            ep_a[fid] = e
            if r > 0.0:
                heapq.heappush(heap, (tl + rem_l[i] / r, fid, e))
                if hasn[fid]:
                    # prefix-landing estimate under the new rate; clamps to
                    # "due now" when the threshold has already passed
                    pend = float(fnoti[fid]) - (float(ftot[fid]) - rem_l[i])
                    heapq.heappush(nheap, (tl + max(0.0, pend) / r, fid, e))
            sc_fids.append(fid)
            sc_old.append(old)
            sc_new.append(r)
            # A parent-rate change propagates down the streaming chain.
            cs = flows[fid].children
            if cs:
                for c in cs:
                    if c.started and not c.done and not mask[c.fid]:
                        kids.append(c.fid)
        return kids

    # ------------------------------------------------------------------
    def _compact_done_heap(self) -> None:
        fdone, fstarted, ep = self._fdone, self._fstarted, self._epoch
        heap = [
            e for e in self._done_heap
            if fstarted[e[1]] and not fdone[e[1]] and e[2] == ep[e[1]]
        ]
        heapq.heapify(heap)
        self._done_heap = heap

    def _next_completion(self) -> float:
        """Earliest valid completion time (lazily dropping stale entries)."""
        heap = self._done_heap
        if not heap:
            return math.inf
        if len(heap) > 64 and len(heap) > 4 * self._n_active:
            self._compact_done_heap()
            heap = self._done_heap
        fdone, fstarted, ep = self._fdone, self._fstarted, self._epoch
        while heap:
            t, fid, epoch = heap[0]
            if fdone[fid] or not fstarted[fid] or epoch != ep[fid]:
                heapq.heappop(heap)
                continue
            return t
        return math.inf

    def _next_notify(self) -> float:
        """Earliest valid runnable-prefix time (same lazy invalidation)."""
        heap = self._notify_heap
        if not heap:
            return math.inf
        fdone, fstarted, ep = self._fdone, self._fstarted, self._epoch
        hasn = self._fhasnoti
        if len(heap) > 64 and len(heap) > 4 * self._n_active:
            heap = [
                e for e in heap
                if fstarted[e[1]] and not fdone[e[1]] and hasn[e[1]]
                and e[2] == ep[e[1]]
            ]
            heapq.heapify(heap)
            self._notify_heap = heap
        while heap:
            t, fid, epoch = heap[0]
            if fdone[fid] or not fstarted[fid] or not hasn[fid] or epoch != ep[fid]:
                heapq.heappop(heap)
                continue
            return t
        return math.inf

    def _complete_batch(self, batch: list[int]) -> None:
        """Retire every flow finishing at this instant in (t, fid) order.

        ``np.add.at`` is unbuffered and applies updates in index order, so
        the per-node running NIC sums see the exact same float sequence as
        completing the flows one at a time; registry egress keeps the
        per-flow dict walk because its running sums are order-pinned
        against the incremental engine.
        """
        now = self.now
        flows = self._flows
        if len(batch) <= 8:
            # Small batch: per-flow scalar updates apply the exact same op
            # sequence to every accumulator (the vectorized path's add.at
            # calls are index-ordered and hit disjoint arrays), minus ~15
            # fixed-cost numpy dispatches.
            fsrc_a, fdst_a, rate_a = self._fsrc, self._fdst, self._rate
            fdone, rem_a, tlast_a = self._fdone, self._rem, self._tlast
            no_cnt, ni_cnt = self._nout_cnt, self._nin_cnt
            nreg, vm_out, vm_in = self._nreg, self._vm_out, self._vm_in
            nlive = self._nlive
            dn, df = self._dirty_nodes, self._dirty_fids
            names, reg = self._nname, self._reg_out
            for fid in batch:
                s = int(fsrc_a[fid])
                d = int(fdst_a[fid])
                r = float(rate_a[fid])
                fdone[fid] = True
                rem_a[fid] = 0.0
                tlast_a[fid] = now
                no_cnt[s] -= 1
                ni_cnt[d] -= 1
                if nreg[s]:
                    reg[names[s]] -= r
                else:
                    vm_out[s] -= r
                vm_in[d] -= r
                dn.add(s)
                dn.add(d)
                nlive[s] -= 1
                nlive[d] -= 1
                st = flows[fid]
                st.done = True
                st.t_done = now
                cs = st.children
                if cs:
                    for c in cs:
                        if c.started and not c.done:
                            df.add(c.fid)
            self._n_active -= len(batch)
            self.events_processed += len(batch)
            if self._record_trace:
                tr = self._trace_raw
                for fid in batch:
                    tr.append((now, 0, fid))
            return
        fa = np.asarray(batch, dtype=_I64)
        sk = self._fsrc[fa]
        dk = self._fdst[fa]
        rt = self._rate[fa]
        self._fdone[fa] = True
        self._rem[fa] = 0.0
        self._tlast[fa] = now
        np.add.at(self._nout_cnt, sk, -1)
        np.add.at(self._nin_cnt, dk, -1)
        isreg = self._nreg[sk]
        vm = ~isreg
        if vm.any():
            np.add.at(self._vm_out, sk[vm], -rt[vm])
        np.add.at(self._vm_in, dk, -rt)
        self._n_active -= len(batch)
        self.events_processed += len(batch)
        sk_l = sk.tolist()
        dk_l = dk.tolist()
        dn = self._dirty_nodes
        df = self._dirty_fids
        # Freed shares on both NICs; the lifted parent-cap on children lands
        # in the main loop below (children must see parents marked done).
        # The per-node fid lists keep their (now stale) entries — the
        # recompute closure filter drops them, and lists compact lazily.
        dn.update(sk_l)
        dn.update(dk_l)
        nlive = self._nlive
        for i, fid in enumerate(batch):
            st = flows[fid]
            st.done = True
            st.t_done = now
            nlive[sk_l[i]] -= 1
            nlive[dk_l[i]] -= 1
            cs = st.children
            if cs:
                for c in cs:
                    if c.started and not c.done:
                        df.add(c.fid)
        if isreg.any():
            # registry egress keeps the per-flow dict walk in batch order —
            # its running sums are order-pinned against the incremental engine
            names = self._nname
            reg = self._reg_out
            rt_l = rt.tolist()
            for i in np.flatnonzero(isreg).tolist():
                reg[names[sk_l[i]]] -= rt_l[i]
        if self._record_trace:
            tr = self._trace_raw
            for fid in batch:
                tr.append((now, 0, fid))

    def _settle_active(self) -> None:
        """Vectorized final settle of every active flow at ``self.now``."""
        n = len(self._flows)
        if n == 0:
            return
        idx = np.flatnonzero(self._fstarted[:n] & ~self._fdone[:n])
        if idx.size == 0:
            return
        adv = self.now > self._tlast[idx]
        if not adv.any():
            return
        idx = idx[adv]
        pos = self._rate[idx] > 0.0
        if pos.any():
            j = idx[pos]
            self._rem[j] = np.maximum(
                0.0, self._rem[j] - self._rate[j] * (self.now - self._tlast[j])
            )
        self._tlast[idx] = self.now

    # ------------------------------------------------------------------
    def run(self, until: float = math.inf) -> float:
        """Advance until no events remain (or ``until``); returns final time."""
        flows = self._flows
        if len(self._ev_pending) > 4096:
            self._fold_events()  # bulk schedule() outside add_plan
        pend = self._ev_pending
        evh = self._ev_heap
        self._in_run = True
        try:
            while True:
                if pend:
                    for e in pend:
                        heapq.heappush(evh, e)
                    del pend[:]
                if self._dirty_nodes or self._dirty_fids:
                    self._recompute()
                t_done = self._next_completion()
                t_noti = self._next_notify()
                t_evt = evh[0][0] if evh else math.inf
                if self._sptr < len(self._spay):
                    ts = self._sts[self._sptr]
                    if ts < t_evt:
                        t_evt = ts
                t_next = t_done if t_done < t_noti else t_noti
                if t_evt < t_next:
                    t_next = t_evt
                if t_next == math.inf or t_next > until:
                    if until != math.inf and until > self.now:
                        self.now = until
                        self._settle_active()
                    return self.now
                self.now = t_next
                if t_noti <= t_done and t_noti <= t_evt:
                    # Runnable prefixes land before (or exactly at) the flow's
                    # own completion — fire every notify due at this instant
                    # in deterministic (time, fid) order, then loop.
                    nheap = self._notify_heap
                    fdone, fstarted, ep = self._fdone, self._fstarted, self._epoch
                    hasn = self._fhasnoti
                    while nheap:
                        t, fid, epoch = nheap[0]
                        if (
                            fdone[fid]
                            or not fstarted[fid]
                            or not hasn[fid]
                            or epoch != ep[fid]
                        ):
                            heapq.heappop(nheap)
                            continue
                        if t > self.now:
                            break
                        heapq.heappop(nheap)
                        hasn[fid] = False
                        self.events_processed += 1
                        st = flows[fid]
                        if st.on_notify is not None:
                            st.on_notify(self.now)
                elif t_done <= t_evt:
                    # Batch every completion due at this instant into one
                    # settle pass: mark them all done first, then fire
                    # callbacks in deterministic (time, fid) order, then
                    # re-rate the union of their dirty closures once.
                    batch: list[int] = []
                    heap = self._done_heap
                    fdone, fstarted, ep = self._fdone, self._fstarted, self._epoch
                    while heap:
                        t, fid, epoch = heap[0]
                        if fdone[fid] or not fstarted[fid] or epoch != ep[fid]:
                            heapq.heappop(heap)
                            continue
                        if t <= self.now:
                            heapq.heappop(heap)
                            batch.append(fid)
                        else:
                            break
                    self._complete_batch(batch)
                    # A completed flow's prefix landed by definition: fire
                    # any notify that has not gone out yet (runnable <= done
                    # always), before the done callbacks.
                    if self._any_noti:
                        hasn = self._fhasnoti
                        for fid in batch:
                            if hasn[fid]:
                                hasn[fid] = False
                                self.events_processed += 1
                                st = flows[fid]
                                if st.on_notify is not None:
                                    st.on_notify(self.now)
                    for fid in batch:
                        st = flows[fid]
                        if st.on_done is not None:
                            st.on_done(self.now)
                else:
                    # Drain every event due at this instant.  Flow starts are
                    # handled per-flow in pop order (lifecycle flags, waiter
                    # releases) but their array bookkeeping is flushed in one
                    # batch; callables force a flush first so they observe
                    # fully-applied state.
                    now = self.now
                    lim = now + 1e-12
                    sts, sseq, spay = self._sts, self._sseq, self._spay
                    sptr = self._sptr
                    slen = len(spay)
                    started: list[int] = []
                    sapp = started.append
                    papp = pend.append
                    nev = 0
                    seq = self._seq
                    while True:
                        if pend:
                            for e in pend:
                                heapq.heappush(evh, e)
                            del pend[:]
                        th = evh[0] if evh else None
                        # Tie-break: everything in the heap was scheduled
                        # after the last fold, so its seq is larger than any
                        # snapshot seq — on equal times the snapshot pops
                        # first, exactly as one global (t, seq) heap would.
                        if sptr < slen and (th is None or sts[sptr] <= th[0]):
                            if sts[sptr] > lim:
                                break
                            fn = spay[sptr]
                            sptr += 1
                        elif th is not None:
                            if th[0] > lim:
                                break
                            fn = heapq.heappop(evh)[2]
                        else:
                            break
                        nev += 1
                        if type(fn) is int:
                            st = flows[fn]
                            if st.started or st.done:
                                continue
                            p = st.parent
                            if p is not None and not p.started:
                                # Gated on the parent's start (no polling);
                                # mirror of _arm_start with the seq local.
                                st.parent.waiters.append(st)
                                continue
                            st.started = True
                            st.t_start = now
                            sapp(fn)
                            # Release children waiting for this flow to start
                            # (schedule() inlined against the seq local).
                            if st.waiters:
                                for w in st.waiters:
                                    if not w.started and not w.done:
                                        t = max(
                                            w.start_after,
                                            now + w.pipeline_delay,
                                            now,
                                        )
                                        seq += 1
                                        papp((t, seq, w.fid))
                                st.waiters.clear()
                        else:
                            if started:
                                self._flush_starts(started)
                                started = []
                                sapp = started.append
                            self._seq = seq
                            fn()
                            seq = self._seq
                    self._seq = seq
                    self.events_processed += nev
                    self._sptr = sptr
                    if started:
                        self._flush_starts(started)
        finally:
            self._in_run = False

    # ------------------------------------------------------------------
    def completion_times(self) -> dict[str, float]:
        """dst vm_id -> time its payload finished arriving."""
        out: dict[str, float] = {}
        for f in self._flows:
            if f.done:
                out[f.flow.dst] = max(out.get(f.flow.dst, 0.0), f.t_done)
        return out


class VectorJaxFlowSim(VectorFlowSim):
    """Vector engine with the fused jax/pallas cap-chain kernel on wide fronts.

    Fronts wider than ``cfg.vector_scalar_cutoff`` route the per-flow
    min-cap chain through :func:`repro.kernels.cap_chain.cap_chain_rates`
    — a fused elementwise-minima kernel run in float64 (so its IEEE-754
    results are bit-identical to the numpy path; see the kernel module for
    the dtype argument).  Narrow fronts keep the scalar fast path, and when
    jax is unavailable the engine degrades gracefully to the plain numpy
    wide fronts; ``jax_active`` records which happened.  Either way the
    event log is bit-identical to :class:`VectorFlowSim`, which stays the
    policing oracle for this tier exactly as the incremental engine polices
    the vector one.
    """

    def __init__(self, cfg: SimConfig | None = None, *, record_rates: bool = False):
        super().__init__(cfg, record_rates=record_rates)
        from repro.kernels.cap_chain import have_jax

        self.jax_active = have_jax()
        self.dispatch_stats["fronts_jax"] = 0
        self.dispatch_stats["flows_jax"] = 0

    def _front_rates(
        self, fids: np.ndarray, src: np.ndarray, dst: np.ndarray
    ) -> np.ndarray:
        if not self.jax_active:
            return super()._front_rates(fids, src, dst)
        from repro.kernels.cap_chain import cap_chain_rates

        cfg = self.cfg
        # Tiny numpy gathers feed the kernel; the fused min chain itself —
        # the ~10 elementwise dispatches the numpy path pays — runs in
        # pallas.  The parent cap is gathered as +inf where absent or
        # already done, matching the numpy path's masked minimum.
        par = self._fpar[fids]
        pr = np.full(fids.size, np.inf, dtype=_F64)
        pm = par >= 0
        if pm.any():
            pi = np.flatnonzero(pm)
            live = ~self._fdone[par[pi]]
            if not live.all():
                pi = pi[live]
            if pi.size:
                pr[pi] = self._rate[par[pi]]
        stats = self.dispatch_stats
        stats["fronts_jax"] += 1
        stats["flows_jax"] += int(fids.size)
        return cap_chain_rates(
            self._nout_cnt[src],
            self._nin_cnt[dst],
            self._nout_cap[src],
            self._nqps[src],
            pr,
            self._fblk[fids],
            per_stream_cap=cfg.per_stream_cap,
            in_cap=cfg.vm_nic.in_cap,
            decompress_rate=cfg.decompress_rate,
            block_size=cfg.block_size,
        )
