"""Discrete-event simulation of FaaSNet provisioning and the paper's baselines."""
from repro.core.reclaim import (
    RECLAIM_POLICIES,
    FixedTTLReclaim,
    HistogramReclaim,
    ReclaimPolicy,
)
from repro.core.registry import RegistrySpec, ShardResolver

from .cluster import (
    BLOCK_SYSTEMS,
    SYSTEMS,
    WaveConfig,
    block_wave,
    provision_wave,
    scalability_table,
    startup_timeline,
)
from .engine import ENGINES, GBPS, FlowSim, NICConfig, SimConfig, make_sim
from .multi_tenant import (
    PLACEMENTS,
    MultiTenantConfig,
    MultiTenantReplay,
    MultiTenantResult,
    ServingConfig,
    TenantConfig,
    TenantResult,
    run_multi_tenant,
)
from .reference import ReferenceFlowSim
from .scale import (
    ScaleConfig,
    ScaleResult,
    giga_burst_config,
    mega_burst_config,
    multi_tenant_config,
    run_scale,
    serving_config,
)
from .vector_engine import VectorFlowSim
from .traces import (
    constant_trace,
    diurnal_trace,
    iot_trace,
    synthetic_gaming_trace,
)
from .workload import ReplayConfig, TickStats, TraceReplay

__all__ = [
    "RECLAIM_POLICIES",
    "ReclaimPolicy",
    "FixedTTLReclaim",
    "HistogramReclaim",
    "RegistrySpec",
    "ShardResolver",
    "SYSTEMS",
    "BLOCK_SYSTEMS",
    "PLACEMENTS",
    "WaveConfig",
    "block_wave",
    "provision_wave",
    "scalability_table",
    "startup_timeline",
    "GBPS",
    "ENGINES",
    "FlowSim",
    "VectorFlowSim",
    "NICConfig",
    "SimConfig",
    "make_sim",
    "MultiTenantConfig",
    "MultiTenantReplay",
    "MultiTenantResult",
    "ServingConfig",
    "TenantConfig",
    "TenantResult",
    "run_multi_tenant",
    "ReferenceFlowSim",
    "ScaleConfig",
    "ScaleResult",
    "giga_burst_config",
    "mega_burst_config",
    "multi_tenant_config",
    "run_scale",
    "serving_config",
    "constant_trace",
    "diurnal_trace",
    "iot_trace",
    "synthetic_gaming_trace",
    "ReplayConfig",
    "TickStats",
    "TraceReplay",
]
