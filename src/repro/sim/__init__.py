"""Discrete-event simulation of FaaSNet provisioning and the paper's baselines."""
from repro.core.reclaim import (
    RECLAIM_POLICIES,
    FixedTTLReclaim,
    HistogramReclaim,
    ReclaimPolicy,
)
from repro.core.registry import RegistrySpec, ShardResolver

from .cluster import SYSTEMS, WaveConfig, provision_wave, scalability_table, startup_timeline
from .engine import GBPS, FlowSim, NICConfig, SimConfig
from .multi_tenant import (
    PLACEMENTS,
    MultiTenantConfig,
    MultiTenantReplay,
    MultiTenantResult,
    ServingConfig,
    TenantConfig,
    TenantResult,
    run_multi_tenant,
)
from .reference import ReferenceFlowSim
from .scale import (
    ScaleConfig,
    ScaleResult,
    mega_burst_config,
    multi_tenant_config,
    run_scale,
    serving_config,
)
from .traces import (
    constant_trace,
    diurnal_trace,
    iot_trace,
    synthetic_gaming_trace,
)
from .workload import ReplayConfig, TickStats, TraceReplay

__all__ = [
    "RECLAIM_POLICIES",
    "ReclaimPolicy",
    "FixedTTLReclaim",
    "HistogramReclaim",
    "RegistrySpec",
    "ShardResolver",
    "SYSTEMS",
    "PLACEMENTS",
    "WaveConfig",
    "provision_wave",
    "scalability_table",
    "startup_timeline",
    "GBPS",
    "FlowSim",
    "NICConfig",
    "SimConfig",
    "MultiTenantConfig",
    "MultiTenantReplay",
    "MultiTenantResult",
    "ServingConfig",
    "TenantConfig",
    "TenantResult",
    "run_multi_tenant",
    "ReferenceFlowSim",
    "ScaleConfig",
    "ScaleResult",
    "mega_burst_config",
    "multi_tenant_config",
    "run_scale",
    "serving_config",
    "constant_trace",
    "diurnal_trace",
    "iot_trace",
    "synthetic_gaming_trace",
    "ReplayConfig",
    "TickStats",
    "TraceReplay",
]
