"""Provisioning-wave simulation for FaaSNet and the paper's baselines.

``provision_wave`` reproduces the microbenchmark methodology of paper §4.3:
N concurrent invocations, each creating one container on its own VM, timed
from request to container-created.  Per-system behaviour and the calibrated
constants (paper §4.1: 2-CPU / 4 GB / 1 Gbps VMs, 758 MB PyStan image,
512 KB blocks) live here; EXPERIMENTS.md records the calibration.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import FunctionTree, RPCCosts
from repro.core.registry import RegistrySpec, ShardResolver
from repro.core.topology import (
    baseline_block_plan,
    baseline_plan,
    dadi_plan,
    faasnet_block_plan,
    faasnet_plan,
    kraken_plan,
    on_demand_block_plan,
    on_demand_plan,
)

from .engine import GBPS, SimConfig, make_sim

MB = 1e6


@dataclass
class WaveConfig:
    """Workload + calibration knobs for one provisioning wave."""

    image_bytes: int = int(758 * MB)  # paper's default PyStan image
    # Fraction of the image a container must hold before it can start.
    # Paper Fig. 20: 512 KB blocks give an 83.9 % network-I/O reduction on
    # the 728 MB Alibaba base image => ~15-16 % fetched.
    startup_fraction: float = 0.15
    # App-level per-stream throughput (paper Fig. 16: ~30 MB/s outbound
    # split across 2 children; ~15 MB/s inbound per stream while seeding,
    # ~30 MB/s when only fetching).
    per_stream_cap: float = 30 * MB
    # Store-and-forward + decompress cost per tree hop (drives the 1.5 s
    # first-to-last spread of paper Fig. 15).
    hop_latency: float = 0.2
    container_start: float = 2.5  # runc + runtime init once blocks are local
    image_extract_rate: float = 100 * MB  # docker-pull layer extraction
    # Per-VM memory budget (paper §4.1: 2-CPU / 4 GB VMs) — the admission
    # denominator for shared-pool placement (repro.sim.multi_tenant).
    vm_mem_mb: int = 4096
    n_layers: int = 10  # layer count for layer-granular systems (Kraken)
    registry_out_cap: float = 9.5 * GBPS
    # Registry request throttling for block-granular (on-demand) fetchers.
    registry_qps: float = 1100.0
    # Sharded registry: ``None`` means one shard with the two caps above
    # (bit-identical to the pre-sharding simulator); an explicit spec wins
    # outright — its per-shard egress/qps replace the legacy knobs.
    registry: RegistrySpec | None = None
    rpc: RPCCosts = field(default_factory=RPCCosts)
    kraken_coord_s: float = 0.070  # origin CPU per (node, layer) announce
    dadi_coord_s: float = 0.160  # DADI root CPU per joining node
    seed: int = 0
    # Engine backend for the wave's FlowSim ("incremental" | "vector" |
    # "reference") and whether it keeps the per-event text log; threaded
    # into SimConfig by every wave/replay entry point.
    engine: str = "incremental"
    record_trace: bool = True
    # Vector engines: closures whose ready set is at most this many flows
    # take the scalar per-flow path instead of the batched numpy/jax one
    # (small-front fixed-cost crossover; see SimConfig.vector_scalar_cutoff).
    vector_scalar_cutoff: int = 64
    # Block-level provisioning (paper §3.1–§3.2): when set, provision_wave
    # fetches this image's missing blocks per layer instead of the scalar
    # ``image_bytes * startup_fraction`` payload, and a container is ready
    # at its *runnable prefix* (boot working set), not full arrival.
    # ``None`` (the default) keeps the scalar model bit-identically.
    image: "object | None" = None  # repro.core.image.ImageSpec

    def registry_spec(self) -> RegistrySpec:
        return RegistrySpec.resolve(
            self.registry, egress_cap=self.registry_out_cap, qps=self.registry_qps
        )


SYSTEMS = ("faasnet", "baseline", "on_demand", "kraken", "dadi_p2p")


def provision_wave(
    system: str,
    n: int,
    cfg: WaveConfig | None = None,
    *,
    warm_roots: int = 0,
    slow_vms: dict[str, float] | None = None,
    straggler_mitigation: bool = False,
) -> dict[str, float]:
    """Provision ``n`` containers concurrently; return vm_id -> latency (s).

    ``warm_roots`` > 0 models the paper's 1→N (rather than 0→N) burst: that
    many VMs already hold the image and only seed.  ``slow_vms`` injects
    stragglers (vm_id -> egress cap in bytes/s); with
    ``straggler_mitigation`` the FT manager demotes a detected slow interior
    node to a leaf (delete + re-insert) before the wave is planned —
    FaaSNet's adaptivity applied to stragglers.
    """
    cfg = cfg or WaveConfig()
    if cfg.image is not None and system in ("faasnet", "baseline", "on_demand"):
        # Block-level provisioning: a container is ready once its boot
        # working set (runnable prefix) landed, not at full arrival.
        if warm_roots or slow_vms or straggler_mitigation:
            raise ValueError(
                "block-level waves (cfg.image) do not support warm_roots/"
                "slow_vms/straggler_mitigation"
            )
        res = block_wave(system, n, cfg, images=cfg.image)
        return {vm: v["runnable"] for vm, v in res.items()}
    nodes = [f"vm{i}" for i in range(n)]
    coord_cost = {"kraken": cfg.kraken_coord_s, "dadi_p2p": cfg.dadi_coord_s}.get(
        system, 0.0
    )
    spec = cfg.registry_spec()
    resolver = ShardResolver(spec)  # one resolver per wave: stateful policies
    sim = make_sim(
        SimConfig(
            registry=spec,
            per_stream_cap=cfg.per_stream_cap,
            hop_latency=cfg.hop_latency,
            coordinator_cost_s=coord_cost,
            engine=cfg.engine,
            record_trace=cfg.record_trace,
            vector_scalar_cutoff=cfg.vector_scalar_cutoff,
        )
    )
    for vm, cap in (slow_vms or {}).items():
        sim.set_slow_vm(vm, cap)

    control = cfg.rpc.control_plane_total()
    lat: dict[str, float] = {}
    done_at: dict[str, float] = {}

    def on_done(vm: str, t: float) -> None:
        done_at[vm] = t

    if system == "faasnet":
        ft = FunctionTree("f")
        for i in range(warm_roots):
            ft.insert(f"warm{i}")
        for vmid in nodes:
            ft.insert(vmid)
        if straggler_mitigation and slow_vms:
            for vmid in slow_vms:
                if vmid in ft and ft.children_of(vmid):
                    ft.delete(vmid)
                    ft.insert(vmid)  # re-attach at the frontier => leaf
        plan = faasnet_plan(
            ft,
            image_bytes=cfg.image_bytes,
            startup_fraction=cfg.startup_fraction,
            manifest_latency=cfg.rpc.manifest_fetch,
            registry=resolver,
        )
        # warm roots already have the payload: zero-byte flows
        plan = _mark_warm(plan, {f"warm{i}" for i in range(warm_roots)})
        extra = cfg.container_start + cfg.rpc.image_load
    elif system == "baseline":
        plan = baseline_plan(nodes, image_bytes=cfg.image_bytes, registry=resolver)
        extra = cfg.container_start + cfg.image_bytes / cfg.image_extract_rate
    elif system == "on_demand":
        plan = on_demand_plan(
            nodes,
            image_bytes=cfg.image_bytes,
            startup_fraction=cfg.startup_fraction,
            manifest_latency=cfg.rpc.manifest_fetch,
            registry=resolver,
        )
        extra = cfg.container_start + cfg.rpc.image_load
    elif system == "kraken":
        layer = cfg.image_bytes // cfg.n_layers
        plan = kraken_plan(
            nodes,
            layer_bytes=[layer] * cfg.n_layers,
            origin="origin",
            seed=cfg.seed,
        )
        extra = cfg.container_start + cfg.image_bytes / cfg.image_extract_rate
    elif system == "dadi_p2p":
        plan = dadi_plan(
            nodes,
            image_bytes=cfg.image_bytes,
            root="vm0",
            startup_fraction=cfg.startup_fraction,
            registry=resolver,
        )
        extra = cfg.container_start + cfg.rpc.image_load
    else:
        raise ValueError(f"unknown system {system!r}; one of {SYSTEMS}")

    sim.add_plan(plan, t0=control, on_node_done=on_done)
    sim.run()
    for vm in nodes:
        if vm not in done_at:  # pragma: no cover - indicates a sim bug
            raise RuntimeError(f"{system}: {vm} never finished its fetch")
        lat[vm] = done_at[vm] + extra
    return lat


def _mark_warm(plan, warm: set[str]):
    """Zero out inbound flows of warm nodes (they already hold the image)."""
    from repro.core.topology import DistributionPlan, Flow

    flows = [
        Flow(f.src, f.dst, f.piece, 0 if f.dst in warm else f.bytes)
        for f in plan.flows
    ]
    return DistributionPlan(
        flows=flows,
        control_latency=plan.control_latency,
        coordinator=plan.coordinator,
        streaming=plan.streaming,
    )


BLOCK_SYSTEMS = ("faasnet", "baseline", "on_demand")


def block_wave(
    system: str,
    n: int,
    cfg: WaveConfig | None = None,
    *,
    images=None,
    cache=None,
) -> dict[str, dict[str, float]]:
    """Block-granular provisioning wave: per-VM runnable + full-arrival times.

    ``images`` is one :class:`~repro.core.image.ImageSpec` for all ``n`` VMs
    or a per-VM list; ``cache`` is the cross-wave
    :class:`~repro.core.image.BlockCache` (fresh by default) — pass the same
    cache across consecutive waves to model warm block reuse, and distinct
    images sharing base layers to model cross-function dedup.  Returns
    ``vm_id -> {"runnable": t, "done": t}``: *runnable* is the paper's §3.2
    boot-working-set milestone plus container start, *done* is full image
    materialization plus the same tail.  Each VM's fetched image is recorded
    in ``cache`` after the wave.
    """
    from repro.core.image import BlockCache, ImageSpec

    cfg = cfg or WaveConfig()
    if images is None:
        images = cfg.image
    if images is None:
        raise ValueError("block_wave needs an ImageSpec (images= or cfg.image)")
    if isinstance(images, ImageSpec):
        images = [images] * n
    if len(images) != n:
        raise ValueError(f"need one image per VM: {len(images)} images, {n} VMs")
    cache = cache if cache is not None else BlockCache()
    nodes = [f"vm{i}" for i in range(n)]
    img_of = dict(zip(nodes, images))
    spec = cfg.registry_spec()
    resolver = ShardResolver(spec)
    sim = make_sim(
        SimConfig(
            registry=spec,
            per_stream_cap=cfg.per_stream_cap,
            hop_latency=cfg.hop_latency,
            engine=cfg.engine,
            record_trace=cfg.record_trace,
            vector_scalar_cutoff=cfg.vector_scalar_cutoff,
        )
    )
    control = cfg.rpc.control_plane_total()
    runnable_at: dict[str, float] = {}
    done_at: dict[str, float] = {}

    def on_runnable(vm: str, t: float) -> None:
        runnable_at.setdefault(vm, t)

    def on_done(vm: str, t: float) -> None:
        done_at[vm] = max(done_at.get(vm, 0.0), t)  # last layer = full image

    # One plan per distinct image: FT fan-out stays within an image's VMs.
    groups: dict[str, list[str]] = {}
    for vm in nodes:
        groups.setdefault(img_of[vm].name, []).append(vm)
    for vms in groups.values():
        img = img_of[vms[0]]
        if system == "faasnet":
            ft = FunctionTree(img.name)
            for vm in vms:
                ft.insert(vm)
            plan = faasnet_block_plan(
                ft,
                image=img,
                cache=cache,
                manifest_latency=cfg.rpc.manifest_fetch,
                registry=resolver,
            )
        elif system == "on_demand":
            plan = on_demand_block_plan(
                vms,
                image=img,
                cache=cache,
                manifest_latency=cfg.rpc.manifest_fetch,
                registry=resolver,
            )
        elif system == "baseline":
            plan = baseline_block_plan(
                vms, image=img, cache=cache, registry=resolver
            )
        else:
            raise ValueError(
                f"unknown block system {system!r}; one of {BLOCK_SYSTEMS}"
            )
        sim.add_plan(
            plan, t0=control, on_node_done=on_done, on_node_runnable=on_runnable
        )
    sim.run()
    out: dict[str, dict[str, float]] = {}
    for vm in nodes:
        img = img_of[vm]
        if vm not in runnable_at or vm not in done_at:  # pragma: no cover
            raise RuntimeError(f"{system}: {vm} never finished its block fetch")
        if system == "baseline":
            extra = cfg.container_start + img.total_bytes() / cfg.image_extract_rate
        else:
            extra = cfg.container_start + cfg.rpc.image_load
        out[vm] = {
            "runnable": runnable_at[vm] + extra,
            "done": done_at[vm] + extra,
        }
        cache.add_image(vm, img)
    return out


def scalability_table(
    systems: tuple[str, ...] = SYSTEMS,
    ns: tuple[int, ...] = (8, 16, 32, 64, 128),
    cfg: WaveConfig | None = None,
) -> dict[str, dict[int, dict[str, float]]]:
    """Paper Figure 14(a): mean/min/max provisioning latency vs concurrency."""
    out: dict[str, dict[int, dict[str, float]]] = {}
    for system in systems:
        out[system] = {}
        for n in ns:
            lat = list(provision_wave(system, n, cfg).values())
            lat.sort()
            out[system][n] = {
                "mean": sum(lat) / len(lat),
                "min": lat[0],
                "max": lat[-1],
                "p50": lat[len(lat) // 2],
            }
    return out


def startup_timeline(system: str, n: int, cfg: WaveConfig | None = None) -> list[float]:
    """Paper Figure 15: sorted wall-clock start times of the N functions."""
    return sorted(provision_wave(system, n, cfg).values())
