"""Workload traces (paper §2.2 / §4.2), scaled as the paper scales them.

The paper scales the production traces to ~1/3 of original peak throughput
and shortens 2 h to <1 h.  We generate the two evaluation traces from their
published descriptions:

  * **IoT** (§4.2): burst 1 at t=9 min, 10 → 300-400 RPS, lasting ~18 min,
    back to 10 RPS at t=28 min; burst 2 at t=40 min to 100 RPS, then within
    ~2 min jumping to ~400 RPS.  55-minute timeline.
  * **Synthetic gaming** (§4.2): two sharp bursts — 1 → 100 RPS at t=11 min
    (tree grows to height 7, 82 VMs), decay to 1 RPS afterwards with VM
    reclaim shrinking the pool to ~30 before burst 2 at t=21 min
    (+62 VMs → 102 VMs, height 7).

Each trace is a list of per-second request rates (RPS).  A deterministic
LCG jitters arrivals so runs are reproducible.  Two synthetic tenants —
``constant_trace`` and ``diurnal_trace`` — round out the multi-tenant mix
(`repro.sim.multi_tenant`): a steady background and a staggered day/night
cycle that overlap the IoT/gaming bursts.

All four generators and the arrival jitter are pinned by golden checksums
in ``tests/test_traces.py`` — their shapes cannot silently drift.
"""
from __future__ import annotations

import math


def _ramp(values: list[float], start: float, end: float, t0: int, t1: int) -> None:
    for t in range(min(t0, len(values)), min(t1, len(values))):
        frac = (t - t0) / max(1, (t1 - t0))
        values[t] = start + (end - start) * frac


def iot_trace(*, duration_s: int = 55 * 60, scale: float = 1.0) -> list[float]:
    rps = [10.0] * duration_s
    m = 60
    _ramp(rps, 10, 350, 9 * m, 10 * m)  # burst 1 rises fast
    for t in range(10 * m, 28 * m):
        rps[t] = 300.0 + 100.0 * 0.5 * (1 + math.sin(t / 47.0))  # 300-400 plateau
    _ramp(rps, 350, 10, 28 * m, 29 * m)
    _ramp(rps, 10, 100, 40 * m, 41 * m)  # burst 2: step to 100 ...
    _ramp(rps, 100, 400, 41 * m, 43 * m)  # ... then jump to ~400 in 2 min
    for t in range(43 * m, duration_s):
        rps[t] = 400.0
    return [r * scale for r in rps]


def synthetic_gaming_trace(*, duration_s: int = 30 * 60, scale: float = 1.0) -> list[float]:
    rps = [1.0] * duration_s
    m = 60
    for t in range(11 * m, min(13 * m, duration_s)):
        rps[t] = 100.0  # sharp burst 1
    _ramp(rps, 100, 1, 13 * m, 14 * m)
    for t in range(21 * m, min(24 * m, duration_s)):
        rps[t] = 125.0  # burst 2, slightly larger (tree 30 → 102 VMs)
    _ramp(rps, 125, 1, 24 * m, 25 * m)
    return [r * scale for r in rps]


def constant_trace(*, duration_s: int = 10 * 60, rps: float = 20.0, scale: float = 1.0) -> list[float]:
    """Steady background tenant: a flat RPS floor for the whole timeline.

    The multi-tenant replay uses it as the always-on tenant the bursty IoT
    and gaming tenants contend with for registry egress and the VM pool.
    """
    return [rps * scale] * duration_s


def diurnal_trace(
    *,
    duration_s: int = 30 * 60,
    base_rps: float = 4.0,
    peak_rps: float = 64.0,
    period_s: int = 20 * 60,
    phase_s: int = 0,
    scale: float = 1.0,
) -> list[float]:
    """Day/night tenant compressed to minutes: half-sinusoid days, flat nights.

    ``rps(t) = base + (peak - base) * max(0, sin(2pi (t + phase) / period))``
    — the positive sine half-cycle is the "day" ramp, the clipped negative
    half is the quiet "night" at ``base_rps``.  ``phase_s`` staggers tenants
    so their peaks overlap partially, the contention pattern the paper's
    trace-driven evaluation (§4.2) exercises.
    """
    out = []
    for t in range(duration_s):
        x = math.sin(2 * math.pi * ((t + phase_s) / period_s))
        out.append((base_rps + (peak_rps - base_rps) * max(0.0, x)) * scale)
    return out


def arrivals_for_second(rps: float, t: int, seed: int = 0) -> int:
    """Deterministic integer arrivals ~ rps (LCG-jittered rounding)."""
    x = (1103515245 * (t * 2654435761 + seed) + 12345) & 0x7FFFFFFF
    frac = (x / 0x7FFFFFFF)
    base = int(rps)
    return base + (1 if frac < (rps - base) else 0)


def arrival_offsets(n: int, t: int, seed: int = 0) -> list[float]:
    """``n`` sorted sub-second arrival offsets in [0, 1) for second ``t``.

    Same LCG family as :func:`arrivals_for_second`, decorrelated per request
    index.  The request-serving layer (``repro.sim.multi_tenant`` with a
    :class:`~repro.sim.multi_tenant.ServingConfig`) stamps each arrival at
    ``t + offset`` so dispatch — and hence the response-latency
    distribution — is not quantized to the 1 s tick boundary.  Sorted
    ascending, so appending a tick's offsets keeps the per-function FIFO
    queue globally ordered by arrival time.  Deterministic: pinned by a
    golden checksum in ``tests/test_traces.py``.
    """
    out = []
    for i in range(n):
        x = (1103515245 * (t * 2654435761 + seed + 40503 * (i + 1)) + 12345) & 0x7FFFFFFF
        out.append(x / 0x80000000)
    out.sort()
    return out
