"""AdamW + schedules + global-norm clipping (pure pytree functions)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> PyTree:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    cfg: AdamWConfig, grads: PyTree, state: PyTree
) -> tuple[PyTree, PyTree, dict]:
    """Returns (new bf16-castable params, new state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return m, v, p_new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(state["master"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_master = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "master": new_master, "step": step}
    return new_master, new_state, {"grad_norm": gnorm, "lr": lr}
