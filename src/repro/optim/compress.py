"""Quantized-communication helpers (gradient/weight compression).

The device-side analogue of FaaSNet's block compression (§3.5): trade cheap
elementwise compute for scarce interconnect bandwidth.  Row-wise symmetric
int8 with an f32 scale per row — 2× wire reduction on bf16 payloads at
~1e-2 relative error, which is ample for weight broadcast and for
error-feedback-compensated gradient all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (..., n) -> (int8 (..., n), f32 scale (...,))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def compress_error_feedback(grad, residual):
    """Error-feedback int8 compression for gradient all-reduce.

    Returns (quantized payload, new residual).  The caller all-reduces the
    dequantized payload; the quantization error is fed back into the next
    step, preserving convergence (Karimireddy et al., 2019).
    """
    target = grad + residual
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale).astype(grad.dtype)
    return deq, target - deq
