"""Mamba2 (SSD — state-space duality) block, pure JAX.

Implements the chunked SSD algorithm (Dao & Gu, 2024) for train/prefill and
the O(1) recurrent step for decode.  The chunked form is the TPU-friendly
one: within-chunk work is dense matmuls (MXU), cross-chunk state passing is
a short ``lax.scan`` — the same structure the Pallas ``ssd_scan`` kernel
tiles for VMEM (see repro/kernels/ssd_scan.py; this module is its oracle
consumer).

Shapes: x (B,T,H,P) heads×headdim, dt (B,T,H), A (H,) [negative],
B/C (B,T,G,N) with G groups broadcast over H heads, state (B,H,P,N).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import _normal, apply_norm, init_norm

PyTree = Any


def init_mamba(key, cfg) -> PyTree:
    s = cfg.ssm
    d = cfg.d_model
    h, p, g, n = s.n_heads, s.head_dim, s.n_groups, s.d_state
    keys = jax.random.split(key, 8)
    params = {
        "w_x": _normal(keys[0], (d, h * p), d**-0.5),
        "w_z": _normal(keys[1], (d, h * p), d**-0.5),
        "w_B": _normal(keys[2], (d, g * n), d**-0.5),
        "w_C": _normal(keys[3], (d, g * n), d**-0.5),
        "w_dt": _normal(keys[4], (d, h), d**-0.5),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "conv_x": _normal(keys[5], (s.conv_width, h * p), 0.2),
        "conv_B": _normal(keys[6], (s.conv_width, g * n), 0.2),
        "conv_C": _normal(keys[7], (s.conv_width, g * n), 0.2),
        "out_norm": init_norm("rmsnorm", h * p),
        "w_out": _normal(keys[4], (h * p, d), (h * p) ** -0.5),
    }
    return params


def causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,T,Ch), kernel (W,Ch)."""
    w, ch = kernel.shape
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(w):  # W is 4: unrolled taps beat a conv op for HLO size
        out = out + pad[:, i : i + x.shape[1], :] * kernel[i].astype(x.dtype)
    return out


def conv_step(x_new: jax.Array, conv_state: jax.Array, kernel: jax.Array):
    """One decode step. x_new (B,Ch); conv_state (B,W-1,Ch) holds history."""
    w = kernel.shape[0]
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,W,Ch)
    y = jnp.einsum("bwc,wc->bc", window.astype(x_new.dtype), kernel.astype(x_new.dtype))
    return y, window[:, 1:, :]


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., Q) -> (..., Q, Q) lower-triangular pairwise sums s[i,j]=sum(a[j+1..i])."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B,T,H,P)
    dt: jax.Array,  # (B,T,H) — post-softplus
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B,T,G,N)
    Cm: jax.Array,  # (B,T,G,N)
    *,
    chunk: int,
    init_state: jax.Array | None = None,  # (B,H,P,N)
    intra_dtype: str = "f32",
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,T,H,P), final_state (B,H,P,N)).

    ``intra_dtype="bf16"`` keeps the O(T·Q) decay matrices and partial
    products in bf16 (halving the dominant HBM traffic of the train step —
    §Perf hillclimb C); cumulative log-decays and the inter-chunk state
    stay f32 for stability.
    """
    b, t, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q = chunk
    # reshape to chunks: (B,nc,Q,...)
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, q, g, n)
    Cc = Cm.reshape(b, nc, q, g, n)
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    a = dtc * A  # (B,nc,Q,H) log-decay per step
    a_cum = jnp.cumsum(a, axis=2)  # within-chunk cumulative
    cdt = jnp.bfloat16 if intra_dtype == "bf16" else jnp.float32

    # 1) intra-chunk (diagonal blocks): Y = (L ∘ (C Bᵀ)) (dt·x)
    L = jnp.exp(_segsum(a.transpose(0, 1, 3, 2))).astype(cdt)  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", Ch, Bh).astype(cdt)
    dtx = (xc.astype(jnp.float32) * dtc[..., None]).astype(cdt)  # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", scores * L, dtx).astype(jnp.float32)

    # 2-4) fused inter-chunk pass.  One scan over chunks computes, per chunk:
    #   y_off_c = C_c · exp(a_cum) · S_in      (inter-chunk contribution)
    #   S_out   = S_c + exp(Σa) · S_in          (state recurrence)
    # with S_c built INSIDE the body — materializing the stacked (B,nc,H,P,N)
    # f32 chunk states (3.2 GB/layer at this shape) as scan xs/ys was the
    # dominant HBM traffic of the whole train step (§Perf hillclimb C).
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum).astype(cdt)  # (B,nc,Q,H)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B,nc,H)
    decay_from_start = jnp.exp(a_cum).astype(cdt)  # (B,nc,Q,H)
    Bhc = Bh.astype(cdt)
    Chc = Ch.astype(cdt)

    def scan_fn(s_prev, inp):
        bh_c, d2e_c, dtx_c, ch_c, dfs_c, dec_c = inp
        y_off_c = jnp.einsum(
            "bqhn,bqh,bhpn->bqhp", ch_c, dfs_c, s_prev.astype(cdt)
        )
        s_c = jnp.einsum("bqhn,bqh,bqhp->bhpn", bh_c, d2e_c, dtx_c).astype(
            jnp.float32
        )
        s_new = s_c + dec_c[..., None, None] * s_prev
        return s_new, y_off_c

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    swap = lambda arr: jnp.swapaxes(arr, 0, 1)  # (B,nc,...) -> (nc,B,...)
    final, y_off = jax.lax.scan(
        scan_fn,
        s0,
        (swap(Bhc), swap(decay_to_end), swap(dtx), swap(Chc),
         swap(decay_from_start), swap(chunk_decay)),
    )
    y_off = swap(y_off)  # (B,nc,Q,H,P) in cdt

    y = (y_diag.astype(jnp.float32) + y_off.astype(jnp.float32)).reshape(
        b, nc * q, h, p
    )[:, :t]
    return y.astype(x.dtype), final


def ssd_step(
    x: jax.Array,  # (B,H,P)
    dt: jax.Array,  # (B,H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B,G,N)
    Cm: jax.Array,  # (B,G,N)
    state: jax.Array,  # (B,H,P,N) f32
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrence. Returns (y (B,H,P), new_state)."""
    h = x.shape[1]
    g = Bm.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32 * A)  # (B,H)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt32, Bh, x.astype(jnp.float32))
    new_state = decay[..., None, None] * state + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# ----------------------------------------------------------------------
# Full block (in_proj → conv → SSD → gate → out_proj)
# ----------------------------------------------------------------------
def apply_mamba(
    p: PyTree,
    x: jax.Array,  # (B,T,d)
    cfg,
    *,
    cache: PyTree | None = None,  # decode: conv+ssm state
    chunk: int = 256,
) -> tuple[jax.Array, PyTree | None]:
    s = s_cfg = cfg.ssm
    h, pd, g, n = s.n_heads, s.head_dim, s.n_groups, s.d_state
    dt_ = x.dtype
    b, t, _ = x.shape
    xs = x @ p["w_x"].astype(dt_)  # (B,T,H*P)
    z = x @ p["w_z"].astype(dt_)
    Bp = x @ p["w_B"].astype(dt_)  # (B,T,G*N)
    Cp = x @ p["w_C"].astype(dt_)
    dt_raw = x @ p["w_dt"].astype(dt_)  # (B,T,H)
    A = -jnp.exp(p["A_log"])  # (H,)

    if cache is None:
        xs = jax.nn.silu(causal_conv(xs, p["conv_x"]))
        Bp = jax.nn.silu(causal_conv(Bp, p["conv_B"]))
        Cp = jax.nn.silu(causal_conv(Cp, p["conv_C"]))
        dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        y, final = ssd_chunked(
            xs.reshape(b, t, h, pd),
            dt_v,
            A,
            Bp.reshape(b, t, g, n),
            Cp.reshape(b, t, g, n),
            chunk=chunk,
            intra_dtype=s_cfg.intra_dtype,
        )
        new_cache = None
    else:
        assert t == 1, "decode path expects a single new token"
        xs1, conv_x = conv_step(xs[:, 0], cache["conv_x"], p["conv_x"])
        Bp1, conv_B = conv_step(Bp[:, 0], cache["conv_B"], p["conv_B"])
        Cp1, conv_C = conv_step(Cp[:, 0], cache["conv_C"], p["conv_C"])
        xs1, Bp1, Cp1 = jax.nn.silu(xs1), jax.nn.silu(Bp1), jax.nn.silu(Cp1)
        dt_v = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
        y1, ssm = ssd_step(
            xs1.reshape(b, h, pd),
            dt_v,
            A,
            Bp1.reshape(b, g, n),
            Cp1.reshape(b, g, n),
            cache["ssm"],
        )
        y = y1[:, None]  # (B,1,H,P)
        xs = xs1[:, None]
        new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "ssm": ssm}

    yd = y.reshape(b, t, h * pd) + xs.reshape(b, t, h * pd) * p["D"].astype(
        dt_
    ).repeat(pd)
    yd = yd * jax.nn.silu(z)
    yd = apply_norm("rmsnorm", p["out_norm"], yd)
    return yd @ p["w_out"].astype(dt_), new_cache


def init_mamba_cache(cfg, batch: int, dtype) -> PyTree:
    s = cfg.ssm
    h, pd, g, n = s.n_heads, s.head_dim, s.n_groups, s.d_state
    w = s.conv_width
    return {
        "conv_x": jnp.zeros((batch, w - 1, h * pd), dtype),
        "conv_B": jnp.zeros((batch, w - 1, g * n), dtype),
        "conv_C": jnp.zeros((batch, w - 1, g * n), dtype),
        "ssm": jnp.zeros((batch, h, pd, n), jnp.float32),
    }
