"""Mixture-of-Experts with capacity-based top-k routing (+ shared experts).

Dispatch/combine use index scatter/gather (NOT the Mesh-TF one-hot einsum,
whose (T,E,C) tensor is O(T²·k) and explodes at production token counts):

  * top-k routing picks (expert, gate) per token-choice;
  * position-within-expert comes from a cumsum over the flattened choice
    list; choices past the expert capacity map to an out-of-range row and
    are dropped by the scatter (their residual path passes through);
  * tokens are scatter-added into an (E·C, d) expert buffer — sharded
    E→model (EP) and C→data — so dispatch is the EP all-to-all;
  * expert FFN is a batched einsum over (E, C, d);
  * combine gathers each choice's output row and weights it by the gate.

DeepSeek-MoE's *shared experts* (always-on) run densely alongside.  The
router adds the Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain

from .layers import _normal, apply_mlp, init_mlp

PyTree = Any


def init_moe(key, cfg) -> PyTree:
    m = cfg.moe
    k_router, k_up, k_gate, k_down, k_shared = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    p: PyTree = {
        "router": _normal(k_router, (d, e), d**-0.5),
        "w_gate": _normal(k_gate, (e, d, f), d**-0.5),
        "w_up": _normal(k_up, (e, d, f), d**-0.5),
        "w_down": _normal(k_down, (e, f, d), f**-0.5),
    }
    if m.n_shared > 0:
        p["shared"] = init_mlp(k_shared, d, m.d_expert * m.n_shared, cfg.act)
    return p


def route_topk(
    logits: jax.Array,  # (T, E) f32
    k: int,
    capacity: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (slot (T,k) int32 into E*C [out-of-range = dropped],
    gate (T,k) f32, eids (T,k) int32, aux_loss scalar)."""
    t, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)  # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, in token order
    onehot = jax.nn.one_hot(eids, e, dtype=jnp.int32)  # (T,k,E)
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = (pos_in_expert * onehot).sum(-1)  # (T,k)
    keep = pos < capacity
    big = jnp.asarray(e * capacity, jnp.int32)  # out-of-range => dropped
    slot = jnp.where(keep, eids * capacity + pos, big)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(eids[:, 0], e, dtype=jnp.float32).mean(axis=0)
    aux = e * jnp.sum(me * ce)
    return slot.astype(jnp.int32), gate_vals, eids, aux


def _dispatch_combine_plan(xf, router, m, t):
    """Routing + scatter for the tokens in ``xf`` (runs per data shard under
    shard_map; plain single-device path otherwise)."""
    n_tok, d = xf.shape
    logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
    if t == 1:  # decode: capacity covers every token — no drops at inference
        capacity = n_tok
    else:
        capacity = int(n_tok * m.top_k / m.n_experts * m.capacity_factor)
        capacity = max(capacity, m.top_k)
    slot, gate, _, aux = route_topk(logits, m.top_k, capacity)
    e = m.n_experts
    upd = jnp.broadcast_to(xf[:, None, :], (n_tok, m.top_k, d)).reshape(-1, d)
    buf = jnp.zeros((e * capacity, d), xf.dtype)
    buf = buf.at[slot.reshape(-1)].add(upd, mode="drop")
    return buf.reshape(e, capacity, d), slot, gate, aux, capacity


def apply_moe(p: PyTree, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x (B,T,d) -> (y (B,T,d), aux_loss scalar).

    Under a mesh, routing+scatter run PER DATA SHARD inside shard_map (each
    shard owns a local capacity slice) — letting the SPMD partitioner
    handle the global scatter replicates the (E·C, d) buffer on every
    device (observed 98 GiB/device on jamba prefill).  The expert FFN
    stays pjit-level with experts sharded over the model axis (EP).
    """
    from repro.distributed.api import active_mesh
    from repro.distributed.sharding import data_axes

    m = cfg.moe
    b, t, d = x.shape
    n_tok = b * t
    xf = x.reshape(n_tok, d)
    dt = x.dtype
    e = m.n_experts
    mesh = active_mesh()
    dp_axes = data_axes(mesh) if mesh is not None else ()
    import numpy as np

    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if mesh is not None else 1
    shardable = dp > 1 and n_tok % dp == 0

    if shardable:
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        def local_dispatch(xl, router):
            bufl, slotl, gatel, auxl, _ = _dispatch_combine_plan(
                xl, router, m, t
            )
            auxg = jax.lax.pmean(auxl, dp_axes)
            return bufl, slotl, gatel, auxg[None]

        # slots stay LOCAL: each data shard owns its capacity slice of
        # every expert, so the combine gather below is shard-local too.
        buf, slot, gate, aux = shard_map(
            local_dispatch,
            mesh=mesh,
            in_specs=(P(dp_axes, None), P(None, None)),
            out_specs=(P(None, dp_axes, None), P(dp_axes, None),
                       P(dp_axes, None), P(dp_axes)),
            check_vma=False,
        )(xf, p["router"])
        aux = aux.mean()
    else:
        buf, slot, gate, aux, _ = _dispatch_combine_plan(
            xf, p["router"], m, t
        )

    xe = constrain(buf, ("model", "data", None))  # EP: experts↔model
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    h = (jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))  # (E,C,d)

    def _combine(ye_l, slot_l, gate_l):
        e_, cap_l, d_ = ye_l.shape
        yef = ye_l.reshape(-1, d_)
        got = jnp.take(yef, jnp.minimum(slot_l, e_ * cap_l - 1), axis=0)
        keep = (slot_l < e_ * cap_l).astype(jnp.float32)
        w = (gate_l * keep).astype(got.dtype)
        return jnp.einsum("tkd,tk->td", got, w)

    if shardable:
        y = shard_map(
            _combine,
            mesh=mesh,
            in_specs=(P(None, dp_axes, None), P(dp_axes, None),
                      P(dp_axes, None)),
            out_specs=P(dp_axes, None),
            check_vma=False,
        )(ye, slot, gate)
    else:
        y = _combine(ye, slot, gate)
    y = y.astype(dt)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xf, cfg.act)
    return y.reshape(b, t, d), aux
