"""Pure-JAX model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM."""
from .lm import Model, model_for

__all__ = ["Model", "model_for"]
