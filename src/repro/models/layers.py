"""Shared NN building blocks (pure functional JAX; params are dict pytrees).

Conventions
-----------
* Every ``init_*`` returns a dict pytree of f32 arrays ("master" params).
* Every ``apply``-style function takes ``(params, x, ...)`` and computes in
  ``compute_dtype`` (bf16 by default), casting weights on the fly.
* Weight shapes keep the *named* structure the sharding rules key off:
  attention projections are (d_model, n_heads, head_dim) — head axis
  explicit so TP sharding specs can target it.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _normal(key, shape, scale):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(
        jnp.float32
    )


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------
def init_norm(kind: str, dim: int) -> PyTree:
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(kind: str, p: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
        return (x32 * p["scale"]).astype(dt)
    elif kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
        x32 = (x32 - mu) * jax.lax.rsqrt(var + eps)
        return (x32 * p["scale"] + p["bias"]).astype(dt)
    raise ValueError(kind)


# ----------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain GELU)
# ----------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, act: str, *, bias: bool = False) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model**-0.5
    scale_out = d_ff**-0.5
    p: PyTree = {"w_out": _normal(k3, (d_ff, d_model), scale_out)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = _normal(k1, (d_model, d_ff), scale_in)
        p["w_up"] = _normal(k2, (d_model, d_ff), scale_in)
    else:
        p["w_up"] = _normal(k2, (d_model, d_ff), scale_in)
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), jnp.float32)
        p["b_out"] = jnp.zeros((d_model,), jnp.float32)
    return p


def apply_mlp(p: PyTree, x: jax.Array, act: str) -> jax.Array:
    dt = x.dtype
    if act in ("swiglu", "geglu"):
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    else:
        h = x @ p["w_up"].astype(dt)
        if "b_up" in p:
            h = h + p["b_up"].astype(dt)
        h = jax.nn.gelu(h)
    y = h @ p["w_out"].astype(dt)
    if "b_out" in p:
        y = y + p["b_out"].astype(dt)
    return y


# ----------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, rope_pct: float = 1.0) -> np.ndarray:
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2, dtype=np.float64) / rot_dim))
    return inv.astype(np.float32)  # (rot_dim/2,)


def apply_rope(
    x: jax.Array,  # (..., T, head_dim)
    positions: jax.Array,  # (..., T) int32
    inv_freq: jax.Array,  # (rot/2,)
) -> jax.Array:
    rot = inv_freq.shape[0] * 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (..., T, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1) if rot < x.shape[-1] else y.astype(x.dtype)


# ----------------------------------------------------------------------
# Embedding / LM head
# ----------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int) -> PyTree:
    return {"table": _normal(key, (vocab, d_model), 0.02)}


def embed(p: PyTree, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def unembed(p: PyTree, x: jax.Array) -> jax.Array:
    """Logits against the (possibly tied) embedding table."""
    return x @ p["table"].astype(x.dtype).T


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False) -> PyTree:
    p = {"w": _normal(key, (d_in, d_out), d_in**-0.5)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def apply_linear(p: PyTree, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ----------------------------------------------------------------------
# Cross-entropy over (possibly vocab-sharded) logits
# ----------------------------------------------------------------------
def softmax_xent(
    logits: jax.Array,  # (..., V) f32/bf16
    labels: jax.Array,  # (...,) int32
    *,
    z_loss: float = 0.0,
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    return loss
