"""Whisper-style encoder-decoder backbone (audio frontend is a STUB).

Per the assignment, the conv frontend is stubbed: ``input_specs`` feeds
precomputed frame embeddings (B, encoder_ctx, d_frontend); a learned input
projection maps them to d_model.  The decoder is a causal transformer with
per-layer cross-attention over the encoder output.  Positional encodings
are sinusoidal for both stacks (whisper uses learned decoder positions
capped at 448 — sinusoidal keeps the 32k/500k structural decode shapes
well-defined; recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain

from . import attention as attn
from .layers import (
    apply_linear,
    apply_mlp,
    apply_norm,
    embed,
    init_embedding,
    init_linear,
    init_mlp,
    init_norm,
)

PyTree = Any


def sinusoid(positions: jax.Array, dim: int, dtype) -> jax.Array:
    """positions (...,) -> (..., dim) classic transformer sinusoids."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_enc_layer(key, cfg) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
        "norm2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, bias=cfg.mlp_bias),
    }


def _init_dec_layer(key, cfg) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model),
        "self_attn": attn.init_attention(k1, cfg),
        "norm_x": init_norm(cfg.norm, cfg.d_model),
        "cross_attn": attn.init_attention(k2, cfg),
        "norm2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act, bias=cfg.mlp_bias),
    }


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg) -> PyTree:
    e = cfg.encdec
    keys = jax.random.split(key, 6)
    enc = _stack([
        _init_enc_layer(jax.random.fold_in(keys[0], i), cfg)
        for i in range(e.encoder_layers)
    ])
    dec = _stack([
        _init_dec_layer(jax.random.fold_in(keys[1], i), cfg)
        for i in range(cfg.n_layers)
    ])
    return {
        "frontend_proj": init_linear(keys[2], e.d_frontend, cfg.d_model, bias=True),
        "embed": init_embedding(keys[3], cfg.vocab_size, cfg.d_model),
        "enc_layers": enc,
        "enc_norm": init_norm(cfg.norm, cfg.d_model),
        "dec_layers": dec,
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }


# ----------------------------------------------------------------------
def encode(params, cfg, frames: jax.Array) -> jax.Array:
    """frames (B, ctx, d_frontend) -> (B, ctx, d_model)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = apply_linear(params["frontend_proj"], frames.astype(dtype))
    x = x + sinusoid(jnp.arange(x.shape[1]), cfg.d_model, dtype)[None]
    x = constrain(x, ("data", None, None))
    scale = cfg.hd**-0.5
    rep = cfg.n_heads // cfg.n_kv_heads

    def body(x, p):
        h = apply_norm(cfg.norm, p["norm1"], x)
        q, k, v = attn.qkv_proj(p["attn"], h, cfg, None, None)
        o = attn.attend_full(q, attn.repeat_kv(k, rep), attn.repeat_kv(v, rep),
                             None, scale)
        x = x + attn.out_proj(p["attn"], o)
        h = apply_norm(cfg.norm, p["norm2"], x)
        x = x + apply_mlp(p["mlp"], h, cfg.act)
        return constrain(x, ("data", None, None)), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_layer(cfg, p, x, enc_kv, *, positions, self_cache, pos, mode):
    scale = cfg.hd**-0.5
    rep = cfg.n_heads // cfg.n_kv_heads
    # self-attention (causal)
    h = apply_norm(cfg.norm, p["norm1"], x)
    q, k, v = attn.qkv_proj(p["self_attn"], h, cfg, None, None)
    # sinusoidal positions are added at the embedding; no RoPE here
    if mode == "decode":
        s = self_cache["k"].shape[2]
        slot = pos % s
        valid = (jnp.arange(s) <= pos) | (pos >= s)
        valid &= jnp.arange(s) != slot
        o = attn.attend_decode_plus_new(
            q, attn.repeat_kv(self_cache["k"], rep),
            attn.repeat_kv(self_cache["v"], rep),
            attn.repeat_kv(k, rep), attn.repeat_kv(v, rep), valid, scale,
        )
        kc = jax.lax.dynamic_update_slice(self_cache["k"], k, (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(self_cache["v"], v, (0, 0, slot, 0))
        new_cache = {"k": kc, "v": vc}
    else:
        t = x.shape[1]
        qpos = positions[0]
        o = attn.attention(q, attn.repeat_kv(k, rep), attn.repeat_kv(v, rep),
                           impl=cfg.attn_impl, q_pos=qpos, k_pos=qpos,
                           window=None, scale=scale, chunk=cfg.attn_chunk)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    x = x + attn.out_proj(p["self_attn"], o)
    # cross-attention over encoder output (precomputed per-layer K/V)
    h = apply_norm(cfg.norm, p["norm_x"], x)
    qx = jnp.einsum("btd,dhk->bhtk", h, p["cross_attn"]["wq"].astype(h.dtype))
    kx, vx = enc_kv
    ox = attn.attend_full(qx, attn.repeat_kv(kx, rep), attn.repeat_kv(vx, rep),
                          None, scale)
    x = x + attn.out_proj(p["cross_attn"], ox)
    # mlp
    h = apply_norm(cfg.norm, p["norm2"], x)
    x = x + apply_mlp(p["mlp"], h, cfg.act)
    return constrain(x, ("data", None, None)), new_cache


def cross_kv(params, cfg, enc_out: jax.Array) -> PyTree:
    """Per-decoder-layer cross K/V, stacked (L, B, Hkv, ctx, hd)."""

    def body(_, p):
        dt = enc_out.dtype
        k = jnp.einsum("bsd,dhk->bhsk", enc_out, p["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bhsk", enc_out, p["cross_attn"]["wv"].astype(dt))
        return None, (k, v)

    _, kv = jax.lax.scan(body, None, params["dec_layers"])
    return kv


def forward(
    params, cfg, batch: dict, *, mode: str, cache: Optional[dict] = None
) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """batch: tokens (B,T) [+ frames (B,ctx,d_frontend)]; decode adds pos ().

    Returns (logits, cache, aux).  Cache = {"self": (L,B,Hkv,S,hd)×2 dict,
    "cross": (kx, vx), "enc_out": ...}.
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    b, t = tokens.shape
    if mode == "decode":
        pos = batch["pos"]
        positions = jnp.broadcast_to(pos, (b, 1))
        enc_kv_all = cache["cross"]
    else:
        pos = None
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        enc_out = encode(params, cfg, batch["frames"])
        enc_kv_all = cross_kv(params, cfg, enc_out)

    x = embed(params["embed"], tokens, dtype)
    x = x + sinusoid(positions, cfg.d_model, dtype)
    x = constrain(x, ("data", None, None))

    if mode == "decode":
        # carry the stacked self-cache; update in place (no ys temp copy)
        def body_d(carry, xs):
            x, cache_buf, i = carry
            p, enc_kv = xs
            sc = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
                cache_buf,
            )
            x, nc = _dec_layer(cfg, p, x, enc_kv, positions=positions,
                               self_cache=sc, pos=pos, mode=mode)
            cache_buf = jax.tree.map(
                lambda buf, n: jax.lax.dynamic_update_index_in_dim(buf, n, i, 0),
                cache_buf, nc,
            )
            return (x, cache_buf, i + 1), None

        (x, new_self, _), _ = jax.lax.scan(
            body_d, (x, cache["self"], jnp.zeros((), jnp.int32)),
            (params["dec_layers"], enc_kv_all),
        )
    else:
        def body(carry, xs):
            x = carry
            p, enc_kv = xs
            x, nc = _dec_layer(cfg, p, x, enc_kv, positions=positions,
                               self_cache=None, pos=pos, mode=mode)
            return x, nc

        if cfg.remat == "block" and mode == "train":
            body = jax.checkpoint(body)
        x, new_self = jax.lax.scan(body, x, (params["dec_layers"], enc_kv_all))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = x @ params["embed"]["table"].astype(dtype).T  # whisper ties embeddings
    logits = constrain(logits, ("data", None, "model"))
    aux = jnp.zeros((), jnp.float32)
    if mode == "train":
        return logits, None, aux
    new_cache = {"self": new_self, "cross": enc_kv_all}
    return logits, new_cache, aux


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    e = cfg.encdec
    L = cfg.n_layers
    hkv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "self": {
            "k": jnp.zeros((L, batch, hkv, max_len, hd), dtype),
            "v": jnp.zeros((L, batch, hkv, max_len, hd), dtype),
        },
        "cross": (
            jnp.zeros((L, batch, hkv, e.encoder_ctx, hd), dtype),
            jnp.zeros((L, batch, hkv, e.encoder_ctx, hd), dtype),
        ),
    }
