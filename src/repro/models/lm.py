"""Unified model facade: one API across decoder-only and enc-dec families.

``model_for(cfg)`` returns a :class:`Model` with
  * ``init(key)``                      → params pytree
  * ``loss(params, batch)``            → (scalar loss, metrics dict)
  * ``prefill(params, batch)``         → (logits, cache)
  * ``decode_step(params, batch, cache)`` → (logits, cache)
  * ``init_cache(batch, max_len)``     → zeroed cache (dry-run stand-in)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .layers import softmax_xent

PyTree = Any


@dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def _lm_loss(forward, cfg):
    def loss_fn(params, batch):
        logits, _, aux = forward(params, cfg, batch, mode="train")
        labels = batch["labels"]
        mask = labels >= 0
        safe = jnp.where(mask, labels, 0)
        per_tok = softmax_xent(logits, safe, z_loss=cfg.z_loss)
        denom = jnp.maximum(mask.sum(), 1)
        ce = jnp.where(mask, per_tok, 0.0).sum() / denom
        total = ce
        if cfg.moe is not None:
            total = total + cfg.moe.aux_loss_weight * aux
        return total, {"ce": ce, "aux": aux, "tokens": denom}

    return loss_fn


def model_for(cfg) -> Model:
    if cfg.family == "audio":
        from . import whisper as impl

        fwd = impl.forward

        def init(key):
            return impl.init_params(key, cfg)

        def prefill(params, batch, cache_len=None):
            logits, cache, _ = fwd(params, cfg, batch, mode="prefill")
            return logits, cache

        def decode_step(params, batch, cache):
            logits, cache, _ = fwd(params, cfg, batch, mode="decode", cache=cache)
            return logits, cache

        def init_cache(batch, max_len, dtype=jnp.bfloat16):
            return impl.init_cache(cfg, batch, max_len, dtype)

        return Model(cfg, init, _lm_loss(fwd, cfg), prefill, decode_step, init_cache)

    from . import transformer as impl

    fwd = impl.forward

    def init(key):
        return impl.init_params(key, cfg)

    def prefill(params, batch, cache_len=None):
        logits, cache, _ = fwd(params, cfg, batch, mode="prefill",
                               cache_len=cache_len)
        return logits, cache

    def decode_step(params, batch, cache):
        logits, cache, _ = fwd(params, cfg, batch, mode="decode", cache=cache)
        return logits, cache

    def init_cache(batch, max_len, dtype=jnp.bfloat16):
        return impl.init_cache(cfg, batch, max_len, dtype)

    return Model(cfg, init, _lm_loss(fwd, cfg), prefill, decode_step, init_cache)
