"""Decoder-only LM assembly covering dense / MoE / SSM / hybrid / VLM.

The layer list (from ``ModelConfig.layer_specs``) is compiled into *stages*:
an unrolled prefix of irregular layers plus a periodic suffix executed with
``jax.lax.scan`` over stacked parameters — HLO size is O(pattern period),
not O(depth), which keeps 512-device dry-run compiles tractable.

Three modes share one code path (``mode`` is static):
  * ``train``   — full-sequence forward, no cache;
  * ``prefill`` — full-sequence forward, emits per-layer caches;
  * ``decode``  — single new token against caches (attention KV ring/full
                  buffers, mamba conv+ssm state).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain

from . import attention as attn
from .layers import (
    apply_mlp,
    apply_norm,
    embed,
    init_embedding,
    init_linear,
    init_mlp,
    init_norm,
    rope_freqs,
    unembed,
    apply_linear,
)
from .mamba2 import apply_mamba, init_mamba, init_mamba_cache
from .moe import apply_moe, init_moe

PyTree = Any


# ----------------------------------------------------------------------
# Stage decomposition
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class Stage:
    pattern: tuple  # tuple[LayerSpec, ...]
    repeat: int
    first_layer: int  # absolute index of the stage's first layer


def build_stages(cfg) -> list[Stage]:
    specs = cfg.layer_specs()
    n = len(specs)
    best = None  # (suffix_len, -period, start)
    for p in range(1, min(12, n) + 1):
        # longest p-periodic suffix with whole number of repeats
        start = n - p
        while start - p >= 0 and specs[start - p : start] == specs[start : start + p]:
            start -= p
        suffix = n - start
        reps = suffix // p
        if reps >= 1:
            key = (suffix, -p)
            if best is None or key > best[0]:
                best = (key, p, start)
    _, period, start = best
    stages: list[Stage] = []
    for i in range(start):  # irregular prefix: one stage per layer
        stages.append(Stage(pattern=(specs[i],), repeat=1, first_layer=i))
    stages.append(
        Stage(pattern=tuple(specs[start : start + period]),
              repeat=(n - start) // period, first_layer=start)
    )
    return stages


# ----------------------------------------------------------------------
# Params
# ----------------------------------------------------------------------
def _init_sublayer(key, cfg, spec) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    p: PyTree = {"norm1": init_norm(cfg.norm, cfg.d_model)}
    if spec.mixer == "attn":
        p["attn"] = attn.init_attention(k1, cfg)
    else:
        p["mamba"] = init_mamba(k1, cfg)
    if spec.ffn != "none":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
        if spec.ffn == "moe":
            p["moe"] = init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, bias=cfg.mlp_bias)
    return p


def _stack(trees: list[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_stage_params(key, cfg, stage: Stage) -> PyTree:
    out = []
    for j, spec in enumerate(stage.pattern):
        reps = []
        for r in range(stage.repeat):
            sub = jax.random.fold_in(key, j * 1000 + r)
            reps.append(_init_sublayer(sub, cfg, spec))
        out.append(_stack(reps) if stage.repeat > 1 else reps[0])
    return tuple(out)


def init_params(key, cfg) -> PyTree:
    keys = jax.random.split(key, 8)
    stages = build_stages(cfg)
    params: PyTree = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
        "stages": [init_stage_params(jax.random.fold_in(keys[1], i), cfg, st)
                   for i, st in enumerate(stages)],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[2], cfg.d_model, cfg.vocab_size)
    if cfg.vlm is not None:
        params["mm_proj"] = init_linear(keys[3], cfg.vlm.d_vision, cfg.d_model,
                                        bias=True)
    return params


# ----------------------------------------------------------------------
# Caches
# ----------------------------------------------------------------------
def _attn_cache_shape(cfg, spec, batch: int, max_len: int):
    s = max_len if spec.is_global or cfg.sliding_window is None else min(
        cfg.sliding_window, max_len
    )
    return (batch, cfg.n_kv_heads, s, cfg.hd)


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., hd) bf16 -> (int8 (..., hd), f32 scale (...,))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> PyTree:
    """Zeroed caches, one entry per stage mirroring stage params layout."""
    stages = build_stages(cfg)
    int8 = cfg.kv_cache_dtype == "int8"
    caches = []
    for st in stages:
        entries = []
        for spec in st.pattern:
            if spec.mixer == "attn":
                shape = _attn_cache_shape(cfg, spec, batch, max_len)
                if int8:
                    e = {
                        "k": jnp.zeros(shape, jnp.int8),
                        "v": jnp.zeros(shape, jnp.int8),
                        "ks": jnp.full(shape[:-1], 1e-12, jnp.float32),
                        "vs": jnp.full(shape[:-1], 1e-12, jnp.float32),
                    }
                else:
                    e = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
            else:
                e = init_mamba_cache(cfg, batch, dtype)
            if st.repeat > 1:
                e = jax.tree.map(
                    lambda x: jnp.zeros((st.repeat,) + x.shape, x.dtype), e
                )
            entries.append(e)
        caches.append(tuple(entries))
    return caches


# ----------------------------------------------------------------------
# Sublayer application
# ----------------------------------------------------------------------
def _apply_attn(cfg, spec, p, x, *, positions, inv_freq, cache, pos, mode,
                cache_len=None):
    h = cfg.n_heads
    rep = h // cfg.n_kv_heads
    scale = cfg.hd**-0.5
    q, k, v = attn.qkv_proj(p, x, cfg, positions, inv_freq)
    window = None if spec.is_global else cfg.sliding_window
    if mode in ("train", "prefill"):
        t = x.shape[1]
        qpos = positions[0]  # (T,) — batch-uniform positions
        o = attn.attention(
            q, attn.repeat_kv(k, rep), attn.repeat_kv(v, rep),
            impl=cfg.attn_impl, q_pos=qpos, k_pos=qpos, window=window,
            scale=scale, chunk=cfg.attn_chunk,
        )
        new_cache = None
        if mode == "prefill":
            cap = cache_len if cache_len is not None else t
            s = _attn_cache_shape(cfg, spec, x.shape[0], cap)[2]
            kk, vv = k[:, :, -s:, :], v[:, :, -s:, :]
            if s > t:  # pad to capacity; future decode steps fill slots t..s
                pad = [(0, 0), (0, 0), (0, s - t), (0, 0)]
                kk, vv = jnp.pad(kk, pad), jnp.pad(vv, pad)
            elif s < t:  # ring layout: key of position p lives at slot p % s
                kk = jnp.roll(kk, t % s, axis=2)
                vv = jnp.roll(vv, t % s, axis=2)
            if cfg.kv_cache_dtype == "int8":
                kq, ks = _quantize_kv(kk)
                vq, vs = _quantize_kv(vv)
                new_cache = {"k": kq, "v": vq, "ks": ks, "vs": vs}
            else:
                new_cache = {"k": kk, "v": vv}
    else:  # decode: T == 1
        s = cache["k"].shape[2]
        slot = pos % s
        # attention reads the OLD cache + this step's k/v separately, so the
        # cache update below is a pure write that aliases its donated buffer
        # (no temp copy of the multi-GB cache).
        valid = (jnp.arange(s) <= pos) | (pos >= s)  # ring fully valid once warm
        valid &= jnp.arange(s) != slot  # current slot is stale in the old cache
        int8 = cfg.kv_cache_dtype == "int8"
        if int8:
            k_old = _dequantize_kv(cache["k"], cache["ks"], k.dtype)
            v_old = _dequantize_kv(cache["v"], cache["vs"], v.dtype)
        else:
            k_old, v_old = cache["k"], cache["v"]
        if cfg.gqa_decode == "grouped":
            o = attn.attend_decode_plus_new_gqa(
                q, k_old, v_old, k, v, valid, scale,
            )
        else:
            o = attn.attend_decode_plus_new(
                q, attn.repeat_kv(k_old, rep), attn.repeat_kv(v_old, rep),
                attn.repeat_kv(k, rep), attn.repeat_kv(v, rep), valid, scale,
            )
        if int8:
            kq, ks1 = _quantize_kv(k)
            vq, vs1 = _quantize_kv(v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, slot, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, slot, 0)),
                "ks": jax.lax.dynamic_update_slice(cache["ks"], ks1, (0, 0, slot)),
                "vs": jax.lax.dynamic_update_slice(cache["vs"], vs1, (0, 0, slot)),
            }
        else:
            kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
            new_cache = {"k": kc, "v": vc}
    return attn.out_proj(p, o), new_cache


def _apply_layer(cfg, spec, p, x, *, positions, inv_freq, cache, pos, mode,
                 cache_len=None):
    aux = jnp.zeros((), jnp.float32)
    h_in = apply_norm(cfg.norm, p["norm1"], x)
    if spec.mixer == "attn":
        h, new_cache = _apply_attn(
            cfg, spec, p["attn"], h_in,
            positions=positions, inv_freq=inv_freq, cache=cache, pos=pos,
            mode=mode, cache_len=cache_len,
        )
    else:
        h, new_cache = apply_mamba(
            p["mamba"], h_in, cfg,
            cache=cache if mode == "decode" else None, chunk=cfg.ssm.chunk,
        )
        if mode == "prefill":
            new_cache = _mamba_prefill_cache(p["mamba"], h_in, cfg)
    x = x + h
    x = constrain(x, ("data", None, None))
    if spec.ffn != "none":
        h2 = apply_norm(cfg.norm, p["norm2"], x)
        if spec.ffn == "moe":
            h2, a = apply_moe(p["moe"], h2, cfg)
            aux = aux + a
        else:
            h2 = apply_mlp(p["mlp"], h2, cfg.act)
        x = x + h2
        x = constrain(x, ("data", None, None))
    return x, new_cache, aux


def _mamba_prefill_cache(p, x_normed_in, cfg):
    """Build decode cache from a prefill pass (conv tail + final SSD state)."""
    from .mamba2 import ssd_chunked

    s = cfg.ssm
    h, pd, g, n = s.n_heads, s.head_dim, s.n_groups, s.d_state
    dt_ = x_normed_in.dtype
    b, t, _ = x_normed_in.shape
    # recompute the projections (cheap relative to carrying them through)
    from .mamba2 import causal_conv

    xs = jax.nn.silu(causal_conv(x_normed_in @ p["w_x"].astype(dt_), p["conv_x"]))
    Bp = jax.nn.silu(causal_conv(x_normed_in @ p["w_B"].astype(dt_), p["conv_B"]))
    Cp = jax.nn.silu(causal_conv(x_normed_in @ p["w_C"].astype(dt_), p["conv_C"]))
    dt_v = jax.nn.softplus(
        (x_normed_in @ p["w_dt"].astype(dt_)).astype(jnp.float32) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])
    _, final = ssd_chunked(
        xs.reshape(b, t, h, pd), dt_v, A,
        Bp.reshape(b, t, g, n), Cp.reshape(b, t, g, n), chunk=s.chunk,
    )
    w = s.conv_width
    tail = lambda arr: (x_normed_in @ arr.astype(dt_))[:, -(w - 1):, :]
    return {
        "conv_x": tail(p["w_x"]),
        "conv_B": tail(p["w_B"]),
        "conv_C": tail(p["w_C"]),
        "ssm": final,
    }


# ----------------------------------------------------------------------
# Stage execution (scan over the periodic suffix)
# ----------------------------------------------------------------------
def _run_stage(cfg, stage: Stage, stage_params, x, *, positions, inv_freq,
               stage_cache, pos, mode, cache_len=None):
    aux_total = jnp.zeros((), jnp.float32)

    def run_pattern(x, params_list, cache_list):
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for j, spec in enumerate(stage.pattern):
            c = cache_list[j] if cache_list is not None else None
            x, nc, a = _apply_layer(
                cfg, spec, params_list[j], x,
                positions=positions, inv_freq=inv_freq, cache=c, pos=pos,
                mode=mode, cache_len=cache_len,
            )
            new_caches.append(nc)
            aux = aux + a
        return x, tuple(new_caches), aux

    if stage.repeat == 1:
        fn = run_pattern
        if cfg.remat == "block" and mode == "train":
            fn = jax.checkpoint(run_pattern)
        x, new_caches, aux = fn(x, stage_params, stage_cache)
        return x, new_caches, aux_total + aux

    if mode == "decode":
        # Carry the stacked cache and update it in place per iteration —
        # emitting it as scan ys would materialize a full temp copy of the
        # (multi-GB) cache instead of aliasing the donated input buffer.
        def body_d(carry, params_list):
            x, aux, cache_buf, i = carry
            cache_list = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
                cache_buf,
            )
            x, new_caches, a = run_pattern(x, params_list, cache_list)
            cache_buf = jax.tree.map(
                lambda buf, nc: jax.lax.dynamic_update_index_in_dim(buf, nc, i, 0),
                cache_buf, new_caches,
            )
            return (x, aux + a, cache_buf, i + 1), None

        (x, aux_total, new_caches, _), _ = jax.lax.scan(
            body_d, (x, aux_total, stage_cache, jnp.zeros((), jnp.int32)),
            stage_params,
        )
        return x, new_caches, aux_total

    def body(carry, xs):
        x, aux = carry
        params_list, cache_list = xs
        x, new_caches, a = run_pattern(x, params_list, cache_list)
        return (x, aux + a), new_caches

    if cfg.remat == "block" and mode == "train":
        body = jax.checkpoint(body)
    xs = (stage_params, stage_cache)
    (x, aux_total), new_caches = jax.lax.scan(body, (x, aux_total), xs)
    return x, new_caches, aux_total


# ----------------------------------------------------------------------
# Public forward
# ----------------------------------------------------------------------
def embed_inputs(params, cfg, batch: dict, mode: str) -> jax.Array:
    dtype = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], batch["tokens"], dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dtype)
    if cfg.vlm is not None and "patch_embeds" in batch:
        vis = apply_linear(params["mm_proj"], batch["patch_embeds"].astype(dtype))
        x = jnp.concatenate([vis, x], axis=1)
    return x


def forward(
    params: PyTree,
    cfg,
    batch: dict,  # tokens (B,T) [+ patch_embeds]; decode: tokens (B,1), pos ()
    *,
    mode: str,  # train | prefill | decode
    cache: Optional[list] = None,
    cache_len: Optional[int] = None,  # prefill: pad caches to this capacity
) -> tuple[jax.Array, Optional[list], jax.Array]:
    """Returns (logits, new_cache, aux_loss). Logits (B,T,V)."""
    stages = build_stages(cfg)
    x = embed_inputs(params, cfg, batch, mode)
    x = constrain(x, ("data", None, None))
    b, t = x.shape[0], x.shape[1]
    if mode == "decode":
        pos = batch["pos"]  # () int32 — current absolute position
        positions = jnp.broadcast_to(pos, (b, 1))
    else:
        pos = None
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    inv_freq = (
        jnp.asarray(rope_freqs(cfg.hd, cfg.rope_theta, cfg.rope_pct))
        if cfg.attn_every
        else None
    )
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, st in enumerate(stages):
        st_cache = cache[i] if cache is not None else None
        x, nc, a = _run_stage(
            cfg, st, params["stages"][i], x,
            positions=positions, inv_freq=inv_freq,
            stage_cache=st_cache, pos=pos, mode=mode, cache_len=cache_len,
        )
        new_caches.append(nc)
        aux = aux + a
    x = apply_norm(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = apply_linear(params["lm_head"], x)
    logits = constrain(logits, ("data", None, "model"))
    return logits, (new_caches if mode in ("prefill", "decode") else None), aux
