"""Attention: GQA projections, causal/sliding-window masks, three impls.

Implementations (selectable via config.attn_impl):
  * ``full``    — materializes (T, S) scores; for smoke tests / short seqs.
  * ``chunked`` — lax.scan over KV chunks with online softmax (flash-style
                  in pure jnp).  Memory O(T · chunk); small HLO independent
                  of sequence length.  Used by the 512-device dry-run, where
                  Pallas cannot lower (CPU hosts).
  * ``pallas``  — TPU flash-attention kernel from ``repro.kernels`` (real
                  hardware path; validated in interpret mode by tests).

Decode (q_len == 1 against a long cache) uses a dedicated path that never
materializes more than (B, H, S) scores and supports sequence-sharded KV.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope

PyTree = Any
NEG_INF = -2.0e38


def init_attention(key, cfg) -> PyTree:
    import jax.random as jr

    from .layers import _normal

    k1, k2, k3, k4 = jr.split(key, 4)
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": _normal(k1, (d, h, hd), d**-0.5),
        "wk": _normal(k2, (d, hk, hd), d**-0.5),
        "wv": _normal(k3, (d, hk, hd), d**-0.5),
        "wo": _normal(k4, (h, hd, d), (h * hd) ** -0.5),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((hk, hd), jnp.float32)
        p["bv"] = jnp.zeros((hk, hd), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def _rms(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


def qkv_proj(p: PyTree, x: jax.Array, cfg, positions: jax.Array, inv_freq):
    """x (B,T,d) -> q (B,H,T,hd), k/v (B,Hkv,T,hd), RoPE applied."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bhtk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bhtk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)[None, :, None, :]
        k = k + p["bk"].astype(dt)[None, :, None, :]
        v = v + p["bv"].astype(dt)[None, :, None, :]
    if "q_norm" in p:
        q = _rms(q, p["q_norm"]["scale"])
        k = _rms(k, p["k_norm"]["scale"])
    if inv_freq is not None:
        pos = positions[:, None, :]  # (B,1,T) broadcasting over heads
        q = apply_rope(q, pos, inv_freq)
        k = apply_rope(k, pos, inv_freq)
    return q, k, v


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B,Hkv,S,hd) -> (B,Hkv*n_rep,S,hd)."""
    if n_rep == 1:
        return k
    b, hk, s, hd = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, hk, n_rep, s, hd)).reshape(
        b, hk * n_rep, s, hd
    )


def out_proj(p: PyTree, o: jax.Array) -> jax.Array:
    y = jnp.einsum("bhtk,hkd->btd", o, p["wo"].astype(o.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(o.dtype)
    return y


# ----------------------------------------------------------------------
# Masks
# ----------------------------------------------------------------------
def causal_window_mask(
    q_pos: jax.Array,  # (T,) query positions
    k_pos: jax.Array,  # (S,) key positions
    window: Optional[int],  # None => full causal
) -> jax.Array:
    """(T, S) bool; True = attend."""
    m = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    return m


# ----------------------------------------------------------------------
# full
# ----------------------------------------------------------------------
def attend_full(
    q: jax.Array,  # (B,H,T,hd)
    k: jax.Array,  # (B,H,S,hd)
    v: jax.Array,
    mask: Optional[jax.Array],  # (T,S) or (B,1,T,S) bool
    scale: float,
) -> jax.Array:
    logits = jnp.einsum("bhtk,bhsk->bhts", q, k).astype(jnp.float32) * scale
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]
        logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhts,bhsk->bhtk", w, v)


# ----------------------------------------------------------------------
# chunked (flash-style: Q tiles outer, KV tiles inner, pure jnp)
# ----------------------------------------------------------------------
def attend_chunked(
    q: jax.Array,  # (B,H,T,hd)
    k: jax.Array,  # (B,H,S,hd)
    v: jax.Array,
    q_pos: jax.Array,  # (T,)
    k_pos: jax.Array,  # (S,)
    window: Optional[int],
    scale: float,
    chunk: int = 1024,
) -> jax.Array:
    """Double-tiled online softmax: the accumulator carried through the KV
    scan is one Q-tile (B,H,bq,hd), NOT the full sequence — carrying full-T
    state through the inner scan would multiply HBM traffic by #KV-tiles
    (measured 200× on train_4k before this restructure)."""
    b, h, t, hd = q.shape
    s = k.shape[2]
    bq = min(chunk, t)
    bk = min(chunk, s)
    nq, nk = -(-t // bq), -(-s // bk)
    pad_q, pad_k = nq * bq - t, nk * bk - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=2**30 - 1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=2**30)  # never attended
    qc = q.reshape(b, h, nq, bq, hd).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, h, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    qpc = q_pos.reshape(nq, bq)
    kpc = k_pos.reshape(nk, bk)

    # Sliding-window tile skipping: a query tile at index i only sees KV
    # tiles [i − ⌈window/bk⌉, i] (positions are contiguous), so local
    # layers touch O(window) keys instead of O(S) — for gemma3's 512-token
    # windows over 32k sequences that is a 16× compute cut on 25/26 layers.
    w_tiles = None
    if window is not None and t == s and nk > 1:
        w_tiles = min(-(-window // bk) + 1, nk)  # window span + diagonal

    def kv_step(qt, qp, carry, kin):
        acc, m, l = carry
        kt, vt, kp = kin
        logits = jnp.einsum("bhtk,bhsk->bhts", qt, kt).astype(jnp.float32) * scale
        msk = causal_window_mask(qp, kp, window)
        logits = jnp.where(msk[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhts,bhsk->bhtk", p.astype(qt.dtype), vt
        ).astype(jnp.float32)
        return (acc_new, m_new, l_new)

    def q_tile(qi, qin):
        qt, qp = qin  # (B,H,bq,hd), (bq,)
        acc0 = jnp.zeros((b, h, bq, hd), jnp.float32)
        m0 = jnp.full((b, h, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        if w_tiles is not None:
            start = jnp.clip(qi - (w_tiles - 1), 0, nk - w_tiles)
            kw = jax.lax.dynamic_slice_in_dim(kc, start, w_tiles, 0)
            vw = jax.lax.dynamic_slice_in_dim(vc, start, w_tiles, 0)
            kpw = jax.lax.dynamic_slice_in_dim(kpc, start, w_tiles, 0)
            (acc, m, l), _ = jax.lax.scan(
                lambda c, kin: (kv_step(qt, qp, c, kin), None),
                (acc0, m0, l0), (kw, vw, kpw),
            )
        else:
            (acc, m, l), _ = jax.lax.scan(
                lambda c, kin: (kv_step(qt, qp, c, kin), None),
                (acc0, m0, l0), (kc, vc, kpc),
            )
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qt.dtype)
        return qi + 1, out

    _, outc = jax.lax.scan(q_tile, jnp.zeros((), jnp.int32), (qc, qpc))
    out = outc.transpose(1, 2, 0, 3, 4).reshape(b, h, nq * bq, hd)
    return out[:, :, :t]


# ----------------------------------------------------------------------
# decode: q_len == 1 against a (possibly seq-sharded) cache
# ----------------------------------------------------------------------
def attend_decode(
    q: jax.Array,  # (B,H,1,hd)
    k: jax.Array,  # (B,H,S,hd)
    v: jax.Array,
    k_valid: jax.Array,  # (S,) bool — True where cache slot holds a real key
    scale: float,
) -> jax.Array:
    logits = jnp.einsum("bhtk,bhsk->bhts", q, k).astype(jnp.float32) * scale
    logits = jnp.where(k_valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhts,bhsk->bhtk", w, v)


def attend_decode_plus_new(
    q: jax.Array,  # (B,H,1,hd)
    k_cache: jax.Array,  # (B,H,S,hd) — the OLD cache (never the updated copy,
    v_cache: jax.Array,  # so the cache write can alias its donated buffer)
    k_new: jax.Array,  # (B,H,1,hd) — this step's key/value
    v_new: jax.Array,
    k_valid: jax.Array,  # (S,) bool — valid OLD slots (excludes current pos)
    scale: float,
) -> jax.Array:
    l_old = jnp.einsum("bhtk,bhsk->bhts", q, k_cache).astype(jnp.float32) * scale
    l_old = jnp.where(k_valid[None, None, None, :], l_old, NEG_INF)
    l_new = jnp.einsum("bhtk,bhsk->bhts", q, k_new).astype(jnp.float32) * scale
    m = jnp.maximum(l_old.max(axis=-1, keepdims=True), l_new)
    p_old = jnp.exp(l_old - m)
    p_new = jnp.exp(l_new - m)
    denom = p_old.sum(axis=-1, keepdims=True) + p_new
    o = jnp.einsum("bhts,bhsk->bhtk", p_old.astype(q.dtype), v_cache)
    o = o + p_new.astype(q.dtype) * v_new
    return o / denom.astype(q.dtype)


def attend_decode_plus_new_gqa(
    q: jax.Array,  # (B,H,1,hd) with H = Hkv * G
    k_cache: jax.Array,  # (B,Hkv,S,hd) — NOT repeated: the repeat of a
    v_cache: jax.Array,  # sequence-sharded cache to H heads forces an SPMD
    k_new: jax.Array,  # (B,Hkv,1,hd)    reshard (observed: involuntary full
    v_new: jax.Array,  # rematerialization + all-gather of the whole cache)
    k_valid: jax.Array,  # (S,) bool
    scale: float,
) -> jax.Array:
    """GQA decode keeping the Hkv axis: group dim lives on Q only, so the
    cache stays in its native (seq-sharded) layout and the only collectives
    are the softmax-stat and output partial-sum reductions (O(B·H) bytes,
    not O(cache))."""
    b, h, _, hd = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, hd)
    l_old = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    l_old = jnp.where(k_valid[None, None, None, :], l_old, NEG_INF)
    l_new = jnp.einsum("bkgd,bksd->bkgs", qg, k_new).astype(jnp.float32) * scale
    m = jnp.maximum(l_old.max(axis=-1, keepdims=True), l_new)
    p_old = jnp.exp(l_old - m)
    p_new = jnp.exp(l_new - m)
    denom = p_old.sum(axis=-1, keepdims=True) + p_new
    o = jnp.einsum("bkgs,bksd->bkgd", p_old.astype(q.dtype), v_cache)
    o = o + p_new.astype(q.dtype) * v_new[:, :, None, 0, :]
    o = o / denom.astype(q.dtype)
    return o.reshape(b, h, 1, hd)


def attention(
    q, k, v, *, impl: str, q_pos, k_pos, window, scale, chunk: int = 1024
):
    """Dispatch on implementation for prefill/train (q_len == kv_len)."""
    if impl == "chunked":
        return attend_chunked(q, k, v, q_pos, k_pos, window, scale, chunk=chunk)
    if impl == "pallas":
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                    window=window, scale=scale)
    mask = causal_window_mask(q_pos, k_pos, window)
    return attend_full(q, k, v, mask, scale)
