"""Version-compatibility shims for the jax API surface this repo uses.

The code targets the modern jax API (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``); older jax releases (< 0.5) ship the
same functionality as ``jax.experimental.shard_map.shard_map`` with
``check_rep`` and a ``make_mesh`` without ``axis_types``.  Importing
``shard_map`` / ``make_mesh`` from here works on both, so the test suite and
dryruns run on whichever jax the container bakes in.
"""
from __future__ import annotations

from typing import Any

import jax

try:  # jax >= 0.5: top-level export, replication check kwarg is check_vma
    from jax import shard_map as _shard_map

    _CHECK_KWARG = "check_vma"
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the old/new replication-check kwarg papered over."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KWARG: check_vma},
    )


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...], **kwargs: Any):
    """``jax.make_mesh`` requesting Auto axis types where supported."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
            **kwargs,
        )
    except (AttributeError, TypeError):  # pragma: no cover - older jax
        return jax.make_mesh(shape, axes, **kwargs)


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-less mesh: new jax takes (shape, names); old takes name/size pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, axes)
    except TypeError:  # pragma: no cover - older jax
        return AbstractMesh(tuple(zip(axes, shape)))
