"""Synthetic batch generation shared by smoke tests, examples and dry-runs.

``make_batch`` builds a real (materialized) batch for a config+shape on the
host; ``batch_specs`` builds the matching ShapeDtypeStructs for AOT
lowering (no allocation) — the two must stay in lock-step, which the tests
assert via jax.eval_shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _text_len(cfg, seq_len: int) -> int:
    if cfg.vlm is not None:
        return seq_len - cfg.vlm.n_patches
    return seq_len


def make_batch(cfg, seq_len: int, batch: int, *, kind: str, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    out: dict = {}
    if kind in ("train", "prefill"):
        t_text = _text_len(cfg, seq_len)
        # Additive-walk sequences: x[t+1] = (x[t] + 1) mod V with a random
        # per-row start.  Marginally uniform over the vocab, but next-token
        # prediction has real signal, so "loss goes down" tests measure
        # learning rather than luck (iid labels bound the loss at ln V).
        start = rng.integers(0, cfg.vocab_size, (batch, 1))
        seq = (start + np.arange(seq_len + 1)[None, :]) % cfg.vocab_size
        out["tokens"] = jnp.asarray(seq[:, :t_text], jnp.int32)
        if cfg.vlm is not None:
            out["patch_embeds"] = jnp.asarray(
                rng.standard_normal((batch, cfg.vlm.n_patches, cfg.vlm.d_vision)),
                jnp.bfloat16,
            )
        if cfg.encdec is not None:
            e = cfg.encdec
            out["frames"] = jnp.asarray(
                rng.standard_normal((batch, e.encoder_ctx, e.d_frontend)),
                jnp.bfloat16,
            )
        if kind == "train":
            out["labels"] = jnp.asarray(seq[:, 1:], jnp.int32)
    else:  # decode
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, 1)), jnp.int32
        )
        out["pos"] = jnp.asarray(seq_len - 1, jnp.int32)
    return out


def batch_specs(cfg, seq_len: int, batch: int, *, kind: str) -> dict:
    f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32
    S = jax.ShapeDtypeStruct
    out: dict = {}
    if kind in ("train", "prefill"):
        t_text = _text_len(cfg, seq_len)
        out["tokens"] = S((batch, t_text), i32)
        if cfg.vlm is not None:
            out["patch_embeds"] = S((batch, cfg.vlm.n_patches, cfg.vlm.d_vision), bf16)
        if cfg.encdec is not None:
            e = cfg.encdec
            out["frames"] = S((batch, e.encoder_ctx, e.d_frontend), bf16)
        if kind == "train":
            out["labels"] = S((batch, seq_len), i32)
    else:
        out["tokens"] = S((batch, 1), i32)
        out["pos"] = S((), i32)
    return out


def token_stream(cfg, seq_len: int, batch: int, *, seed: int = 0):
    """Infinite deterministic token batches for the training examples."""
    step = 0
    while True:
        yield make_batch(cfg, seq_len, batch, kind="train", seed=seed + step)
        step += 1
