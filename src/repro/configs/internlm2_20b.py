"""internlm2-20b [dense]: 48L d=6144 48H (GQA kv=8) ff=16384 vocab=92544.

[arXiv:2403.17297; hf] — RMSNorm, SwiGLU, GQA.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2_20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="internlm2_20b_smoke",
    family="dense",
    n_layers=3,
    d_model=192,
    n_heads=6,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    attn_impl="full",
)
