"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) ff_expert=1408 vocab=102400.

[arXiv:2401.06066; hf] — fine-grained MoE: 64 routed experts top-6 + 2
shared experts (d_expert 1408); first layer uses a dense MLP (d_ff 10944).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek_moe_16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,  # dense first layer
    vocab_size=102400,
    moe=MoEConfig(
        n_experts=64, top_k=6, d_expert=1408, n_shared=2, moe_first_dense=1
    ),
)

SMOKE = ModelConfig(
    name="deepseek_moe_16b_smoke",
    family="moe",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=320,
    vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=2, moe_first_dense=1),
    attn_impl="full",
)
