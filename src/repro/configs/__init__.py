"""Architecture configs: one module per assigned arch + shape definitions."""
from .base import (
    ARCH_IDS,
    LONG_CTX_ARCHS,
    SHAPES,
    EncDecConfig,
    LayerSpec,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    VLMConfig,
    cells,
    get,
    get_config,
    get_smoke,
)

__all__ = [
    "ARCH_IDS",
    "LONG_CTX_ARCHS",
    "SHAPES",
    "EncDecConfig",
    "LayerSpec",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "VLMConfig",
    "cells",
    "get",
    "get_config",
    "get_smoke",
]
