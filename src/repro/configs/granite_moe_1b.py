"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (kv=8) ff_expert=512 vocab=49155.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — 32 experts top-8, every
layer MoE, RMSNorm, SwiGLU, tied embeddings.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite_moe_1b",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
)

SMOKE = ModelConfig(
    name="granite_moe_1b_smoke",
    family="moe",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=8, top_k=4, d_expert=64),
    attn_impl="full",
)
