"""deepseek-7b [dense]: 30L d=4096 32H (kv=32 i.e. MHA) ff=11008 vocab=102400.

llama-arch [arXiv:2401.02954; hf] — RMSNorm, SwiGLU, full rotary.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek_7b_smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=344,
    vocab_size=512,
    attn_impl="full",
)
