"""stablelm-12b [dense]: 40L d=5120 32H (GQA kv=8) ff=13824 vocab=100352.

[hf:stabilityai/stablelm-2-12b family; hf] — LayerNorm + partial rotary
(25 %), untied embeddings, qk-norm per StableLM-2 12B.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm_12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    norm="layernorm",
    act="swiglu",
    qk_norm=True,
    rope_pct=0.25,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="stablelm_12b_smoke",
    family="dense",
    n_layers=4,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=344,
    vocab_size=512,
    norm="layernorm",
    act="swiglu",
    qk_norm=True,
    rope_pct=0.25,
    attn_impl="full",
)
