"""Model/arch configuration schema + registry.

Every assigned architecture provides ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (exact published shape) and ``SMOKE`` (reduced same-family config
for CPU tests).  ``repro.configs.get(name)`` returns them.

The layer pattern is described declaratively so one assembly routine
(repro.models.transformer) covers dense / MoE / SSM / hybrid / local-global
families: layer ``i`` gets
  * mixer  = attn  if attn_every and i % attn_every == attn_offset else mamba
  * global = True  if global_every and (i+1) % global_every == 0 (else local
             sliding window when sliding_window is set)
  * ffn    = none  if d_ff == 0 and no moe;
             moe   if moe and i >= moe_first_dense and
                     (i - moe_offset) % moe_every == 0;
             mlp   otherwise
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1  # every k-th layer is MoE
    moe_offset: int = 0
    moe_first_dense: int = 0  # first k layers use dense MLP (DeepSeek-MoE)
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    n_heads: int
    head_dim: int
    d_state: int
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256
    # precision of the intra-chunk SSD tensors (decay matrix, dtx, partial
    # products); the inter-chunk state recurrence is always f32
    intra_dtype: str = "f32"  # f32 | bf16


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int
    encoder_ctx: int  # frames after the (stubbed) conv frontend
    d_frontend: int  # frontend feature dim fed by input_specs


@dataclass(frozen=True)
class VLMConfig:
    n_patches: int  # patch embeddings per sample (anyres tiling stub)
    d_vision: int  # vision tower output dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    # layer pattern
    attn_every: int = 1  # 0 => attention-free
    attn_offset: int = 0
    sliding_window: Optional[int] = None
    global_every: Optional[int] = None  # gemma3: 6 => 5 local : 1 global
    # components
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # flavour
    norm: str = "rmsnorm"
    act: str = "swiglu"
    attn_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_pct: float = 1.0
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d)
    # numerics / impl
    compute_dtype: str = "bfloat16"
    attn_impl: str = "chunked"  # full | chunked | pallas
    attn_chunk: int = 1024
    remat: str = "block"  # none | block
    z_loss: float = 0.0
    kv_cache_dtype: str = "bf16"  # bf16 | int8 (quantized decode cache)
    # decode GQA: "repeat" materializes H heads from the cache (baseline);
    # "grouped" keeps the Hkv axis so a sequence-sharded cache never
    # reshards (§Perf hillclimb B)
    gqa_decode: str = "repeat"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def layer_specs(self) -> list["LayerSpec"]:
        specs = []
        for i in range(self.n_layers):
            if self.attn_every and (i % self.attn_every) == self.attn_offset:
                mixer = "attn"
            elif self.ssm is not None:
                mixer = "mamba"
            else:
                mixer = "attn"
            is_global = True
            if self.sliding_window is not None:
                if self.global_every:
                    is_global = (i + 1) % self.global_every == 0
                else:
                    is_global = False
            if self.moe is not None and i >= self.moe.moe_first_dense and (
                (i - self.moe.moe_offset) % self.moe.moe_every == 0
            ):
                ffn = "moe"
            elif self.d_ff > 0:
                ffn = "mlp"
            else:
                ffn = "none"
            specs.append(LayerSpec(mixer=mixer, is_global=is_global, ffn=ffn))
        return specs

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for spec in self.layer_specs():
            n += d  # norm1
            if spec.mixer == "attn":
                n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                n += self.n_heads * hd * d
            else:
                s = self.ssm
                hp = s.n_heads * s.head_dim
                n += 2 * d * hp + 2 * d * s.n_groups * s.d_state + d * s.n_heads
                n += s.conv_width * (hp + 2 * s.n_groups * s.d_state)
                n += hp * d + hp + 3 * s.n_heads
            if spec.ffn == "mlp":
                n += d  # norm2
                mult = 3 if self.act in ("swiglu", "geglu") else 2
                n += mult * d * self.d_ff
            elif spec.ffn == "moe":
                n += d
                m = self.moe
                n += d * m.n_experts  # router
                n += m.n_experts * 3 * d * m.d_expert
                if m.n_shared:
                    n += 3 * d * (m.d_expert * m.n_shared)
        n += d  # final norm
        if self.encdec is not None:
            e = self.encdec
            per_enc = d + 2 * (d * self.n_heads * hd + d) + d + 2 * d * self.d_ff
            n += e.encoder_layers * per_enc  # rough: enc self-attn + mlp
            n += self.n_layers * (d + 2 * d * self.n_kv_heads * hd + d * self.n_heads * hd + self.n_heads * hd * d)  # cross-attn
        if self.vlm is not None:
            n += self.vlm.d_vision * d + d  # mm projector
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        all_experts = n_moe_layers * m.n_experts * 3 * self.d_model * m.d_expert
        active = n_moe_layers * m.top_k * 3 * self.d_model * m.d_expert
        return full - all_experts + active


@dataclass(frozen=True)
class LayerSpec:
    mixer: str  # attn | mamba
    is_global: bool
    ffn: str  # mlp | moe | none


# ----------------------------------------------------------------------
# Shapes (assigned input-shape set, identical for all LM archs)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "stablelm_12b",
    "deepseek_7b",
    "gemma3_1b",
    "internlm2_20b",
    "jamba_v01_52b",
    "whisper_medium",
    "deepseek_moe_16b",
    "granite_moe_1b",
    "mamba2_130m",
    "llava_next_mistral_7b",
]

# archs for which long_500k runs (sub-quadratic / mostly-local attention);
# the rest skip it (pure full attention — see DESIGN.md §Arch-applicability)
LONG_CTX_ARCHS = {"mamba2_130m", "jamba_v01_52b", "gemma3_1b"}


def get(name: str):
    """Return the module for arch ``name`` (exposes CONFIG and SMOKE)."""
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod


def get_config(name: str) -> ModelConfig:
    return get(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return get(name).SMOKE


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, with skip annotations."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and arch not in LONG_CTX_ARCHS:
                skip = "pure full-attention arch: 500k dense-KV decode exempted"
            if skip is None or include_skipped:
                out.append((arch, shape.name, skip))
    return out
