"""mamba2-130m [ssm]: 24L d=768 attention-free, ssm_state=128, vocab=50280.

[arXiv:2405.21060; unverified] — SSD (state-space duality): expand 2 →
d_inner 1536, headdim 64 → 24 heads, 1 group, d_state 128, conv width 4.
No FFN (d_ff = 0): the block IS the mixer. Tied embeddings.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2_130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_every=0,  # attention-free
    tie_embeddings=True,
    ssm=SSMConfig(n_heads=24, head_dim=64, d_state=128, n_groups=1),
)

SMOKE = ModelConfig(
    name="mamba2_130m_smoke",
    family="ssm",
    n_layers=3,
    d_model=96,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    attn_every=0,
    tie_embeddings=True,
    ssm=SSMConfig(n_heads=4, head_dim=16, d_state=16, n_groups=1),
)
