"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (kv=8) ff=14336, MoE 16e top-2.

[arXiv:2403.19887; hf] — Mamba:attention 7:1 interleave (1 attention layer
per block of 8, at in-block index 4), MoE every other layer (16 experts,
top-2), RMSNorm.  The mamba sublayers are modeled with the SSD form
(d_state 16, headdim 64 → 128 heads); see DESIGN.md hardware-adaptation
notes.  Attention layers use no RoPE in Jamba; we keep RoPE off-pattern
cost-free by retaining it (structural dry-run parity) — noted deviation.
"""
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba_v01_52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    attn_every=8,
    attn_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, moe_every=2, moe_offset=1),
    ssm=SSMConfig(n_heads=128, head_dim=64, d_state=16, n_groups=1),
)

SMOKE = ModelConfig(
    name="jamba_v01_52b_smoke",
    family="hybrid",
    n_layers=8,  # one full block: 1 attn + 7 mamba, 4 MoE
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attn_every=8,
    attn_offset=4,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=256, moe_every=2, moe_offset=1),
    ssm=SSMConfig(n_heads=4, head_dim=32, d_state=8, n_groups=1),
    attn_impl="full",
)
