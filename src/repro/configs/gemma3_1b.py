"""gemma3-1b [dense]: 26L d=1152 4H (kv=1) ff=6912 vocab=262144.

[hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global sliding window
(512 local), GeGLU, RMSNorm, qk-norm, embeddings scaled by sqrt(d), tied
embeddings, head_dim 256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    sliding_window=512,
    global_every=6,  # 5 local : 1 global
    act="geglu",
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="gemma3_1b_smoke",
    family="dense",
    n_layers=8,  # exercises the 5:1 pattern + remainder
    d_model=96,
    n_heads=2,
    n_kv_heads=1,
    head_dim=48,
    d_ff=256,
    vocab_size=512,
    sliding_window=16,
    global_every=6,
    act="geglu",
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    attn_impl="full",
)
