"""whisper-medium [audio]: 24L d=1024 16H (kv=16) ff=4096 vocab=51865.

[arXiv:2212.04356; unverified] — enc-dec (24 encoder + 24 decoder layers),
conv frontend STUBBED: input_specs feeds precomputed frame embeddings
(B, 1500, 80→d_frontend).  LayerNorm + GELU + attention biases per Whisper.
"""
from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium",
    family="audio",
    n_layers=24,  # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    attn_bias=True,
    mlp_bias=True,
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=24, encoder_ctx=1500, d_frontend=1024),
)

SMOKE = ModelConfig(
    name="whisper_medium_smoke",
    family="audio",
    n_layers=2,
    d_model=96,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab_size=384,
    norm="layernorm",
    act="gelu",
    attn_bias=True,
    mlp_bias=True,
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=2, encoder_ctx=32, d_frontend=48),
    attn_impl="full",
)
