"""llava-next-mistral-7b [vlm]: 32L d=4096 32H (kv=8) ff=14336 vocab=32000.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — Mistral-7B backbone;
anyres tiling vision frontend STUBBED: input_specs supplies precomputed
patch embeddings (B, 576, 1024) which an MLP projector maps into the LM
sequence ahead of the text tokens.
"""
from .base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava_next_mistral_7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    vlm=VLMConfig(n_patches=576, d_vision=1024),
)

SMOKE = ModelConfig(
    name="llava_next_mistral_7b_smoke",
    family="vlm",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=344,
    vocab_size=512,
    vlm=VLMConfig(n_patches=16, d_vision=48),
    attn_impl="full",
)
