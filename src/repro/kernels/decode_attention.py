"""Flash-decoding Pallas TPU kernel: one query vs a long KV cache.

Grid = (B*H, S/bs) with the cache dimension innermost; the online-softmax
state (acc, m, l) lives in VMEM scratch across cache blocks, so HBM traffic
is exactly one read of the KV cache — the decode roofline is KV-bandwidth
bound and this kernel hits it structurally.  Invalid cache slots (beyond
the current position / unwritten ring slots) are masked via an int32
validity vector, blocked alongside K/V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (1, hd)
    k = k_ref[0].astype(jnp.float32)  # (bs, hd)
    v = v_ref[0].astype(jnp.float32)
    s = (q @ k.T) * scale  # (1, bs)
    s = jnp.where(valid_ref[0][None, :] > 0, s, NEG_INF)
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * alpha + p.sum()
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[0, 0] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[0, 0], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("scale", "bs", "interpret"))
def decode_attention_bhsd(
    q: jax.Array,  # (BH, 1, hd)
    k: jax.Array,  # (BH, S, hd)
    v: jax.Array,
    valid: jax.Array,  # (BH, S) int32 — 1 where the slot holds a real key
    *,
    scale: float,
    bs: int = 512,
    interpret: bool = True,
) -> jax.Array:
    bh, _, hd = q.shape
    s = k.shape[1]
    bs = min(bs, s)
    assert s % bs == 0, (s, bs)
    grid = (bh, s // bs)
    kernel = functools.partial(_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bs), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
