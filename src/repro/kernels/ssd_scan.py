"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid = (B*H, T/Q) with the chunk dimension innermost; the SSM state (P, N)
is VMEM scratch carried across chunks.  Per chunk the kernel does the
dense SSD algebra (segment-sum decay matrix, C·Bᵀ scores, state update) as
(Q×N)@(N×Q) and (Q×Q)@(Q×P) matmuls — MXU work — instead of a length-T
recurrence, which is the SSD insight mapped onto the TPU: the only true
sequential dependency is the tiny (P×N) state hop between chunks.

Shapes per program: x (Q,P), dt (Q,1), B/C (Q,N), A scalar (per head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, q):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, 1)
    A = a_ref[0, 0]  # scalar log-decay rate (negative)
    B = b_ref[0].astype(jnp.float32)  # (Q, N)
    C = c_ref[0].astype(jnp.float32)  # (Q, N)

    a = dt[:, 0] * A  # (Q,) per-step log decay
    a_cum = jnp.cumsum(a)  # (Q,)

    # intra-chunk: L[i,j] = exp(sum_{j<s<=i} a_s) for j <= i
    diff = a_cum[:, None] - a_cum[None, :]  # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    scores = (C @ B.T) * L  # (Q, Q)
    dtx = x * dt  # (Q, P)
    y = scores @ dtx  # (Q, P)

    # inter-chunk: contribution of the incoming state
    decay_from_start = jnp.exp(a_cum)[:, None]  # (Q, 1)
    y += (C * decay_from_start) @ state_ref[...].T  # (Q,N)@(N,P)

    # state update: S = exp(sum a) * S_in + sum_s exp(a_cum[end]-a_cum[s]) dtx_s B_s
    decay_to_end = jnp.exp(a_cum[-1] - a_cum)[:, None]  # (Q, 1)
    new_state = (dtx * decay_to_end).T @ B  # (P, N)
    state_ref[...] = jnp.exp(a_cum[-1]) * state_ref[...] + new_state

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def ssd_scan_bhtpn(
    x: jax.Array,  # (BH, T, P)
    dt: jax.Array,  # (BH, T, 1) — post-softplus
    a: jax.Array,  # (BH, 1) negative per-head decay rate
    b: jax.Array,  # (BH, T, N)
    c: jax.Array,  # (BH, T, N)
    *,
    q: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, t, p = x.shape
    n = b.shape[2]
    q = min(q, t)
    assert t % q == 0, (t, q)
    grid = (bh, t // q)
    kernel = functools.partial(_ssd_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
