"""Flash-attention Pallas TPU kernel (prefill/train path).

Canonical TPU tiling: grid = (B*H, Tq/bq, Tk/bk) with the KV dimension
innermost (TPU grids run sequentially, so VMEM scratch carries the online
softmax state across KV blocks).  Q/K/V blocks live in VMEM; the MXU sees
(bq × hd) @ (hd × bk) and (bq × bk) @ (bk × hd) matmuls with bq=bk=128 by
default — hardware-aligned on the 128×128 systolic array.

Causal and sliding-window masking are applied from absolute positions
derived from block indices (positions are assumed contiguous from 0, which
is how the models call it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, window, bq, bk
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window

    # Skip fully-masked blocks (strictly above the diagonal / outside window).
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = (q @ k.T) * scale  # (bq, bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[:, 0] = m_new

    # any overlap with the allowed region?
    lo_q, hi_q = qi * bq, qi * bq + bq - 1
    lo_k = ki * bk
    live = hi_q >= lo_k
    if window is not None:
        live &= (lo_q - (ki * bk + bk - 1)) < window
    pl.when(live)(_compute)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "bq", "bk", "interpret")
)
def flash_attention_bhtd(
    q: jax.Array,  # (BH, T, hd)
    k: jax.Array,  # (BH, S, hd)
    v: jax.Array,
    *,
    scale: float,
    window: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, t, hd = q.shape
    s = k.shape[1]
    bq = min(bq, t)
    bk = min(bk, s)
    assert t % bq == 0 and s % bk == 0, (t, bq, s, bk)
    grid = (bh, t // bq, s // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, window=window, bq=bq, bk=bk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
