"""Fused cap-chain rate kernel for the vector flow engine (jax/pallas).

One wide recompute front of the FaaSNet fluid model is an elementwise
minimum chain over per-flow gathered operands::

    rate(f) = min(per_stream_cap,
                  src_out_cap / n_out(src),
                  dst_in_cap  / n_in(dst),
                  decompress_rate,
                  block_size * qps(src) / n_out(src)   [block-mode only],
                  parent_rate)                          [+inf when absent]

The numpy path in :class:`repro.sim.vector_engine.VectorFlowSim` pays ~10
separate elementwise dispatches per front for this; here the whole chain is
one fused pallas kernel over the front (``cap_chain_rates``), plus a
segment-reduction kernel for the per-NIC active-flow counts that feed the
equal-split denominators (``nic_flow_counts``).

Bit-identity contract: the kernel runs in **float64** (under
``jax.experimental.enable_x64``, scoped so the rest of the process keeps
default jax dtype promotion) and performs the identical IEEE-754 divisions
and minima on the identical operands as the numpy/scalar paths, so the
resulting rates — and therefore the engine's event log — are bit-identical,
not merely close.  ``tests/test_vector_engine.py`` pins this with a
four-way differential.  Like the other kernels in this package the pallas
call runs in interpret mode on CPU hosts; when jax is absent entirely the
callers fall back to the numpy reference (``cap_chain_rates_np``), which is
also the oracle the kernel is tested against.
"""
from __future__ import annotations

import functools

import numpy as np

try:  # pragma: no cover - exercised via have_jax() at runtime
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental import pallas as pl

    _HAVE_JAX = True
except Exception:  # pragma: no cover - jax genuinely absent on this host
    _HAVE_JAX = False

__all__ = [
    "have_jax",
    "cap_chain_rates",
    "cap_chain_rates_np",
    "nic_flow_counts",
    "nic_flow_counts_np",
]

# Pallas block width for the 1-D front; fronts are padded up to a multiple
# with neutral operands (n_out=n_in=1, caps=+inf) and sliced back.
_BLK = 256


def have_jax() -> bool:
    """True when the jax/pallas path is importable on this host."""
    return _HAVE_JAX


# ----------------------------------------------------------------------
# numpy reference (and jax-absent fallback)
# ----------------------------------------------------------------------
def cap_chain_rates_np(
    n_out,
    n_in,
    out_cap,
    qps,
    par_rate,
    blk,
    *,
    per_stream_cap: float,
    in_cap: float,
    decompress_rate: float,
    block_size: float,
) -> np.ndarray:
    """Reference min-cap chain: same operand order as the fused kernel."""
    n_out = np.asarray(n_out, dtype=np.float64)
    r = np.minimum(per_stream_cap, np.asarray(out_cap, dtype=np.float64) / n_out)
    r = np.minimum(r, in_cap / np.asarray(n_in, dtype=np.float64))
    r = np.minimum(r, decompress_rate)
    b = np.asarray(blk, dtype=bool)
    if b.any():
        q = block_size * np.asarray(qps, dtype=np.float64) / n_out
        r = np.where(b, np.minimum(r, q), r)
    return np.minimum(r, np.asarray(par_rate, dtype=np.float64))


def nic_flow_counts_np(nodes, n_nodes: int) -> np.ndarray:
    """Reference segment reduction: active-flow count per NIC index."""
    return np.bincount(np.asarray(nodes, dtype=np.int64), minlength=n_nodes)


# ----------------------------------------------------------------------
# pallas kernels
# ----------------------------------------------------------------------
if _HAVE_JAX:

    def _cap_chain_kernel(
        n_out_ref, n_in_ref, out_cap_ref, qps_ref, par_ref, blk_ref, caps_ref,
        r_ref,
    ):
        n_out = n_out_ref[...]
        per_stream = caps_ref[0]
        in_cap = caps_ref[1]
        dec = caps_ref[2]
        bsz = caps_ref[3]
        r = jnp.minimum(per_stream, out_cap_ref[...] / n_out)
        r = jnp.minimum(r, in_cap / n_in_ref[...])
        r = jnp.minimum(r, dec)
        # Block-mode flows add the shard QPS throttle; computed for every
        # lane (qps=+inf on VM sources keeps it neutral) and masked in.
        r = jnp.where(
            blk_ref[...], jnp.minimum(r, bsz * qps_ref[...] / n_out), r
        )
        r_ref[...] = jnp.minimum(r, par_ref[...])

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def _cap_chain_call(n_out, n_in, out_cap, qps, par, blk, caps, *, interpret):
        n = n_out.shape[0]
        spec = pl.BlockSpec((_BLK,), lambda i: (i,))
        return pl.pallas_call(
            _cap_chain_kernel,
            grid=(n // _BLK,),
            in_specs=[
                spec, spec, spec, spec, spec, spec,
                pl.BlockSpec((4,), lambda i: (0,)),
            ],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((n,), n_out.dtype),
            interpret=interpret,
        )(n_out, n_in, out_cap, qps, par, blk, caps)

    def _count_kernel(nodes_ref, cnt_ref, *, n_nodes):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

        # Scatter-add bincount for this chunk; padded lanes carry index
        # n_nodes and land in the sacrificial overflow slot sliced off below.
        cnt = cnt_ref[...]
        cnt_ref[...] = cnt.at[nodes_ref[...]].add(1)

    @functools.partial(jax.jit, static_argnames=("n_nodes", "interpret"))
    def _count_call(nodes, *, n_nodes, interpret):
        n = nodes.shape[0]
        kernel = functools.partial(_count_kernel, n_nodes=n_nodes)
        return pl.pallas_call(
            kernel,
            grid=(n // _BLK,),
            in_specs=[pl.BlockSpec((_BLK,), lambda i: (i,))],
            out_specs=pl.BlockSpec((n_nodes + 1,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((n_nodes + 1,), nodes.dtype),
            interpret=interpret,
        )(nodes)


def _pad(a: np.ndarray, pad: int, value) -> np.ndarray:
    if pad == 0:
        return a
    return np.pad(a, (0, pad), constant_values=value)


# ----------------------------------------------------------------------
# public entry points
# ----------------------------------------------------------------------
def cap_chain_rates(
    n_out,
    n_in,
    out_cap,
    qps,
    par_rate,
    blk,
    *,
    per_stream_cap: float,
    in_cap: float,
    decompress_rate: float,
    block_size: float,
    interpret: bool = True,
) -> np.ndarray:
    """Fused per-flow min-cap chain over one recompute front.

    All array inputs are per-flow gathers of length ``len(front)``; counts
    may be integer dtype (converted exactly to float64 — fleet counts are
    far below 2**53).  Returns float64 rates bit-identical to
    :func:`cap_chain_rates_np`.  Falls back to the numpy reference when jax
    is unavailable.
    """
    if not _HAVE_JAX:
        return cap_chain_rates_np(
            n_out, n_in, out_cap, qps, par_rate, blk,
            per_stream_cap=per_stream_cap,
            in_cap=in_cap,
            decompress_rate=decompress_rate,
            block_size=block_size,
        )
    n = len(n_out)
    pad = (-n) % _BLK
    no = _pad(np.asarray(n_out, dtype=np.float64), pad, 1.0)
    ni = _pad(np.asarray(n_in, dtype=np.float64), pad, 1.0)
    oc = _pad(np.asarray(out_cap, dtype=np.float64), pad, 0.0)
    qp = _pad(np.asarray(qps, dtype=np.float64), pad, 0.0)
    pr = _pad(np.asarray(par_rate, dtype=np.float64), pad, 0.0)
    bk = _pad(np.asarray(blk, dtype=bool), pad, False)
    caps = np.asarray(
        [per_stream_cap, in_cap, decompress_rate, block_size], dtype=np.float64
    )
    # x64 scoped to the call: the kernel must trace and run in float64 for
    # bit-identity with the numpy oracle, without flipping global jax
    # promotion for other float32 kernels in the same process.
    with enable_x64():
        out = _cap_chain_call(
            jnp.asarray(no), jnp.asarray(ni), jnp.asarray(oc), jnp.asarray(qp),
            jnp.asarray(pr), jnp.asarray(bk), jnp.asarray(caps),
            interpret=interpret,
        )
        res = np.asarray(out)
    return res[:n] if pad else res


def nic_flow_counts(nodes, n_nodes: int, *, interpret: bool = True) -> np.ndarray:
    """Segment-reduced active-flow counts per NIC (scatter-add bincount).

    Validates the engine's incrementally-maintained ``_nout_cnt``/
    ``_nin_cnt`` arrays; numpy ``bincount`` fallback when jax is absent.
    """
    if not _HAVE_JAX:
        return nic_flow_counts_np(nodes, n_nodes)
    nodes = np.asarray(nodes, dtype=np.int64)
    pad = (-len(nodes)) % _BLK
    padded = _pad(nodes, pad, n_nodes)  # overflow slot catches pad lanes
    with enable_x64():
        out = _count_call(jnp.asarray(padded), n_nodes=n_nodes, interpret=interpret)
        res = np.asarray(out)
    return res[:n_nodes]
