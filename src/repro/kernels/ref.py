"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each oracle is the *mathematically direct* formulation — full attention
matrices, per-step SSM recurrence — with f32 accumulation, so tests compare
the tiled kernels against an implementation with no shared code or tricks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(
    q: jax.Array,  # (BH, T, hd)
    k: jax.Array,  # (BH, S, hd)
    v: jax.Array,
    *,
    scale: float,
    window: int | None = None,
) -> jax.Array:
    t, s = q.shape[1], k.shape[1]
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qp = jnp.arange(t)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    logits = jnp.where(mask[None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bts,bsd->btd", w, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (BH, 1, hd)
    k: jax.Array,  # (BH, S, hd)
    v: jax.Array,
    valid: jax.Array,  # (BH, S) int32
    *,
    scale: float,
) -> jax.Array:
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(valid[:, None, :] > 0, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bts,bsd->btd", w, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,  # (BH, T, P)
    dt: jax.Array,  # (BH, T, 1)
    a: jax.Array,  # (BH, 1)
    b: jax.Array,  # (BH, T, N)
    c: jax.Array,  # (BH, T, N)
) -> jax.Array:
    """Direct per-step recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    bh, t, p = x.shape
    n = b.shape[2]

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (BH,P), (BH,1), (BH,N), (BH,N)
        decay = jnp.exp(dtt * a)  # (BH,1)
        h = decay[..., None] * h + jnp.einsum(
            "bp,bn->bpn", xt.astype(jnp.float32) * dtt, bt.astype(jnp.float32)
        )
        y = jnp.einsum("bpn,bn->bp", h, ct.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((bh, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        h0,
        (
            x.transpose(1, 0, 2),
            dt.astype(jnp.float32).transpose(1, 0, 2),
            b.transpose(1, 0, 2),
            c.transpose(1, 0, 2),
        ),
    )
    return ys.transpose(1, 0, 2).astype(x.dtype)
