"""Jit'd public wrappers over the Pallas kernels (model-facing API).

``interpret`` defaults to True on CPU hosts (this container) and should be
False on real TPU backends; the models only route here when
``cfg.attn_impl == "pallas"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_bhsd
from .flash_attention import flash_attention_bhtd
from .ssd_scan import ssd_scan_bhtpn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, q_pos=None, k_pos=None, window=None, scale,
                    interpret=None):
    """(B,H,T,hd) attention; positions must be contiguous from 0."""
    if interpret is None:
        interpret = not _on_tpu()
    b, h, t, hd = q.shape
    s = k.shape[2]
    out = flash_attention_bhtd(
        q.reshape(b * h, t, hd),
        k.reshape(b * h, s, hd),
        v.reshape(b * h, s, hd),
        scale=scale,
        window=window,
        interpret=interpret,
    )
    return out.reshape(b, h, t, hd)


def decode_attention(q, k, v, valid, *, scale, interpret=None):
    """q (B,H,1,hd), k/v (B,H,S,hd), valid (S,) or (B,S)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, h, _, hd = q.shape
    s = k.shape[2]
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None], (b, s))
    validbh = jnp.broadcast_to(valid[:, None, :], (b, h, s)).reshape(b * h, s)
    out = decode_attention_bhsd(
        q.reshape(b * h, 1, hd),
        k.reshape(b * h, s, hd),
        v.reshape(b * h, s, hd),
        validbh.astype(jnp.int32),
        scale=scale,
        interpret=interpret,
    )
    return out.reshape(b, h, 1, hd)


def ssd_scan(x, dt, a, b, c, *, chunk=128, interpret=None):
    """x (B,T,H,P), dt (B,T,H), a (H,), b/c (B,T,G,N) with G broadcast to H."""
    if interpret is None:
        interpret = not _on_tpu()
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)
    out = ssd_scan_bhtpn(
        x.transpose(0, 2, 1, 3).reshape(bsz * h, t, p),
        dt.transpose(0, 2, 1).reshape(bsz * h, t, 1),
        jnp.broadcast_to(a[None], (bsz, h)).reshape(bsz * h, 1),
        bh.transpose(0, 2, 1, 3).reshape(bsz * h, t, n),
        ch.transpose(0, 2, 1, 3).reshape(bsz * h, t, n),
        q=chunk,
        interpret=interpret,
    )
    return out.reshape(bsz, h, t, p).transpose(0, 2, 1, 3)
