"""Checkpointing in the FaaSNet I/O-efficient block format (paper §3.5).

A checkpoint is ONE byte stream (all leaves concatenated, f32/bf16 raw
little-endian) stored as zstd-compressed fixed-size blocks with an offset
table, plus a JSON manifest mapping each leaf path to its (offset, size)
within the raw stream.  That layout is exactly what the paper's on-demand
fetch needs:

  * **lazy restore** — read only the blocks covering the leaves a consumer
    needs first (embedding + first layer-group for serving cold start);
  * **tree distribution** — the compressed blocks are the unit streamed
    down host FTs (``repro.sim``) or the device tree (``broadcast.py``);
  * **read-amplification accounting** — BlockReader.stats reproduces the
    paper's Fig. 20 analysis on real checkpoints.

Saves are atomic (tmp + rename) and optionally asynchronous (background
thread); ``latest_step`` scans for the newest *complete* checkpoint, so a
crash mid-save never corrupts restart.
"""
from __future__ import annotations

import json
import os
import re
import threading
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockstore import (
    DEFAULT_BLOCK_SIZE,
    BlockReader,
    write_blockstore,
)

PyTree = Any


def _leaf_paths(tree: PyTree) -> list[tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for k in path:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        out.append(("/".join(keys), leaf))
    return out


@dataclass
class LeafMeta:
    path: str
    shape: tuple[int, ...]
    dtype: str
    offset: int  # into the raw (uncompressed) stream
    nbytes: int


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        *,
        block_size: int = DEFAULT_BLOCK_SIZE,
        keep: int = 3,
        async_save: bool = False,
    ) -> None:
        self.dir = directory
        self.block_size = block_size
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _paths(self, step: int) -> tuple[str, str]:
        return (
            os.path.join(self.dir, f"ckpt_{step:08d}.blocks"),
            os.path.join(self.dir, f"ckpt_{step:08d}.json"),
        )

    def save(self, step: int, tree: PyTree) -> None:
        leaves = _leaf_paths(tree)
        metas: list[LeafMeta] = []
        bufs: list[bytes] = []
        off = 0
        for path, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == jnp.bfloat16:
                raw = arr.view(np.uint16).tobytes()
                dtype = "bfloat16"
            else:
                raw = arr.tobytes()
                dtype = str(arr.dtype)
            metas.append(LeafMeta(path, tuple(arr.shape), dtype, off, len(raw)))
            bufs.append(raw)
            off += len(raw)
        payload = b"".join(bufs)

        def write() -> None:
            bpath, mpath = self._paths(step)
            manifest = write_blockstore(payload, bpath, block_size=self.block_size)
            doc = {
                "step": step,
                "block_manifest": manifest.to_dict(),
                "leaves": [m.__dict__ for m in metas],
            }
            tmp = mpath + ".tmp"
            with open(tmp, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, mpath)  # manifest last => presence implies complete
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            for p in self._paths(s):
                try:
                    os.remove(p)
                except FileNotFoundError:
                    pass

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.match(r"ckpt_(\d+)\.json$", name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def _load_manifest(self, step: int) -> tuple[dict, list[LeafMeta]]:
        _, mpath = self._paths(step)
        with open(mpath) as f:
            doc = json.load(f)
        metas = [LeafMeta(**{**m, "shape": tuple(m["shape"])}) for m in doc["leaves"]]
        return doc, metas

    def _decode(self, meta: LeafMeta, raw: bytes):
        if meta.dtype == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(meta.shape)
            return jnp.asarray(arr.view(jnp.bfloat16))
        return jnp.asarray(np.frombuffer(raw, np.dtype(meta.dtype)).reshape(meta.shape))

    def restore(self, step: int, like: PyTree) -> PyTree:
        """Full restore into the structure of ``like``."""
        doc, metas = self._load_manifest(step)
        with BlockReader(self._paths(step)[0]) as reader:
            by_path = {m.path: m for m in metas}
            leaves = []
            for path, leaf in _leaf_paths(like):
                m = by_path[path]
                leaves.append(self._decode(m, reader.read_range(m.offset, m.nbytes)))
        return jax.tree.unflatten(jax.tree.structure(like), leaves)

    def restore_lazy(
        self,
        step: int,
        like: PyTree,
        first: Callable[[str], bool],
    ) -> tuple[PyTree, Callable[[], PyTree], "BlockReader"]:
        """On-demand restore (paper §3.5): load leaves matching ``first`` now.

        Returns (partial tree with zeros elsewhere, finish() to complete it,
        reader for fetch statistics).  ``finish()`` returns the full tree.
        """
        doc, metas = self._load_manifest(step)
        reader = BlockReader(self._paths(step)[0])
        by_path = {m.path: m for m in metas}
        tdef = jax.tree.structure(like)
        pairs = _leaf_paths(like)

        def load(pred):
            ls = []
            for path, leaf in pairs:
                m = by_path[path]
                if pred(path):
                    ls.append(self._decode(m, reader.read_range(m.offset, m.nbytes)))
                else:
                    ls.append(jnp.zeros(m.shape, jnp.dtype(
                        jnp.bfloat16 if m.dtype == "bfloat16" else m.dtype)))
            return jax.tree.unflatten(tdef, ls)

        partial_tree = load(first)

        def finish() -> PyTree:
            return load(lambda p: True)

        return partial_tree, finish, reader

    def iter_blocks(self, step: int) -> Iterator[bytes]:
        """Compressed blocks in order — the unit FaaSNet streams down FTs."""
        with BlockReader(self._paths(step)[0]) as reader:
            for i in range(reader.manifest.n_blocks):
                yield reader.fetch_block_compressed(i)
