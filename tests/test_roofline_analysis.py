"""HLO roofline analyzer + sharding rules: unit coverage.

The analyzer feeds §Roofline, so its parsing must be exact on known HLO;
sharding rules are checked against an abstract production mesh (no devices
needed to validate PartitionSpecs).
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.launch.hlo_analysis import (
    HBM_BW,
    PEAK_FLOPS,
    analyze_hlo,
    parse_hlo,
    roofline_terms,
)

SAMPLE = """
HloModule jit_f

%body (arg: (s32[], f32[8,8], f32[8,8])) -> (s32[], f32[8,8], f32[8,8]) {
  %arg = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}) parameter(0)
  %c1 = s32[] constant(1)
  %i = s32[] get-tuple-element(%arg), index=0
  %a = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %w = f32[8,8]{1,0} get-tuple-element(%arg), index=2
  %ni = s32[] add(%i, %c1)
  %d = f32[8,8]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%body
  ROOT %t = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}) tuple(%ni, %ar, %w)
}

%cond (arg2: (s32[], f32[8,8], f32[8,8])) -> pred[] {
  %arg2 = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}) parameter(0)
  %c7 = s32[] constant(7)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  ROOT %lt = pred[] compare(%i2, %c7), direction=LT
}

ENTRY %main (x: f32[8,8], w0: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %w0 = f32[8,8]{1,0} parameter(1)
  %c0 = s32[] constant(0)
  %tup = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}) tuple(%c0, %x, %w0)
  %wh = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_while_trip_scaling():
    stats = analyze_hlo(SAMPLE)
    # dot: 2*8*8*8 flops, scaled by trip count 7
    assert stats.flops == 7 * 2 * 8 * 8 * 8
    # all-reduce operand: 8*8*4 bytes, scaled by 7
    assert stats.collective_bytes == 7 * 8 * 8 * 4
    assert stats.count_by_kind["all-reduce"] == 7


def test_parse_handles_tuple_types():
    comps, entry = parse_hlo(SAMPLE)
    assert entry == "%main"
    ops = {i.op for i in comps["%body"].instrs}
    assert {"dot", "all-reduce", "add", "tuple"} <= ops


def test_roofline_terms_math():
    r = roofline_terms(
        hlo_flops=PEAK_FLOPS,  # exactly 1 s of compute
        hlo_bytes=HBM_BW / 2,  # 0.5 s of memory
        collective_bytes=0.0,
        chips=4,
        model_flops=2 * PEAK_FLOPS,  # 0.5 s useful per chip
    )
    assert r["dominant"] == "compute"
    assert r["bound_s"] == pytest.approx(1.0)
    assert r["roofline_fraction"] == pytest.approx(0.5)


def test_real_compiled_module_roundtrip():
    """Analyzer numbers on a real compiled scan match hand math."""
    L, D = 5, 32

    def f(x, w):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    w = jax.ShapeDtypeStruct((D, D), jnp.float32)
    text = jax.jit(f).lower(x, w).compile().as_text()
    stats = analyze_hlo(text)
    assert stats.flops == L * 2 * D * D * D


# ----------------------------------------------------------------------
# sharding rules on the (abstract) production mesh
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def rules():
    from repro.configs import get_config
    from repro.distributed.sharding import ShardingRules

    from repro.compat import abstract_mesh

    mesh = abstract_mesh((16, 16), ("data", "model"))
    return ShardingRules(get_config("stablelm_12b"), mesh)


def test_param_specs(rules):
    assert rules.param_spec(("embed", "table"), (100352, 5120)) == P("model", None)
    # q heads 32 % 16 == 0 -> sharded; kv heads 8 % 16 != 0 -> replicated
    assert rules.param_spec(("attn", "wq"), (5120, 32, 160)) == P(None, "model", None)
    assert rules.param_spec(("attn", "wk"), (5120, 8, 160)) == P(None, None, None)
    assert rules.param_spec(("mlp", "w_gate"), (5120, 13824)) == P(None, "model")
    # stage-stacked leaf: leading repeat dim stays unsharded
    assert rules.param_spec(("attn", "wq"), (40, 5120, 32, 160)) == P(
        None, None, "model", None
    )
    assert rules.param_spec(("norm1", "scale"), (5120,)) == P(None)


def test_zero1_extends_first_free_dim(rules):
    base = rules.param_spec(("attn", "wq"), (5120, 32, 160))
    z = rules.zero1_spec(base, (5120, 32, 160))
    assert z == P(("data",), "model", None)


def test_cache_specs(rules):
    # kv heads 8 not divisible by 16 -> sequence goes to model
    assert rules.cache_spec(("k",), (128, 8, 32768, 160)) == P(
        ("data",), None, "model", None
    )
    # divisible kv heads -> heads to model
    assert rules.cache_spec(("k",), (128, 16, 32768, 160)) == P(
        ("data",), "model", None, None
    )
    # batch 1 (long_500k): no data sharding
    assert rules.cache_spec(("k",), (1, 8, 524288, 160)) == P(
        None, None, "model", None
    )


def test_batch_specs(rules):
    assert rules.batch_spec("tokens", (256, 4096)) == P(("data",), None)
    assert rules.batch_spec("pos", ()) == P()
    assert rules.batch_spec("tokens", (1, 4096)) == P(None, None)
