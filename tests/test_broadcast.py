"""Tree-broadcast schedules: correctness on a forced multi-device CPU mesh.

Runs in a SUBPROCESS because the 8-device XLA_FLAGS must be set before jax
initializes, and the rest of the suite needs the default single device.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys, json
sys.path.insert(0, os.environ['REPRO_SRC'])
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.distributed.broadcast import (
    tree_broadcast, faasnet_rounds, binomial_rounds, _bcast_body,
    flatten_pytree, unflatten_pytree)

mesh = make_mesh((4, 2), ('data', 'model'))
params = {'a': jnp.arange(640, dtype=jnp.float32).reshape(80, 8) / 1037.0,
          'b': jnp.arange(10, dtype=jnp.float32) * 0.05}
flat, spec = flatten_pytree(params, pad_to=4)
out = {}

# 1) schedule delivers root's bytes to every replica, from garbage
for sched, info in [('binomial', binomial_rounds(4)),
                    ('pipelined', faasnet_rounds(4, 4)),
                    ('naive', None)]:
    def corrupt_then_bcast(buf, sched=sched, info=info):
        idx = jax.lax.axis_index(('data',))
        buf = jnp.where(idx == 0, buf, -7.0)
        return _bcast_body(buf, axes=('data',), dp=4, schedule=sched,
                           n_blocks=4, rounds_info=info)
    outs = shard_map(corrupt_then_bcast, mesh=mesh, in_specs=P(),
                     out_specs=P('data'), check_vma=False)(
        jnp.broadcast_to(flat, flat.shape))
    ok = bool(jnp.allclose(outs.reshape(4, -1), flat[None], atol=0))
    out[f'{sched}_correct'] = ok

# 2) end-to-end API: identity on replicated params + report sanity
for sched in ('naive', 'allgather', 'binomial', 'pipelined'):
    res, rep = tree_broadcast(params, mesh, schedule=sched, n_blocks=4)
    same = all(np.allclose(np.asarray(x), np.asarray(y), atol=2e-2)
               for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(res)))
    out[f'{sched}_identity'] = same
    out[f'{sched}_serialized'] = rep.serialized_bytes
    out[f'{sched}_rounds'] = rep.rounds

# 3) compressed broadcast close to exact
res, rep = tree_broadcast(params, mesh, schedule='pipelined', n_blocks=4,
                          compress=True)
err = max(float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
          for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(res)))
out['compress_max_err'] = err
out['compress_payload'] = rep.payload_bytes

# 4) faasnet schedule static properties at larger dp
r16 = faasnet_rounds(16, 32)
out['dp16_blocks32_rounds'] = len(r16)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["REPRO_SRC"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_schedules_deliver_from_root(results):
    for sched in ("binomial", "pipelined", "naive"):
        assert results[f"{sched}_correct"], sched


def test_identity_on_replicated(results):
    for sched in ("naive", "allgather", "binomial", "pipelined"):
        assert results[f"{sched}_identity"], sched


def test_serialized_bytes_ordering(results):
    """pipelined ≤ binomial ≤ naive ≤ allgather in serialized link traffic."""
    assert results["pipelined_serialized"] <= results["binomial_serialized"]
    assert results["binomial_serialized"] <= results["naive_serialized"]
    assert results["naive_serialized"] <= results["allgather_serialized"]


def test_compressed_broadcast(results):
    assert results["compress_max_err"] < 2e-2
    # int8 payload ≈ half the bf16 payload
    assert results["compress_payload"] < results["pipelined_serialized"]


def test_faasnet_round_count(results):
    """Single-port binary tree: ~2B + O(log dp) rounds for B blocks."""
    assert results["dp16_blocks32_rounds"] <= 2 * 32 + 2 * 4 + 4
