"""FunctionTree: unit tests + hypothesis property tests (balance invariant)."""
import math
import random

import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import FunctionTree


def test_insert_first_is_root():
    ft = FunctionTree("f")
    ft.insert("a")
    assert ft.root.vm_id == "a"
    assert ft.parent_of("a") is None
    assert ft.height == 1


def test_insert_attaches_bfs_first_open_slot():
    ft = FunctionTree("f")
    for v in "abcde":
        ft.insert(v)
    # complete binary tree: b,c under a; d,e under b
    assert ft.children_of("a") == ["b", "c"]
    assert ft.children_of("b") == ["d", "e"]
    assert ft.height == 3


def test_duplicate_insert_raises():
    ft = FunctionTree("f")
    ft.insert("a")
    with pytest.raises(ValueError):
        ft.insert("a")


def test_delete_missing_raises():
    ft = FunctionTree("f")
    with pytest.raises(KeyError):
        ft.delete("zz")


def test_delete_root_single():
    ft = FunctionTree("f")
    ft.insert("a")
    ft.delete("a")
    assert ft.root is None and len(ft) == 0


def test_delete_root_promotes_and_balances():
    ft = FunctionTree("f")
    for v in "abcdefg":
        ft.insert(v)
    ft.delete("a")
    ft.check_invariants()
    assert "a" not in ft
    assert len(ft) == 6


def test_delete_interior_rebalances():
    ft = FunctionTree("f")
    for i in range(20):
        ft.insert(f"v{i}")
    for victim in ("v1", "v2", "v5", "v0"):
        ft.delete(victim)
        ft.check_invariants()
    assert len(ft) == 16


def test_height_logarithmic_after_inserts():
    ft = FunctionTree("f")
    for i in range(1000):
        ft.insert(f"v{i}")
    ft.check_invariants()
    assert ft.height == math.floor(math.log2(1000)) + 1  # complete tree


def test_edges_match_parents():
    ft = FunctionTree("f")
    for i in range(50):
        ft.insert(f"v{i}")
    for parent, child in ft.edges():
        assert ft.parent_of(child) == parent


def test_serialization_roundtrip():
    ft = FunctionTree("fid")
    for i in range(33):
        ft.insert(f"v{i}")
    ft.delete("v7")
    d = ft.to_dict()
    ft2 = FunctionTree.from_dict(d)
    ft2.check_invariants()
    assert ft2.vm_ids() == ft.vm_ids()
    assert ft2.height == ft.height


def test_rotations_preserve_membership():
    random.seed(7)
    ft = FunctionTree("f")
    alive = []
    for i in range(200):
        v = f"v{i}"
        ft.insert(v)
        alive.append(v)
    random.shuffle(alive)
    for v in alive[:150]:
        ft.delete(v)
        ft.check_invariants()
    remaining = set(alive[150:])
    assert set(ft.vm_ids()) == remaining


# ----------------------------------------------------------------------
# hypothesis: the AVL height invariant survives any insert/delete sequence
# ----------------------------------------------------------------------
@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 40)), max_size=120))
def test_invariants_under_random_ops(ops):
    ft = FunctionTree("f")
    live: list[str] = []
    counter = 0
    for is_insert, idx in ops:
        if is_insert or not live:
            v = f"n{counter}"
            counter += 1
            ft.insert(v)
            live.append(v)
        else:
            v = live.pop(idx % len(live))
            ft.delete(v)
        ft.check_invariants()
    assert sorted(ft.vm_ids()) == sorted(live)
    if live:
        # AVL height bound: h <= 1.4405 log2(n+2)
        assert ft.height <= 1.4405 * math.log2(len(live) + 2) + 1


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 300))
def test_bfs_first_slot_keeps_completeness(n):
    ft = FunctionTree("f")
    for i in range(n):
        ft.insert(f"v{i}")
    assert ft.height == math.floor(math.log2(n)) + 1
