"""FunctionTree: unit tests + invariant property tests (balance invariant).

Two flavours of property testing:
  * seeded ``random.Random`` churn sequences — always run, no third-party
    dependency, cover invariants I1-I4 and the ``on_reparent`` contract;
  * hypothesis variants — run only when ``hypothesis`` is installed.
"""
import math
import random

import pytest

from repro.core import FunctionTree

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False


def test_insert_first_is_root():
    ft = FunctionTree("f")
    ft.insert("a")
    assert ft.root.vm_id == "a"
    assert ft.parent_of("a") is None
    assert ft.height == 1


def test_insert_attaches_bfs_first_open_slot():
    ft = FunctionTree("f")
    for v in "abcde":
        ft.insert(v)
    # complete binary tree: b,c under a; d,e under b
    assert ft.children_of("a") == ["b", "c"]
    assert ft.children_of("b") == ["d", "e"]
    assert ft.height == 3


def test_duplicate_insert_raises():
    ft = FunctionTree("f")
    ft.insert("a")
    with pytest.raises(ValueError):
        ft.insert("a")


def test_delete_missing_raises():
    ft = FunctionTree("f")
    with pytest.raises(KeyError):
        ft.delete("zz")


def test_delete_root_single():
    ft = FunctionTree("f")
    ft.insert("a")
    ft.delete("a")
    assert ft.root is None and len(ft) == 0


def test_delete_root_promotes_and_balances():
    ft = FunctionTree("f")
    for v in "abcdefg":
        ft.insert(v)
    ft.delete("a")
    ft.check_invariants()
    assert "a" not in ft
    assert len(ft) == 6


def test_delete_interior_rebalances():
    ft = FunctionTree("f")
    for i in range(20):
        ft.insert(f"v{i}")
    for victim in ("v1", "v2", "v5", "v0"):
        ft.delete(victim)
        ft.check_invariants()
    assert len(ft) == 16


def test_height_logarithmic_after_inserts():
    ft = FunctionTree("f")
    for i in range(1000):
        ft.insert(f"v{i}")
    ft.check_invariants()
    assert ft.height == math.floor(math.log2(1000)) + 1  # complete tree


def test_edges_match_parents():
    ft = FunctionTree("f")
    for i in range(50):
        ft.insert(f"v{i}")
    for parent, child in ft.edges():
        assert ft.parent_of(child) == parent


def test_serialization_roundtrip():
    ft = FunctionTree("fid")
    for i in range(33):
        ft.insert(f"v{i}")
    ft.delete("v7")
    d = ft.to_dict()
    ft2 = FunctionTree.from_dict(d)
    ft2.check_invariants()
    assert ft2.vm_ids() == ft.vm_ids()
    assert ft2.height == ft.height


def test_rotations_preserve_membership():
    random.seed(7)
    ft = FunctionTree("f")
    alive = []
    for i in range(200):
        v = f"v{i}"
        ft.insert(v)
        alive.append(v)
    random.shuffle(alive)
    for v in alive[:150]:
        ft.delete(v)
        ft.check_invariants()
    remaining = set(alive[150:])
    assert set(ft.vm_ids()) == remaining


# ----------------------------------------------------------------------
# Seeded churn properties (no hypothesis required): invariants I1-I4 and
# the on_reparent contract survive arbitrary insert/delete interleavings.
# ----------------------------------------------------------------------
def _parent_map(ft: FunctionTree) -> dict:
    return {
        n.vm_id: (n.parent.vm_id if n.parent is not None else None)
        for n in ft.bfs()
    }


def _churn_ops(rng: random.Random, n_ops: int, p_insert: float = 0.55):
    """Yield ('insert', vm) / ('delete', vm) ops over a live set."""
    live: list[str] = []
    counter = 0
    for _ in range(n_ops):
        if not live or rng.random() < p_insert:
            v = f"n{counter}"
            counter += 1
            live.append(v)
            yield ("insert", v)
        else:
            v = live.pop(rng.randrange(len(live)))
            yield ("delete", v)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 17])
def test_invariants_under_seeded_churn(seed):
    rng = random.Random(seed)
    ft = FunctionTree("f")
    live: set[str] = set()
    for op, v in _churn_ops(rng, 400):
        if op == "insert":
            ft.insert(v)
            live.add(v)
        else:
            ft.delete(v)
            live.discard(v)
        ft.check_invariants()  # I1 pointers, I2 heights, I3 balance, I4 unique
        if live:
            # AVL height bound: h <= 1.4405 log2(n+2)
            assert ft.height <= 1.4405 * math.log2(len(live) + 2) + 1
    assert set(ft.vm_ids()) == live


@pytest.mark.parametrize("seed", [5, 11, 23])
def test_on_reparent_covers_every_parent_change(seed):
    """Every node whose parent changed across a delete gets notified.

    This is the contract the provisioning layer depends on: a missed
    notification would leave a worker streaming from a stale parent.
    Rotations may additionally notify a node whose parent is transiently
    moved and then restored, so notified ⊇ changed (not ==) is the
    guaranteed relation on the *final* state; each individual callback is
    checked to be accurate at the moment it fires.
    """
    rng = random.Random(seed)
    ft = FunctionTree("f")
    for op, v in _churn_ops(rng, 300):
        if op == "insert":
            ft.insert(v)
            continue
        before = _parent_map(ft)
        notified: set[str] = set()

        def cb(node, old_parent, new_parent):
            # accuracy at fire time: the pointer really is the new parent
            assert node.parent is new_parent
            assert old_parent is not new_parent or old_parent is None
            notified.add(node.vm_id)

        ft.on_reparent.append(cb)
        ft.delete(v)
        ft.on_reparent.remove(cb)
        after = _parent_map(ft)
        changed = {u for u in after if before.get(u, "__absent__") != after[u]}
        assert changed <= notified, (v, changed - notified)
        assert v not in notified  # the deleted node itself is gone, not moved
        ft.check_invariants()


def test_on_reparent_silent_during_pure_inserts():
    """BFS-slot insertion into a complete tree never rotates or reparents."""
    ft = FunctionTree("f")
    fired: list = []
    ft.on_reparent.append(lambda node, old, new: fired.append(node.vm_id))
    for i in range(128):
        ft.insert(f"v{i}")
    assert fired == []


def test_delete_last_bfs_leaf_no_reparent():
    ft = FunctionTree("f")
    for v in "abcde":
        ft.insert(v)
    fired: list = []
    ft.on_reparent.append(lambda node, old, new: fired.append(node.vm_id))
    ft.delete("e")  # deepest-last leaf: plain unlink, nothing moves
    assert fired == []
    ft.check_invariants()


# ----------------------------------------------------------------------
# hypothesis: the AVL height invariant survives any insert/delete sequence
# ----------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 40)), max_size=120))
    def test_invariants_under_random_ops(ops):
        ft = FunctionTree("f")
        live: list[str] = []
        counter = 0
        for is_insert, idx in ops:
            if is_insert or not live:
                v = f"n{counter}"
                counter += 1
                ft.insert(v)
                live.append(v)
            else:
                v = live.pop(idx % len(live))
                ft.delete(v)
            ft.check_invariants()
        assert sorted(ft.vm_ids()) == sorted(live)
        if live:
            # AVL height bound: h <= 1.4405 log2(n+2)
            assert ft.height <= 1.4405 * math.log2(len(live) + 2) + 1

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 300))
    def test_bfs_first_slot_keeps_completeness(n):
        ft = FunctionTree("f")
        for i in range(n):
            ft.insert(f"v{i}")
        assert ft.height == math.floor(math.log2(n)) + 1
