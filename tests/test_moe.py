"""MoE routing invariants (property + example based) + behavioural checks.

The hypothesis-driven variant runs only when ``hypothesis`` is installed;
a deterministic sweep over representative shapes always runs.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import route_topk

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False


def _check_routing_invariants(t, e, k, seed):
    k = min(k, e)
    logits = jax.random.normal(jax.random.key(seed), (t, e))
    capacity = max(int(t * k / e * 1.25), k)
    slot, gate, eids, aux = route_topk(logits, k, capacity)
    slot = np.asarray(slot)
    gate = np.asarray(gate)
    eids = np.asarray(eids)
    # gates: renormalized over top-k, in [0, 1], sum to 1
    np.testing.assert_allclose(gate.sum(-1), 1.0, atol=1e-5)
    assert (gate >= 0).all()
    # a token never picks the same expert twice
    for row in eids:
        assert len(set(row.tolist())) == k
    # capacity respected: kept slots unique and within range
    kept = slot[slot < e * capacity]
    assert len(set(kept.tolist())) == len(kept)  # no slot collisions
    per_expert = {}
    for s in kept:
        per_expert[s // capacity] = per_expert.get(s // capacity, 0) + 1
    assert all(v <= capacity for v in per_expert.values())
    assert np.isfinite(float(aux))


def test_routing_invariants_examples():
    rng = random.Random(0)
    cases = [(2, 4, 1), (64, 16, 4), (7, 4, 4), (33, 8, 2)]
    cases += [
        (rng.randint(2, 64), rng.choice([4, 8, 16]), rng.randint(1, 4))
        for _ in range(8)
    ]
    for t, e, k in cases:
        _check_routing_invariants(t, e, k, seed=rng.randint(0, 1000))


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        t=st.integers(2, 64),
        e=st.sampled_from([4, 8, 16]),
        k=st.integers(1, 4),
        seed=st.integers(0, 1000),
    )
    def test_routing_invariants(t, e, k, seed):
        _check_routing_invariants(t, e, k, seed)


def test_first_come_first_served_order():
    """Earlier tokens win capacity (paper-faithful dropping semantics)."""
    t, e, k, cap = 8, 2, 1, 2
    logits = jnp.stack([jnp.full((e,), 0.0).at[0].set(5.0)] * t)  # all pick e0
    slot, gate, eids, _ = route_topk(logits, k, cap)
    slot = np.asarray(slot)
    assert (slot[:2, 0] < e * cap).all()  # first two fit
    assert (slot[2:, 0] >= e * cap).all()  # rest dropped


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux ≈ 1 (Switch normalization)."""
    t, e = 512, 8
    logits = jnp.zeros((t, e)) + jax.random.normal(jax.random.key(0), (t, e)) * 1e-6
    _, _, _, aux = route_topk(logits, 2, capacity=512)
    assert 0.8 < float(aux) < 1.25


def test_moe_layer_residual_passthrough_for_dropped_tokens():
    """Dropped tokens produce zero MoE output (residual carries them)."""
    from repro.configs import MoEConfig, ModelConfig
    from repro.models.moe import apply_moe, init_moe

    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=32,
        moe=MoEConfig(n_experts=2, top_k=1, d_expert=32, capacity_factor=0.01),
    )
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, 16), jnp.float32)
    y, aux = apply_moe(p, x, cfg)
    # capacity 1: at most 2 tokens routed; most rows of y are exactly zero
    nonzero_rows = int((jnp.abs(y[0]).sum(-1) > 0).sum())
    assert nonzero_rows <= 2
