"""Training: loss goes down, microbatch equivalence, failure/restart."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ModelConfig
from repro.data.synthetic import make_batch
from repro.optim.adamw import AdamWConfig, init_opt_state, lr_at
from repro.train.loop import SimulatedFailure, run_train
from repro.train.step import init_train_state, make_train_step

TINY = ModelConfig(
    name="tiny",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    attn_impl="full",
    remat="none",
)


def test_loss_decreases():
    res = run_train(TINY, steps=30, seq_len=64, batch=4, log_every=1,
                    opt=AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=30))
    first = res.losses[1]
    last = res.losses[30]
    assert last < first - 0.5, (first, last)


def test_microbatch_equivalence():
    """n_micro=1 vs n_micro=4 produce (nearly) the same update."""
    _, step1 = make_train_step(TINY, None, n_micro=1)
    _, step4 = make_train_step(TINY, None, n_micro=4)
    params, opt = init_train_state(TINY, jax.random.key(0))
    batch = make_batch(TINY, 64, 8, kind="train")
    p1, _, m1 = jax.jit(step1)(params, opt, batch)
    params, opt = init_train_state(TINY, jax.random.key(0))
    p4, _, m4 = jax.jit(step4)(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2
        )


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Restart after an injected failure reproduces the uninterrupted run."""
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    kw = dict(steps=20, seq_len=32, batch=4, ckpt_every=10, log_every=1, opt=opt)
    ref = run_train(TINY, ckpt_dir=str(tmp_path / "ref"), **kw)

    with pytest.raises(SimulatedFailure):
        run_train(TINY, ckpt_dir=str(tmp_path / "ft"), fail_at_step=13, **kw)
    res = run_train(TINY, ckpt_dir=str(tmp_path / "ft"), **kw)
    assert res.resumed_from == 10
    assert res.losses[20] == pytest.approx(ref.losses[20], abs=1e-4)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-2)


def test_grad_compression_error_feedback():
    from repro.optim.compress import compress_error_feedback

    key = jax.random.key(0)
    g = jax.random.normal(key, (1024,))
    resid = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    # over steps, error feedback recovers the true cumulative gradient
    for _ in range(20):
        sent, resid = compress_error_feedback(g, resid)
        total_sent = total_sent + sent
    rel = float(jnp.linalg.norm(total_sent - 20 * g) / jnp.linalg.norm(20 * g))
    assert rel < 0.02
