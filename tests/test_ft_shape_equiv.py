"""Shape preservation: frontier/index FunctionTree ≡ BFS-scanning reference.

The O(log n) slot discovery (open-slot frontier + open-depth descent for
insert, height descent for the delete filler) must produce *bit-identical*
tree shapes to the original O(n) BFS scans — the paper's semantics are
"first BFS node with <2 children" and "last BFS node", and the golden
traces in ``tests/test_scale.py`` depend on the shapes matching exactly.

:class:`BFSReferenceTree` overrides only the two discovery methods with the
seed's full scans; everything else (attachment, splice, rotations, retrace)
is shared.  Driving both trees through identical mixed insert/delete
sequences and comparing ``to_dict()`` snapshots after every op therefore
isolates exactly the discovery logic this PR replaced.

Runs seeded (≥1000 mixed ops, no third-party deps); a hypothesis variant
adds adversarial sequences when the package is installed.
"""
import random

import pytest

from repro.core import FunctionTree

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False


class BFSReferenceTree(FunctionTree):
    """FunctionTree whose slot discovery is the original full BFS scan."""

    def _take_open_slot(self):
        for n in self.bfs():
            if n.child_count() < 2:
                return n
        raise AssertionError("unreachable: a finite binary tree has open slots")

    def _last_bfs_node(self):
        last = None
        for n in self.bfs():
            last = n
        assert last is not None
        return last


def _drive(ops, *, check_every: int = 1):
    """Apply one op sequence to both trees, comparing snapshots as we go."""
    fast, ref = FunctionTree("f"), BFSReferenceTree("f")
    for k, (op, v) in enumerate(ops):
        if op == "insert":
            fast.insert(v)
            ref.insert(v)
        else:
            fast.delete(v)
            ref.delete(v)
        if k % check_every == 0:
            assert fast.to_dict() == ref.to_dict(), (k, op, v)
            fast.check_invariants()
            ref.check_invariants()
    assert fast.to_dict() == ref.to_dict()
    fast.check_invariants()
    ref.check_invariants()
    return fast, ref


def _mixed_ops(rng: random.Random, n_ops: int, p_insert: float = 0.55):
    live: list[str] = []
    counter = 0
    out = []
    for _ in range(n_ops):
        if not live or rng.random() < p_insert:
            v = f"n{counter}"
            counter += 1
            live.append(v)
            out.append(("insert", v))
        else:
            v = live.pop(rng.randrange(len(live)))
            out.append(("delete", v))
    return out


@pytest.mark.parametrize("seed", [0, 1, 2, 7, 13])
def test_shape_identical_under_mixed_churn(seed):
    """≥1000 mixed ops per seed: byte-identical to_dict() after every op."""
    rng = random.Random(seed)
    _drive(_mixed_ops(rng, 1000))


def test_shape_identical_delete_heavy():
    """Grow to 300, then tear down in random order, checking every step."""
    rng = random.Random(99)
    ops = [("insert", f"n{i}") for i in range(300)]
    live = [f"n{i}" for i in range(300)]
    rng.shuffle(live)
    ops += [("delete", v) for v in live]
    _drive(ops)


def test_shape_identical_interleaved_rebuild():
    """Empty the tree repeatedly: the frontier fast path re-arms correctly."""
    ops = []
    for round_ in range(5):
        names = [f"r{round_}_{i}" for i in range(40)]
        ops += [("insert", v) for v in names]
        ops += [("delete", v) for v in names[::-1]]
    _drive(ops)


def test_insert_after_churn_picks_bfs_first_slot():
    """After deep churn the index descent still matches a fresh BFS scan."""
    rng = random.Random(5)
    fast, ref = _drive(_mixed_ops(rng, 600, p_insert=0.6))
    for i in range(50):
        v = f"extra{i}"
        fast.insert(v)
        ref.insert(v)
        assert fast.to_dict() == ref.to_dict()


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 60)), max_size=150))
    def test_shape_identical_hypothesis(raw_ops):
        live: list[str] = []
        counter = 0
        ops = []
        for is_insert, idx in raw_ops:
            if is_insert or not live:
                v = f"n{counter}"
                counter += 1
                live.append(v)
                ops.append(("insert", v))
            else:
                v = live.pop(idx % len(live))
                ops.append(("delete", v))
        _drive(ops)
