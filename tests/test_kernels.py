"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize(
    "b,h,t,hd,window",
    [
        (2, 4, 256, 64, None),
        (1, 2, 512, 64, None),
        (2, 2, 256, 128, None),
        (1, 4, 256, 64, 64),
        (1, 1, 128, 32, 32),
    ],
)
def test_flash_attention_sweep(b, h, t, hd, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(b * 100 + t), 3)
    q = _rand(k1, (b, h, t, hd), dtype)
    k = _rand(k2, (b, h, t, hd), dtype)
    v = _rand(k3, (b, h, t, hd), dtype)
    scale = hd**-0.5
    out = ops.flash_attention(q, k, v, scale=scale, window=window, interpret=True)
    exp = ref.flash_attention_ref(
        q.reshape(b * h, t, hd), k.reshape(b * h, t, hd), v.reshape(b * h, t, hd),
        scale=scale, window=window,
    ).reshape(b, h, t, hd)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("s,valid_upto", [(512, 511), (1024, 700), (2048, 1)])
def test_decode_attention_sweep(s, valid_upto, dtype):
    b, h, hd = 2, 4, 64
    k1, k2, k3 = jax.random.split(jax.random.key(s), 3)
    q = _rand(k1, (b, h, 1, hd), dtype)
    k = _rand(k2, (b, h, s, hd), dtype)
    v = _rand(k3, (b, h, s, hd), dtype)
    valid = (jnp.arange(s) <= valid_upto).astype(jnp.int32)
    out = ops.decode_attention(q, k, v, valid, scale=hd**-0.5, interpret=True)
    exp = ref.decode_attention_ref(
        q.reshape(b * h, 1, hd), k.reshape(b * h, s, hd), v.reshape(b * h, s, hd),
        jnp.broadcast_to(valid[None], (b * h, s)), scale=hd**-0.5,
    ).reshape(b, h, 1, hd)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize(
    "t,h,p,g,n,chunk",
    [
        (256, 4, 64, 1, 32, 64),
        (128, 2, 32, 2, 16, 32),
        (512, 4, 64, 1, 64, 128),
    ],
)
def test_ssd_scan_sweep(t, h, p, g, n, chunk, dtype):
    b = 2
    keys = jax.random.split(jax.random.key(t + h), 5)
    x = _rand(keys[0], (b, t, h, p), dtype)
    dt = jax.nn.softplus(_rand(keys[1], (b, t, h), jnp.float32)) * 0.1
    a = -jnp.exp(jax.random.normal(keys[2], (h,)))
    bm = _rand(keys[3], (b, t, g, n), dtype)
    cm = _rand(keys[4], (b, t, g, n), dtype)
    out = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    rep = h // g
    xr = x.transpose(0, 2, 1, 3).reshape(b * h, t, p)
    dtr = dt.transpose(0, 2, 1).reshape(b * h, t, 1)
    ar = jnp.broadcast_to(a[None], (b, h)).reshape(b * h, 1)
    bmr = jnp.repeat(bm, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, t, n)
    cmr = jnp.repeat(cm, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, t, n)
    exp = (
        ref.ssd_scan_ref(xr, dtr, ar, bmr, cmr)
        .reshape(b, h, t, p)
        .transpose(0, 2, 1, 3)
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-3, rtol=3e-2,
    )


def test_ssd_kernel_matches_model_chunked_path():
    """The pure-jnp model SSD (mamba2.ssd_chunked) agrees with the kernel."""
    from repro.models.mamba2 import ssd_chunked

    b, t, h, p, g, n = 1, 128, 2, 32, 1, 16
    keys = jax.random.split(jax.random.key(0), 5)
    x = _rand(keys[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(keys[1], (b, t, h), jnp.float32)) * 0.1
    a = -jnp.exp(jax.random.normal(keys[2], (h,)))
    bm = _rand(keys[3], (b, t, g, n), jnp.float32)
    cm = _rand(keys[4], (b, t, g, n), jnp.float32)
    y_model, _ = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    y_kernel = ops.ssd_scan(x, dt, a, bm, cm, chunk=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_model, np.float32), np.asarray(y_kernel, np.float32),
        atol=1e-3, rtol=1e-3,
    )
