"""Model zoo: per-arch smoke tests + cross-path consistency."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.data.synthetic import batch_specs, make_batch
from repro.models import model_for

SEQ, BATCH = 32, 2


@pytest.fixture(scope="module")
def states():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke(arch)
            m = model_for(cfg)
            cache[arch] = (cfg, m, m.init(jax.random.key(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(states, arch):
    cfg, m, params = states(arch)
    batch = make_batch(cfg, SEQ, BATCH, kind="train")
    loss, metrics = m.loss(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    assert 2.0 < float(loss) < 12.0  # ~ln(vocab) at init
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode_shapes(states, arch):
    cfg, m, params = states(arch)
    pb = make_batch(cfg, SEQ, BATCH, kind="prefill")
    logits, cache = m.prefill(params, pb)
    assert logits.shape[0] == BATCH and logits.shape[-1] == cfg.vocab_size
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    db = make_batch(cfg, SEQ, BATCH, kind="decode")
    dl, c2 = m.decode_step(params, db, m.init_cache(BATCH, SEQ))
    assert dl.shape == (BATCH, 1, cfg.vocab_size)
    assert jnp.isfinite(dl.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_match_make_batch(states, arch):
    cfg, _, _ = states(arch)
    for kind in ("train", "prefill", "decode"):
        real = make_batch(cfg, SEQ, BATCH, kind=kind)
        spec = batch_specs(cfg, SEQ, BATCH, kind=kind)
        assert set(real) == set(spec)
        for k in real:
            assert real[k].shape == spec[k].shape, (arch, kind, k)
            assert real[k].dtype == spec[k].dtype, (arch, kind, k)


@pytest.mark.parametrize(
    "arch", ["deepseek_7b", "gemma3_1b", "mamba2_130m", "whisper_medium",
             "llava_next_mistral_7b"]
)
def test_decode_matches_teacher_forcing(states, arch):
    cfg, m, params = states(arch)
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=16.0))
        m = model_for(cfg)
    T = 24  # TOTAL sequence length (for VLMs: patches + text)
    n_text = T - (cfg.vlm.n_patches if cfg.vlm is not None else 0)
    full = make_batch(cfg, T + 1, BATCH, kind="prefill", seed=3)
    logits_full, _ = m.prefill(params, full)
    pre = {k: (v[:, :n_text] if k == "tokens" else v) for k, v in full.items()}
    _, cache = m.prefill(params, pre, cache_len=T + 1)
    db = {"tokens": full["tokens"][:, n_text : n_text + 1],
          "pos": jnp.asarray(T, jnp.int32)}
    dl, _ = m.decode_step(params, db, cache)
    a = np.asarray(logits_full[:, -1], np.float32)
    c = np.asarray(dl[:, 0], np.float32)
    err = np.max(np.abs(a - c)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 0.06, f"{arch}: decode/teacher mismatch {err}"


def test_chunked_attention_equals_full():
    cfg_full = replace(get_smoke("deepseek_7b"), attn_impl="full")
    cfg_chunk = replace(cfg_full, attn_impl="chunked", attn_chunk=8)
    m1, m2 = model_for(cfg_full), model_for(cfg_chunk)
    params = m1.init(jax.random.key(0))
    b = make_batch(cfg_full, 30, 2, kind="train")  # 30 % 8 != 0: padding path
    l1, _ = m1.loss(params, b)
    l2, _ = m2.loss(params, b)
    assert abs(float(l1) - float(l2)) < 5e-3


def test_sliding_window_masks_distant_tokens():
    """gemma3 local layers must not attend past the window."""
    cfg = get_smoke("gemma3_1b")
    m = model_for(cfg)
    params = m.init(jax.random.key(0))
    b1 = make_batch(cfg, 40, 1, kind="prefill", seed=1)
    l1, _ = m.prefill(params, b1)
    # perturb a token far outside every local window but inside global range
    toks = np.asarray(b1["tokens"]).copy()
    toks[0, 1] ^= 1
    l2, _ = m.prefill(params, {"tokens": jnp.asarray(toks)})
    # last-position logits still differ (global layers see token 1)
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))


def test_int8_kv_cache_close_to_bf16():
    cfg = get_smoke("deepseek_7b")
    m16 = model_for(cfg)
    m8 = model_for(replace(cfg, kv_cache_dtype="int8"))
    params = m16.init(jax.random.key(0))
    T = 16
    pb = make_batch(cfg, T, 2, kind="prefill", seed=5)
    _, c16 = m16.prefill(params, pb, cache_len=T + 1)
    _, c8 = m8.prefill(params, pb, cache_len=T + 1)
    db = {"tokens": pb["tokens"][:, :1], "pos": jnp.asarray(T, jnp.int32)}
    l16, _ = m16.decode_step(params, db, c16)
    l8, _ = m8.decode_step(params, db, c8)
    a, b = np.asarray(l16, np.float32), np.asarray(l8, np.float32)
    assert np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9) < 0.05


def test_param_count_analytic_matches_actual():
    for arch in ("deepseek_7b", "granite_moe_1b", "mamba2_130m"):
        cfg = get_smoke(arch)
        m = model_for(cfg)
        params = m.init(jax.random.key(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)


def test_window_tile_skip_matches_full():
    """Sliding-window tile skipping (gemma3 §Perf D) is exact."""
    import jax

    from repro.models.attention import (
        attend_chunked,
        attend_full,
        causal_window_mask,
    )

    key = jax.random.key(0)
    for (t, chunk, window) in [(256, 64, 48), (300, 64, 130), (256, 32, 32)]:
        k1, k2, k3 = jax.random.split(key, 3)
        b, h, hd = 2, 3, 32
        q = jax.random.normal(k1, (b, h, t, hd), jnp.float32)
        k = jax.random.normal(k2, (b, h, t, hd), jnp.float32)
        v = jax.random.normal(k3, (b, h, t, hd), jnp.float32)
        pos = jnp.arange(t)
        out = attend_chunked(q, k, v, pos, pos, window, hd**-0.5, chunk=chunk)
        exp = attend_full(q, k, v, causal_window_mask(pos, pos, window), hd**-0.5)
        assert float(jnp.max(jnp.abs(out - exp))) < 1e-5, (t, chunk, window)
