"""Golden determinism for the workload traces and the arrival jitter.

The multi-tenant replay's bit-determinism rests on these generators: if the
LCG jitter or a ramp shape drifts, every downstream golden test silently
re-baselines.  So the four trace generators are pinned by SHA-256 checksums
over their full (9-decimal-rounded) value streams, plus spot samples at the
paper's named inflection points, and ``arrivals_for_second`` is pinned by an
exact 24-second sample sequence.
"""
import hashlib

from repro.sim import (
    constant_trace,
    diurnal_trace,
    iot_trace,
    synthetic_gaming_trace,
)
from repro.sim.traces import arrival_offsets, arrivals_for_second


def _digest(trace: list[float]) -> str:
    return hashlib.sha256(",".join(f"{v:.9f}" for v in trace).encode()).hexdigest()


# ----------------------------------------------------------------------
# Golden checksums (full streams)
# ----------------------------------------------------------------------
def test_iot_trace_checksum():
    tr = iot_trace()
    assert len(tr) == 55 * 60
    assert _digest(tr) == (
        "324f033fa6fb8e89800673bca4ef1fe4db015e01c5397894e385e93d7563c1c6"
    )


def test_gaming_trace_checksum():
    tr = synthetic_gaming_trace()
    assert len(tr) == 30 * 60
    assert _digest(tr) == (
        "17874d5eeb5f842629267b5cca70e5b0633ea1b001a048299a7cec38b6649c3a"
    )


def test_constant_trace_checksum():
    tr = constant_trace()
    assert len(tr) == 10 * 60
    assert _digest(tr) == (
        "fd7c99143e07746f3eb4f90c77db8e1b43ae4e98a96b04b55786ef6d0da73135"
    )


def test_diurnal_trace_checksum():
    tr = diurnal_trace()
    assert len(tr) == 30 * 60
    assert _digest(tr) == (
        "4985b33e578e14078ecd3d27f189ee6823939c262ce2fb3eca86d2367dae8e75"
    )


# ----------------------------------------------------------------------
# Ramp shapes at the paper's named inflection points
# ----------------------------------------------------------------------
def test_iot_trace_shape():
    tr = iot_trace()
    m = 60
    assert tr[0] == 10.0 and tr[9 * m] == 10.0  # quiet until burst 1
    assert tr[570] == 180.0  # mid-ramp of the 9->10 min rise
    assert abs(tr[10 * m] - 359.913219) < 1e-6  # 300-400 RPS plateau start
    assert abs(tr[20 * m] - 369.432855) < 1e-6  # mid-plateau sinusoid
    assert tr[28 * m] == 350.0 and tr[29 * m + 30] == 10.0  # decay done
    assert tr[40 * m + 30] == 55.0  # burst 2 step to 100...
    assert tr[42 * m] == 250.0 and tr[43 * m] == 400.0  # ...then jump to 400
    assert tr[-1] == 400.0


def test_gaming_trace_shape():
    tr = synthetic_gaming_trace()
    m = 60
    assert tr[0] == 1.0
    assert tr[11 * m] == 100.0 and tr[12 * m] == 100.0  # sharp burst 1
    assert tr[13 * m + 30] == 50.5  # halfway down the decay ramp
    assert tr[15 * m] == 1.0  # reclaim window between bursts
    assert tr[21 * m] == 125.0 and tr[23 * m] == 125.0  # larger burst 2
    assert tr[24 * m + 30] == 63.0 and tr[29 * m] == 1.0


def test_diurnal_trace_shape():
    tr = diurnal_trace()  # base 4, peak 64, period 20 min
    assert tr[0] == 4.0  # sin(0) clipped day-start
    assert tr[300] == 64.0  # quarter-period: peak of the day half-cycle
    assert abs(tr[600] - 4.0) < 1e-12  # sin(pi) rounding: day/night boundary
    assert tr[900] == 4.0  # clipped night half-cycle
    assert tr[1500] == 64.0  # next day's peak
    assert min(tr) == 4.0 and max(tr) == 64.0


def test_trace_scale_is_linear():
    for gen in (iot_trace, synthetic_gaming_trace, constant_trace, diurnal_trace):
        base = gen()
        doubled = gen(scale=2.0)
        assert doubled == [2 * v for v in base]


# ----------------------------------------------------------------------
# Arrival jitter (the LCG every replay's determinism hangs on)
# ----------------------------------------------------------------------
def test_arrivals_pinned_sequence():
    assert [arrivals_for_second(33.7, t, seed=5) for t in range(24)] == [
        34, 34, 33, 34, 34, 33, 34, 34, 33, 34, 34, 33,
        34, 34, 33, 34, 34, 33, 34, 34, 33, 34, 34, 33,
    ]
    assert [arrivals_for_second(10 / 3, t, seed=0) for t in range(24)] == [
        4, 3, 4, 3, 3, 4, 3, 3, 4, 3, 3, 4,
        3, 3, 4, 3, 3, 4, 3, 3, 4, 3, 3, 4,
    ]


def test_arrivals_mean_tracks_rps():
    """Jittered rounding is unbiased: the long-run mean approaches the RPS."""
    for rps in (0.3, 7.5, 33.7):
        n = 5000
        total = sum(arrivals_for_second(rps, t, seed=1) for t in range(n))
        assert abs(total / n - rps) < 0.05 * max(1.0, rps)


def test_arrivals_integer_floor_and_seed_sensitivity():
    assert all(
        arrivals_for_second(5.0, t) == 5 for t in range(50)
    )  # integral rps: no jitter
    seq_a = [arrivals_for_second(2.5, t, seed=0) for t in range(64)]
    seq_b = [arrivals_for_second(2.5, t, seed=1) for t in range(64)]
    assert seq_a != seq_b  # seeds genuinely decorrelate tenants


# ----------------------------------------------------------------------
# Sub-second arrival offsets (the request-serving layer's dispatch stamps)
# ----------------------------------------------------------------------
def test_arrival_offsets_pinned():
    assert [round(x, 6) for x in arrival_offsets(5, 7, seed=3)] == [
        0.214918, 0.261858, 0.308798, 0.355737, 0.402677,
    ]
    grid = []
    for t in range(40):
        for n in (0, 1, 3, 8):
            grid.extend(arrival_offsets(n, t, seed=t % 5))
    assert (
        hashlib.sha256(repr(grid).encode()).hexdigest()
        == "33adbd99a7e6dccb9565c57e69df3a21c5832a2d6fb75eaa49cc3cbba5226d34"
    )


def test_arrival_offsets_shape():
    for t in (0, 13, 999):
        for n in (0, 1, 7, 100):
            offs = arrival_offsets(n, t, seed=t)
            assert len(offs) == n
            assert offs == sorted(offs)  # keeps the FIFO queue ordered
            assert all(0.0 <= x < 1.0 for x in offs)
    # seeds and ticks genuinely decorrelate
    assert arrival_offsets(6, 3, seed=1) != arrival_offsets(6, 3, seed=2)
    assert arrival_offsets(6, 3, seed=1) != arrival_offsets(6, 4, seed=1)
