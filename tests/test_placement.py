"""Shared-pool placement (ISSUE 5): goldens, co-location, reclaim policies.

The acceptance bar for the shared-pool refactor:

  * exclusive-lease mode reproduces the PRE-refactor TickStats streams
    bit-identically (hashes below captured on commit ``fef2a8c``, before
    the shared-pool refactor);
  * a single tenant under shared placement degenerates to exclusive
    leasing bit-identically (every warm VM already hosts the function, so
    ``pick_vm_for`` always falls back to a fresh reservation);
  * under shared placement overlapping tenants genuinely co-locate (one VM
    in several FunctionTrees, §3.1), memory admission holds every tick,
    and the pool spends fewer VM-hours than exclusive leasing;
  * mid-wave scheduler failover stays bit-identical in every mode —
    including with the predictive reclaim policy, whose learned histograms
    ride the snapshot;
  * legacy (pre-memory, pre-policy) failover snapshots still restore.
"""
import hashlib
import json

import pytest

from repro.sim import (
    MultiTenantConfig,
    MultiTenantReplay,
    ReplayConfig,
    TenantConfig,
    TraceReplay,
    constant_trace,
    diurnal_trace,
    iot_trace,
    run_multi_tenant,
    synthetic_gaming_trace,
)

# Captured on the pre-refactor commit (exclusive leasing was the only mode):
# 3 tenants (gaming/diurnal/steady) x 250 VMs x 4 min, faasnet, reclaim 120 s.
GOLDEN_EXCLUSIVE_3T = (
    "dfa29f6c603ea308f7675d91fbbb1b0687b14c9461c12c55288170041cc53e3a"
)


def _three_tenant_cfg(placement: str, **kw) -> MultiTenantConfig:
    dur = 4 * 60
    gaming = synthetic_gaming_trace()[10 * 60 : 10 * 60 + dur]
    return MultiTenantConfig(
        tenants=[
            TenantConfig("gaming", gaming, seed=1),
            TenantConfig(
                "diurnal", diurnal_trace(duration_s=dur, phase_s=300), seed=2
            ),
            TenantConfig("steady", constant_trace(duration_s=dur), seed=3),
        ],
        system="faasnet",
        vm_pool_size=250,
        idle_reclaim_s=120.0,
        placement=placement,
        check_partition=True,
        **kw,
    )


def _stream_hash(res) -> str:
    lines = []
    for fid in sorted(res.timelines):
        for ts in res.timelines[fid]:
            lines.append(f"{fid} {ts!r}")
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()


# ----------------------------------------------------------------------
# Goldens: the refactor must not move a single pre-refactor TickStats
# ----------------------------------------------------------------------
def test_exclusive_mode_matches_pre_refactor_golden():
    res = run_multi_tenant(_three_tenant_cfg("exclusive"))
    assert _stream_hash(res) == GOLDEN_EXCLUSIVE_3T


def test_exclusive_golden_survives_failover():
    res = run_multi_tenant(_three_tenant_cfg("exclusive", failover_at=90))
    assert res.failovers == 1
    assert _stream_hash(res) == GOLDEN_EXCLUSIVE_3T


def test_single_tenant_shared_equals_exclusive_bit_identically():
    """1 tenant, uniform mem: shared placement IS exclusive leasing.

    (The pre-refactor single-tenant goldens themselves are pinned in
    ``tests/test_registry.py::test_golden_tickstats_streams_unchanged``,
    which runs ReplayConfig's default — exclusive — path; this equality
    extends that golden coverage to the shared path.)
    """
    trace = iot_trace(scale=1 / 3)[: 8 * 60]
    runs = {}
    for placement in ("shared", "exclusive"):
        r = TraceReplay(
            ReplayConfig(
                system="faasnet",
                idle_reclaim_s=120,
                vm_pool_size=120,
                placement=placement,
            )
        )
        r.run(trace)
        runs[placement] = r
    assert runs["shared"].timeline == runs["exclusive"].timeline
    assert runs["shared"].prov_latencies == runs["exclusive"].prov_latencies
    assert runs["shared"].responses == runs["exclusive"].responses


# ----------------------------------------------------------------------
# Shared placement: genuine cross-tenant co-location under memory admission
# ----------------------------------------------------------------------
def test_shared_pool_co_locates_tenants():
    replay = MultiTenantReplay(_three_tenant_cfg("shared"))
    res = replay.run()
    stats = res.manager_stats
    # more placements than reservations == co-location happened
    assert stats["inserts"] > stats["reservations"]
    multi = [vm for vm in replay.mgr.vms.values() if len(vm.functions) > 1]
    assert multi, "no VM ever hosted two tenants' functions"
    replay.check_shared_invariants()  # memory + occupancy still consistent
    # the engine saw cross-tree flows on shared hosts
    assert res.peak_nic_utilization > 0.0
    assert res.cold_starts == sum(t.provisioned for t in res.per_tenant.values())


def test_shared_uses_fewer_vm_hours_than_exclusive():
    shared = run_multi_tenant(_three_tenant_cfg("shared"))
    exclusive = run_multi_tenant(_three_tenant_cfg("exclusive"))
    assert 0.0 < shared.vm_seconds < exclusive.vm_seconds
    assert shared.vm_hours() == shared.vm_seconds / 3600.0


def test_shared_two_run_deterministic_and_failover_parity():
    a = run_multi_tenant(_three_tenant_cfg("shared"))
    b = run_multi_tenant(_three_tenant_cfg("shared"))
    assert a.timelines == b.timelines
    assert a.vm_seconds == b.vm_seconds
    fo = run_multi_tenant(_three_tenant_cfg("shared", failover_at=90))
    assert fo.failovers == 1
    assert fo.timelines == a.timelines
    assert fo.per_tenant == a.per_tenant
    assert fo.manager_stats == a.manager_stats
    assert fo.vm_seconds == a.vm_seconds


def test_histogram_reclaim_failover_parity_and_savings():
    """The learned keep-alive histograms ride the failover snapshot."""
    hist = run_multi_tenant(_three_tenant_cfg("shared", reclaim="histogram"))
    hist_fo = run_multi_tenant(
        _three_tenant_cfg("shared", reclaim="histogram", failover_at=90)
    )
    assert hist_fo.failovers == 1
    assert hist_fo.timelines == hist.timelines
    assert hist_fo.manager_stats == hist.manager_stats
    fixed = run_multi_tenant(_three_tenant_cfg("shared", reclaim="fixed"))
    # the predictive policy reclaims short-reuse instances sooner than the
    # fixed 120 s lifespan on this mix
    assert hist.vm_seconds < fixed.vm_seconds


def test_policy_instance_in_config_is_copied_per_run():
    """A ReclaimPolicy instance in the config must not leak learned state
    between runs of the same config (two-run bit-identity)."""
    from repro.sim import HistogramReclaim

    pol = HistogramReclaim(120.0, min_observations=1)
    cfg_a = _three_tenant_cfg("shared")
    cfg_a.reclaim = pol
    a = run_multi_tenant(cfg_a)
    assert pol.counts == {}  # the caller's instance was never mutated
    cfg_b = _three_tenant_cfg("shared")
    cfg_b.reclaim = pol
    b = run_multi_tenant(cfg_b)
    assert a.timelines == b.timelines
    assert a.manager_stats == b.manager_stats


def test_cold_start_dispatch_is_not_a_reuse_gap():
    """A fresh instance's first-ever request is provisioning slack, not a
    reuse gap: a never-reused function must teach the histogram nothing and
    keep the default keep-alive (the dead-tenant fallback)."""
    cfg = MultiTenantConfig(
        tenants=[TenantConfig("once", [5.0] + [0.0] * 120, seed=7)],
        system="faasnet",
        vm_pool_size=20,
        idle_reclaim_s=600.0,
        placement="shared",
        reclaim="histogram",
    )
    replay = MultiTenantReplay(cfg)
    res = replay.run()
    assert res.cold_starts > 0  # instances really provisioned + served once
    assert replay.mgr.reclaim.counts == {}  # no bogus ~0 s observations


def test_binpack_vs_ft_aware_placement_modes_both_run():
    ft = run_multi_tenant(_three_tenant_cfg("shared", ft_aware_placement=True))
    bp = run_multi_tenant(_three_tenant_cfg("shared", ft_aware_placement=False))
    for res in (ft, bp):
        assert sum(t.provisioned for t in res.per_tenant.values()) > 0
    # §5: FT-aware spreads inbound streams away from seeding-heavy hosts —
    # it must not lose to binpack on the worst tenant's provisioning tail
    worst_ft = max(t.p99_prov_s for t in ft.per_tenant.values())
    worst_bp = max(t.p99_prov_s for t in bp.per_tenant.values())
    assert worst_ft <= worst_bp


def test_tenant_mem_must_fit_a_vm():
    cfg = _three_tenant_cfg("shared")
    cfg.tenants[0].mem_mb = 8192  # bigger than the 4096 MB VM
    with pytest.raises(ValueError, match="needs 8192 MB"):
        MultiTenantReplay(cfg)
    with pytest.raises(ValueError, match="unknown placement"):
        MultiTenantReplay(_three_tenant_cfg("timeshare"))


def test_legacy_snapshot_restores_into_shared_replay():
    """Pre-memory / pre-policy snapshots restore with the CONFIG's policy
    and memory requirements re-applied — a legacy restore must not disable
    memory admission or drop the requested reclaim policy."""
    from repro.sim import HistogramReclaim

    def legacy_blob(replay):
        blob = json.loads(json.dumps(replay.snapshot()["manager"], sort_keys=True))
        # strip everything the pre-refactor format did not have
        del blob["function_mem"]
        del blob["default_function_mem_mb"]
        del blob["reclaim"]
        for v in blob["vms"].values():
            del v["func_mem_mb"]
            del v["func_last_active"]
        return blob

    replay = MultiTenantReplay(_three_tenant_cfg("shared"))
    # place one instance so the restore has occupancy to re-charge
    vm = replay.mgr.pick_vm_for("gaming", 0.0)
    replay.mgr.insert("gaming", vm.vm_id, 0.0)
    replay.restore_snapshot(legacy_blob(replay))  # bare-manager envelope
    assert replay.mgr.reclaim.snapshot() == {
        "policy": "fixed_ttl",
        "ttl_s": 120.0,
    }
    # memory admission survives: requirements come from the config and the
    # placed instance is re-charged at today's requirement
    assert replay.mgr.mem_need("gaming") == 512
    assert replay.mgr.vms[vm.vm_id].func_mem_mb == {"gaming": 512}
    assert replay.mgr.vms[vm.vm_id].mem_used_mb == 512
    # ... and the config's *policy* survives a legacy restore too
    hist_replay = MultiTenantReplay(
        _three_tenant_cfg("shared", reclaim="histogram")
    )
    hist_replay.restore_snapshot(legacy_blob(hist_replay))
    assert isinstance(hist_replay.mgr.reclaim, HistogramReclaim)
