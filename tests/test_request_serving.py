"""Request-level serving (ISSUE 6): goldens, herd control, invariants.

The acceptance bar for the serving tentpole:

  * with ``serving=None`` (the default) every pre-serving code path is
    bit-identical — the PR 3-5 exclusive-mode golden still holds and the
    legacy deficit scheduler is reproduced tick-for-tick by the
    ``herd_control=False`` admission rule while nothing has activated;
  * the per-tenant response-latency stream under serving is pinned by a
    SHA-256 golden, and a mid-wave scheduler failover — whose snapshot now
    carries the parked FIFO queues and the in-flight wave locks — replays
    bit-identically against an uninterrupted run;
  * a 10k-request single-tick burst on a cold function triggers exactly
    ONE provisioning wave, sized far below one-VM-per-request, and no
    request is dropped;
  * request conservation (``requests == dispatched + queued`` and
    ``dispatched == completed + in_flight``) holds every tick across
    shared/exclusive placement x fixed/histogram reclaim, and dispatch is
    FIFO: arrival and start times are non-decreasing in dispatch order;
  * sub-tick dispatch yields non-degenerate (non-tick-quantized) response
    latency distributions — the ``p99_response_s == 7.0`` artifact class
    is gone.

Property tests run twice: seeded ``random.Random`` sweeps always run;
hypothesis variants run only when ``hypothesis`` is installed (same
optional-dep gating as ``tests/test_function_tree.py``).
"""
import hashlib
import json
import random

import pytest

from repro.core import FTManager
from repro.sim import (
    MultiTenantConfig,
    MultiTenantReplay,
    ReplayConfig,
    ServingConfig,
    TenantConfig,
    TraceReplay,
    constant_trace,
    diurnal_trace,
    run_multi_tenant,
    serving_config,
    synthetic_gaming_trace,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False

from test_placement import GOLDEN_EXCLUSIVE_3T, _stream_hash, _three_tenant_cfg

# 3 serving tenants (gaming burst / diurnal / steady) x 250 VMs x 3 min,
# shared placement: SHA-256 of the per-tenant (completion_t, latency)
# response stream.  Captured when the serving layer landed, with
# contention-aware wave sizing (effective service time feedback).
GOLDEN_SERVING_3T = (
    "70edf3161d5c485b89f81d5a5bf0d8a239e48c5495c131703f9782c77f5f5ea3"
)


def _serving_3t_cfg(**kw) -> MultiTenantConfig:
    dur = 3 * 60
    gaming = synthetic_gaming_trace()[10 * 60 : 10 * 60 + dur]
    kw.setdefault("serving", ServingConfig())
    return MultiTenantConfig(
        tenants=[
            TenantConfig("gaming", gaming, seed=1),
            TenantConfig(
                "diurnal", diurnal_trace(duration_s=dur, phase_s=300), seed=2
            ),
            TenantConfig("steady", constant_trace(duration_s=dur), seed=3),
        ],
        system="faasnet",
        vm_pool_size=250,
        idle_reclaim_s=120.0,
        placement="shared",
        check_partition=True,
        **kw,
    )


def _response_hash(replay: MultiTenantReplay) -> str:
    lines = []
    for ts in replay.tenants:
        for t, lat in ts.responses:
            lines.append(f"{ts.cfg.function_id} {t!r} {lat!r}")
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()


def _burst_cfg(
    herd: bool, burst: int = 10_000, ticks: int = 60, **tenant_kw
) -> MultiTenantConfig:
    trace = [0.0] * 5 + [float(burst)] + [0.0] * (ticks - 6)
    tenant_kw.setdefault("max_reserve_per_tick", 100_000)
    return MultiTenantConfig(
        tenants=[TenantConfig("cold", trace, seed=3, **tenant_kw)],
        vm_pool_size=2000,
        serving=ServingConfig(herd_control=herd),
        check_partition=True,
    )


# ----------------------------------------------------------------------
# ServingConfig validation + defaults-off wiring
# ----------------------------------------------------------------------
def test_serving_knobs_default_off():
    assert MultiTenantConfig().serving is None
    assert ReplayConfig().serving is None


@pytest.mark.parametrize(
    "kw",
    [
        {"cpu_slots": 0},
        {"drain_budget_s": 0.0},
        {"drain_budget_s": -1.0},
        {"rate_window_s": 0},
    ],
)
def test_serving_config_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        ServingConfig(**kw)


def test_serving_config_factory_attaches_knobs():
    cfg = serving_config(minutes=1, herd_control=False, cpu_slots=4)
    assert cfg.serving is not None
    assert cfg.serving.cpu_slots == 4
    assert not cfg.serving.herd_control
    assert len(cfg.tenants) == 8


def test_defaults_off_reproduces_pre_serving_golden():
    """The PR 3-5 exclusive golden is untouched with serving knobs off.

    This is the differential half of the tentpole: the dispatch hot loop
    was rewritten, but a config that never mentions serving must produce
    the exact pre-serving TickStats stream.
    """
    res = run_multi_tenant(_three_tenant_cfg("exclusive"))
    assert _stream_hash(res) == GOLDEN_EXCLUSIVE_3T


# ----------------------------------------------------------------------
# Golden + mid-wave failover (satellite 2)
# ----------------------------------------------------------------------
def test_serving_golden_3t():
    rep = MultiTenantReplay(_serving_3t_cfg())
    rep.run()
    assert _response_hash(rep) == GOLDEN_SERVING_3T


def test_serving_failover_mid_wave_bit_identical():
    """Failover at t=62 (gaming burst in flight) must not move a response.

    A Spy subclass captures the wire blob to prove the snapshot carries
    REAL serving state — a non-empty parked queue and an in-flight wave
    lock — and ``_failover`` clears the live queues before restoring, so a
    bit-identical stream means the queues genuinely crossed the wire.
    """
    captured = {}

    class Spy(MultiTenantReplay):
        def snapshot(self):
            blob = super().snapshot()
            captured.update(json.loads(json.dumps(blob)))
            return blob

    rep = Spy(_serving_3t_cfg(failover_at=62))
    res = rep.run()
    assert res.failovers == 1
    assert captured["version"] == 3
    locks = captured["manager"]["wave_locks"]
    assert locks.get("gaming", 0) > 0  # wave in flight at snapshot time
    parked = captured["serving"]["queues"]
    assert len(parked["gaming"]) > 0  # the herd is parked in the queue
    assert _response_hash(rep) == GOLDEN_SERVING_3T


def test_serving_snapshot_restores_into_fresh_replay():
    """A serving snapshot restores queues + locks into a new replay object."""
    rep = MultiTenantReplay(_serving_3t_cfg())
    for t in range(63):
        now = float(t)
        rep.sim.run(until=now)
        for ts in rep.tenants:
            rep._step_tenant(ts, t, now)
    blob = json.loads(json.dumps(rep.snapshot(), sort_keys=True))
    fresh = MultiTenantReplay(_serving_3t_cfg())
    fresh.restore_snapshot(blob)
    assert fresh.mgr.wave_locks == rep.mgr.wave_locks
    assert fresh.mgr.wave_locks.get("gaming", 0) > 0
    for a, b in zip(fresh.tenants, rep.tenants):
        assert list(a.queue) == list(b.queue)
    assert any(fresh.tenants[0].queue)  # gaming's parked herd came across


# ----------------------------------------------------------------------
# FTManager wave-lock bookkeeping (control-plane unit tests)
# ----------------------------------------------------------------------
def test_wave_lock_open_land_cycle():
    mgr = FTManager()
    assert not mgr.wave_active("f")
    assert not mgr.wave_landed("f")  # landing without a wave is a no-op
    mgr.wave_open("f", 3)
    assert mgr.wave_active("f")
    assert mgr.stats["waves"] == 1
    assert not mgr.wave_landed("f")
    assert not mgr.wave_landed("f")
    assert mgr.wave_landed("f")  # third landing closes the wave
    assert not mgr.wave_active("f")


def test_wave_lock_rejects_double_open_and_bad_size():
    mgr = FTManager()
    mgr.wave_open("f", 2)
    with pytest.raises(RuntimeError):
        mgr.wave_open("f", 1)
    with pytest.raises(ValueError):
        mgr.wave_open("g", 0)


def test_wave_locks_ride_manager_snapshot():
    mgr = FTManager()
    mgr.wave_open("a", 5)
    mgr.wave_open("b", 1)
    mgr.wave_landed("a")
    blob = json.loads(json.dumps(mgr.snapshot(), sort_keys=True))
    restored = FTManager.restore(blob)
    assert restored.wave_locks == {"a": 4, "b": 1}
    assert restored.wave_active("a") and restored.wave_active("b")


# ----------------------------------------------------------------------
# Cold-start herd regression (satellite 3)
# ----------------------------------------------------------------------
def test_cold_burst_triggers_exactly_one_wave():
    res = run_multi_tenant(_burst_cfg(herd=True))
    tr = res.per_tenant["cold"]
    tc = _burst_cfg(herd=True).tenants[0]
    target = int(tc.vm_target_factor * 10_000 * tc.function_duration_s) + 1
    assert res.manager_stats["waves"] == 1  # the herd bought ONE wave
    assert tr.provisioned <= target
    # far below one-VM-per-request: the wave is backlog/drain-budget sized
    assert tr.provisioned < 10_000 // 4
    assert tr.requests == 10_000
    assert tr.completed == tr.requests  # no request dropped
    assert tr.wasted_provisions == 0


def test_naive_admission_overprovisions_versus_herd():
    herd = run_multi_tenant(_burst_cfg(herd=True)).per_tenant["cold"]
    naive = run_multi_tenant(_burst_cfg(herd=False)).per_tenant["cold"]
    assert naive.completed == naive.requests == 10_000
    assert herd.provisioned < naive.provisioned
    assert herd.wasted_provisions <= naive.wasted_provisions


def test_naive_admission_reproduces_legacy_deficit_rule():
    """herd_control=False == today's scheduler, tick for tick.

    While nothing has activated the two dispatch loops cannot diverge
    (there are no instances to serve from), so the reservation stream must
    be IDENTICAL to the legacy path — per tick, not just in total.  Run a
    trace short enough that no container lands inside it.
    """
    for max_res in (64, 100_000):
        trace = [0.0, 0.0, 0.0, 10_000.0, 0.0, 0.0]

        def cfg(serving):
            return MultiTenantConfig(
                tenants=[
                    TenantConfig(
                        "cold", list(trace), seed=3,
                        max_reserve_per_tick=max_res,
                    )
                ],
                vm_pool_size=2000,
                serving=serving,
                check_partition=True,
            )

        legacy = run_multi_tenant(cfg(None))
        naive = run_multi_tenant(cfg(ServingConfig(herd_control=False)))
        leg_tl = legacy.timelines["cold"]
        nav_tl = naive.timelines["cold"]
        assert [t.provisioning_vms for t in leg_tl] == [
            t.provisioning_vms for t in nav_tl
        ]
        assert [t.active_vms for t in leg_tl] == [t.active_vms for t in nav_tl]
        assert (
            legacy.per_tenant["cold"].provisioned
            == naive.per_tenant["cold"].provisioned
        )


# ----------------------------------------------------------------------
# Tick-quantization regression (satellite 4)
# ----------------------------------------------------------------------
def _bursty_trace() -> list[float]:
    trace = [5.0] * 90
    for t in range(30, 45):
        trace[t] = 120.0
    return trace


def test_legacy_dispatch_is_tick_quantized():
    """The artifact being fixed: every legacy latency is an exact integer."""
    replay = TraceReplay(ReplayConfig(vm_pool_size=300))
    replay.run(_bursty_trace())
    lats = [lat for _, lat in replay.responses]
    assert len(lats) > 1000
    assert all(lat == int(lat) for lat in lats)


def test_serving_dispatch_is_not_tick_quantized():
    replay = TraceReplay(
        ReplayConfig(vm_pool_size=300, serving=ServingConfig())
    )
    replay.run(_bursty_trace())
    lats = [lat for _, lat in replay.responses]
    assert len(lats) > 1000
    fractional = [lat for lat in lats if lat != int(lat)]
    # non-degenerate: the distribution is continuous, not a handful of
    # integer spikes
    assert len(fractional) > len(lats) * 0.3
    assert len({round(lat % 1.0, 6) for lat in fractional}) > 50
    lats.sort()
    p99 = lats[int(0.99 * (len(lats) - 1))]
    assert p99 != int(p99)


# ----------------------------------------------------------------------
# Conservation + FIFO monotonicity properties (satellite 1)
# ----------------------------------------------------------------------
def _random_trace(rng: random.Random, n: int) -> list[float]:
    trace = []
    level = rng.uniform(0.0, 10.0)
    for _ in range(n):
        if rng.random() < 0.15:  # occasional burst / lull
            level = rng.choice([0.0, rng.uniform(20.0, 60.0), rng.uniform(0, 5)])
        trace.append(level)
    return trace


def _assert_serving_invariants(rep: MultiTenantReplay) -> None:
    for ts in rep.tenants:
        # conservation at end of run (every tick already asserted via
        # check_partition -> _check_conservation)
        assert ts.requests == len(ts.responses) + len(ts.queue)
        assert ts.completed_done + len(ts.in_flight) == len(ts.responses)
        # FIFO: dispatch order == arrival order (wait times need NOT be
        # monotone — a later arrival can hit an idle instance)
        arrivals = [a for a, _ in ts.dispatch_log]
        assert arrivals == sorted(arrivals)
        for a, s in ts.dispatch_log:
            assert s >= a  # no request starts before it arrives
        # Start times are non-decreasing within each tick's dispatch batch
        # (TickStats.completed gives the batch sizes).  Across ticks an
        # instance that landed mid-tick may legitimately back-fill an
        # earlier start than the previous tick's last dispatch — the
        # scheduler could not have known about capacity that had not
        # activated yet.
        i = 0
        for tick in ts.timeline:
            batch = [s for _, s in ts.dispatch_log[i : i + tick.completed]]
            assert batch == sorted(batch), f"tick {tick.t}: {batch}"
            i += tick.completed
        assert i == len(ts.dispatch_log)


@pytest.mark.parametrize("placement", ["shared", "exclusive"])
@pytest.mark.parametrize("reclaim", ["fixed", "histogram"])
@pytest.mark.parametrize("seed", [0, 7])
def test_conservation_and_fifo_seeded(placement, reclaim, seed):
    rng = random.Random(seed)
    cfg = MultiTenantConfig(
        tenants=[
            TenantConfig("a", _random_trace(rng, 50), seed=seed),
            TenantConfig("b", _random_trace(rng, 50), seed=seed + 1),
        ],
        vm_pool_size=120,
        idle_reclaim_s=20.0,
        placement=placement,
        reclaim=reclaim,
        serving=ServingConfig(
            cpu_slots=rng.choice([1, 2, 4]),
            herd_control=rng.random() < 0.5,
            drain_budget_s=rng.uniform(5.0, 20.0),
            rate_window_s=rng.randrange(5, 40),
        ),
        check_partition=True,  # conservation asserted every tick
    )
    rep = MultiTenantReplay(cfg)
    rep.run()
    _assert_serving_invariants(rep)
    assert sum(ts.requests for ts in rep.tenants) > 0


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        rates=st.lists(
            st.floats(min_value=0.0, max_value=40.0, allow_nan=False),
            min_size=5,
            max_size=30,
        ),
        placement=st.sampled_from(["shared", "exclusive"]),
        reclaim=st.sampled_from(["fixed", "histogram"]),
        herd=st.booleans(),
        slots=st.integers(min_value=1, max_value=4),
        budget=st.floats(min_value=1.0, max_value=25.0),
        seed=st.integers(min_value=0, max_value=1000),
    )
    def test_conservation_and_fifo_hypothesis(
        rates, placement, reclaim, herd, slots, budget, seed
    ):
        cfg = MultiTenantConfig(
            tenants=[TenantConfig("f", list(rates), seed=seed)],
            vm_pool_size=100,
            idle_reclaim_s=10.0,
            placement=placement,
            reclaim=reclaim,
            serving=ServingConfig(
                cpu_slots=slots, herd_control=herd, drain_budget_s=budget
            ),
            check_partition=True,
        )
        rep = MultiTenantReplay(cfg)
        rep.run()
        _assert_serving_invariants(rep)


# ----------------------------------------------------------------------
# CPU-slot contention
# ----------------------------------------------------------------------
def test_cpu_slots_stretch_colocated_requests():
    """k busy co-residents stretch service by (k+1)/cpu_slots, floored at 1.

    Two tenants pinned onto the SAME VM by memory-constrained shared
    placement: with cpu_slots=1, overlapping requests must take longer
    than the nominal duration; with ample slots they never stretch.
    """

    def cfg(slots):
        return MultiTenantConfig(
            tenants=[
                TenantConfig("a", [4.0] * 30, seed=1, mem_mb=512),
                TenantConfig("b", [4.0] * 30, seed=2, mem_mb=512),
            ],
            vm_pool_size=1,  # one VM: everyone co-locates on it
            serving=ServingConfig(cpu_slots=slots, herd_control=False),
            check_partition=True,
        )

    stretched = MultiTenantReplay(cfg(1))
    stretched.run()
    roomy = MultiTenantReplay(cfg(16))
    roomy.run()
    dur = 2.0
    service = lambda rep: [  # noqa: E731
        f - s
        for ts in rep.tenants
        for (_, s), (f, _) in zip(ts.dispatch_log, ts.responses)
    ]
    tight = service(stretched)
    wide = service(roomy)
    assert any(t > dur for t in tight)  # contention stretched something
    assert all(abs(w - dur) < 1e-9 for w in wide)  # no stretch with slots
    assert max(tight) <= dur * 8  # bounded by co-residency, not unbounded


# ----------------------------------------------------------------------
# giga_replay_config: serving + block provisioning on one shared sim
# ----------------------------------------------------------------------
def test_giga_replay_config_shape():
    from repro.sim.scale import giga_replay_config

    cfg = giga_replay_config(0)
    assert cfg.serving is not None
    assert cfg.images is not None
    assert set(cfg.images) == {t.function_id for t in cfg.tenants}
    assert cfg.wave.engine == "vector"
    assert cfg.wave.record_trace is False
    assert cfg.vm_pool_size == 100_000
    # the failover must actually fire inside the (short) replay window
    assert cfg.failover_at is not None and cfg.failover_at < cfg.duration_s()


def test_serving_plus_blocks_replay_deterministic():
    """The giga-replay combination — sub-tick serving, block-level
    provisioning and the vector engine on ONE shared FlowSim — at test
    scale: the failover fires, cold starts flow through the block path,
    and a re-run is bit-identical."""
    from repro.sim.scale import giga_replay_config

    def run():
        cfg = giga_replay_config(0, n_tenants=4, minutes=2, scale=0.25)
        cfg.vm_pool_size = 300
        return run_multi_tenant(cfg)

    a, b = run(), run()
    assert a.failovers == 1
    assert a.cold_starts > 0
    total_req = sum(t.requests for t in a.per_tenant.values())
    total_done = sum(t.completed for t in a.per_tenant.values())
    assert total_req > 0 and total_done > 0
    assert a.timelines == b.timelines
    assert a.cold_starts == b.cold_starts
    key = lambda r: {  # noqa: E731
        k: (t.requests, t.completed, t.p99_response_s, t.wasted_provisions)
        for k, t in r.per_tenant.items()
    }
    assert key(a) == key(b)
