"""Single-tenant TraceReplay regression: systems ordering + determinism.

The replay path (arrivals -> FIFO serving -> scale-out -> provisioning over
the FlowSim -> reclaim) had no deterministic pinning before this test: a
short IoT-trace prefix covering the burst-1 ramp is replayed under faasnet,
baseline and on_demand, asserting faasnet's provisioning strictly beats the
baseline and that the full :class:`TickStats` stream is two-run identical.
"""
import statistics as st

from repro.sim import ReplayConfig, TraceReplay, iot_trace


def _prefix(minutes: int = 12) -> list[float]:
    # burst 1 starts at t=9 min; a 12-min prefix covers ramp + early plateau
    return iot_trace(scale=1 / 3)[: minutes * 60]


def _run(system: str) -> TraceReplay:
    r = TraceReplay(
        ReplayConfig(system=system, idle_reclaim_s=420, vm_pool_size=300)
    )
    r.run(_prefix())
    return r


def test_faasnet_beats_baseline_on_trace():
    f = _run("faasnet")
    b = _run("baseline")
    o = _run("on_demand")
    assert f.prov_latencies and b.prov_latencies and o.prov_latencies
    # provisioning makespan (first reservation -> last ready) strictly better
    assert f.prov_makespan_s() < b.prov_makespan_s()
    # and per-container latency much better (paper: 13.4x at the wave level)
    assert st.mean(f.prov_latencies) < 0.5 * st.mean(b.prov_latencies)
    assert max(f.prov_latencies) < max(b.prov_latencies)
    # the burst is actually absorbed: responses recover under faasnet
    burst_t = 9 * 60
    assert f.recovery_time(burst_t + 60, normal_s=3.5) < b.recovery_time(
        burst_t + 60, normal_s=3.5
    )


def test_trace_replay_two_run_deterministic():
    for system in ("faasnet", "baseline", "on_demand"):
        a = _run(system)
        b = _run(system)
        assert a.timeline == b.timeline, system  # full TickStats stream
        assert a.prov_latencies == b.prov_latencies, system
        assert a.responses == b.responses, system


def test_trace_replay_provisions_through_tree():
    """FaaSNet replays grow a real FunctionTree: height follows the wave."""
    f = _run("faasnet")
    heights = [ts.ft_height for ts in f.timeline]
    assert max(heights) >= 4  # ~100 RPS wave -> dozens of VMs -> height >= 4
    pre_burst = max(heights[: 8 * 60])  # 10/3 RPS floor -> a handful of VMs
    assert pre_burst < max(heights)  # the burst visibly grows the tree
    assert f.prov_makespan_s() > 0.0


def test_prov_makespan_empty_replay_is_zero():
    r = TraceReplay(ReplayConfig(system="faasnet", vm_pool_size=4))
    r.run([0.0] * 10)
    assert r.prov_latencies == []
    assert r.prov_makespan_s() == 0.0
