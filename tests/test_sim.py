"""Simulator: paper-calibrated behaviour, determinism, straggler handling."""
import statistics as st

from repro.sim import SYSTEMS, FlowSim, SimConfig, WaveConfig, provision_wave
from repro.core.topology import REGISTRY, DistributionPlan, Flow


def test_faasnet_scales_flat():
    """Paper Fig. 14: FaaSNet keeps near-identical latency from 8 to 128."""
    cfg = WaveConfig()
    l8 = st.mean(provision_wave("faasnet", 8, cfg).values())
    l128 = st.mean(provision_wave("faasnet", 128, cfg).values())
    assert l128 / l8 < 1.25


def test_system_ordering_at_128():
    """FaaSNet < DADI+P2P < on-demand < baseline ~ Kraken (paper §4.3)."""
    cfg = WaveConfig()
    mean = {s: st.mean(provision_wave(s, 128, cfg).values()) for s in SYSTEMS}
    assert mean["faasnet"] < mean["dadi_p2p"] < mean["on_demand"]
    assert mean["on_demand"] < mean["baseline"]
    assert mean["faasnet"] * 5 < mean["kraken"]


def test_headline_speedups():
    """Paper abstract: 13.4x vs baseline, 16.3x vs Kraken (±40% tolerance)."""
    cfg = WaveConfig()
    f = st.mean(provision_wave("faasnet", 128, cfg).values())
    b = st.mean(provision_wave("baseline", 128, cfg).values())
    k = max(provision_wave("kraken", 128, cfg).values())
    assert 8.0 < b / f < 19.0  # paper: 13.4x
    assert 9.0 < k / f < 23.0  # paper: 16.3x (wall-clock based)


def test_warm_roots_skip_registry():
    """With >=1 warm seed the registry is never touched (paper §3.4)."""
    cfg = WaveConfig()
    lat = provision_wave("faasnet", 64, cfg, warm_roots=1)
    assert max(lat.values()) < 12.0


def test_determinism():
    cfg = WaveConfig()
    a = provision_wave("kraken", 32, cfg)
    b = provision_wave("kraken", 32, cfg)
    assert a == b


def test_straggler_mitigation_improves_tail():
    cfg = WaveConfig()
    slow = {"vm1": 2e6}  # 2 MB/s egress: vm1 throttles its whole subtree
    base = provision_wave("faasnet", 64, cfg, slow_vms=dict(slow))
    mitigated = provision_wave(
        "faasnet", 64, cfg, slow_vms=dict(slow), straggler_mitigation=True
    )
    assert max(mitigated.values()) < max(base.values()) * 0.6


def test_flow_sim_conservation():
    """A single flow finishes in exactly bytes/rate seconds."""
    sim = FlowSim(SimConfig(per_stream_cap=10e6))
    done = {}
    sim.add_plan(
        DistributionPlan(flows=[Flow(REGISTRY, "a", "img", 100_000_000)],
                         streaming=False),
        on_node_done=lambda vm, t: done.setdefault(vm, t),
    )
    sim.run()
    assert abs(done["a"] - 10.0) < 1e-6


def test_nic_sharing():
    """Two flows into one dst split the inbound NIC."""
    sim = FlowSim(SimConfig())
    done = {}
    plan = DistributionPlan(
        flows=[Flow("s1", "dst", "a", 125_000_000), Flow("s2", "dst", "b", 125_000_000)],
        streaming=False,
    )
    sim.add_plan(plan, on_node_done=lambda vm, t: done.setdefault(vm, t))
    sim.run()
    # 250 MB over a 125 MB/s NIC shared by two flows => 2 s
    assert abs(sim.now - 2.0) < 1e-6


def test_run_until_preserves_progress():
    sim = FlowSim(SimConfig(per_stream_cap=10e6))
    sim.add_plan(
        DistributionPlan(flows=[Flow(REGISTRY, "a", "img", 100_000_000)],
                         streaming=False)
    )
    for t in range(1, 11):
        sim.run(until=float(t))
    assert sim.completion_times()["a"] == 10.0
