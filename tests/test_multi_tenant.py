"""Multi-tenant trace replay: determinism, failover parity, pool partition.

The acceptance bar for the replay subsystem (ISSUE 3):

  * two runs of the same config are bit-identical (full TickStats streams);
  * a run with a mid-wave scheduler failover — FTManager.snapshot() ->
    json round-trip -> FTManager.restore() — matches the uninterrupted
    run's TickStats stream *exactly*;
  * the pool invariant holds at every tick, checked inline by the replay:
    exclusive mode partitions the pool across free_pool + per-tenant trees;
    shared mode (the default since ISSUE 5) requires every instance's
    memory to fit its VM and occupancy to agree across manager and replay;
  * faasnet's total provisioning time beats the baseline's (ratio < 1.0).

The 8-tenant x 2000-VM soak (``multi_tenant_config``) is ``--runslow``
gated; the fast tests run the same code paths at 3 tenants x a few minutes.
"""
import pytest

from repro.sim import (
    MultiTenantConfig,
    MultiTenantReplay,
    TenantConfig,
    constant_trace,
    diurnal_trace,
    multi_tenant_config,
    run_multi_tenant,
    synthetic_gaming_trace,
)


def _small_cfg(
    *,
    system: str = "faasnet",
    failover_at=None,
    check_partition: bool = False,
    vm_pool_size: int = 250,
    minutes: int = 4,
) -> MultiTenantConfig:
    dur = minutes * 60
    # gaming burst moved into the window by trimming from t=10min
    gaming = synthetic_gaming_trace()[10 * 60 : 10 * 60 + dur]
    return MultiTenantConfig(
        tenants=[
            TenantConfig("gaming", gaming, seed=1),
            TenantConfig(
                "diurnal", diurnal_trace(duration_s=dur, phase_s=300), seed=2
            ),
            TenantConfig("steady", constant_trace(duration_s=dur), seed=3),
        ],
        system=system,
        vm_pool_size=vm_pool_size,
        idle_reclaim_s=120.0,
        failover_at=failover_at,
        check_partition=check_partition,
    )


def test_two_run_bit_deterministic():
    a = run_multi_tenant(_small_cfg())
    b = run_multi_tenant(_small_cfg())
    assert a.timelines == b.timelines  # full per-tenant TickStats streams
    assert a.per_tenant == b.per_tenant
    assert a.manager_stats == b.manager_stats
    assert a.peak_registry_egress == b.peak_registry_egress


def test_failover_matches_uninterrupted_run_exactly():
    """Mid-wave snapshot/json/restore must not perturb a single TickStats."""
    failed_over = run_multi_tenant(_small_cfg(failover_at=90))
    uninterrupted = run_multi_tenant(_small_cfg(failover_at=None))
    assert failed_over.failovers == 1 and uninterrupted.failovers == 0
    assert failed_over.timelines == uninterrupted.timelines
    assert failed_over.per_tenant == uninterrupted.per_tenant
    # snapshot carries the telemetry counters: accounting stays continuous
    assert failed_over.manager_stats == uninterrupted.manager_stats


def test_failover_really_replaces_the_manager():
    replay = MultiTenantReplay(_small_cfg(failover_at=60))
    original_mgr = replay.mgr
    res = replay.run()
    assert res.failovers == 1
    assert replay.mgr is not original_mgr  # restored object, not the original
    assert replay.mgr.stats["inserts"] > 0  # and it kept doing real work


def test_partition_invariant_holds_every_tick():
    """check_partition raises on any lost/duplicated VM reservation."""
    replay = MultiTenantReplay(
        _small_cfg(failover_at=90, check_partition=True)
    )
    replay.run()
    replay._check_partition()  # still a partition after the final tick


def test_tenants_contend_for_the_shared_pool():
    """A starved pool degrades every tenant; a roomy one serves the burst."""
    roomy = run_multi_tenant(_small_cfg(vm_pool_size=250))
    starved = run_multi_tenant(_small_cfg(vm_pool_size=24))
    assert starved.free_vms == 0  # pool fully committed under load
    for fid in roomy.per_tenant:
        s, r = starved.per_tenant[fid], roomy.per_tenant[fid]
        assert s.completed < r.completed  # every tenant lost throughput
        # a zero-completion tenant was starved outright; otherwise the tail
        # visibly degrades under contention
        assert s.completed == 0 or s.p99_response_s >= r.p99_response_s


def test_faasnet_beats_baseline_ratio_below_one():
    f = run_multi_tenant(_small_cfg(system="faasnet"))
    b = run_multi_tenant(_small_cfg(system="baseline"))
    assert f.total_prov_time_s > 0 and b.total_prov_time_s > 0
    ratio = f.total_prov_time_s / b.total_prov_time_s
    assert ratio < 1.0, ratio  # the acceptance criterion
    assert ratio < 0.6, ratio  # and comfortably so (paper: ~0.248)


def test_config_validation():
    with pytest.raises(ValueError, match="at least one tenant"):
        MultiTenantReplay(MultiTenantConfig(tenants=[]))
    with pytest.raises(ValueError, match="duplicate tenant"):
        MultiTenantReplay(
            MultiTenantConfig(
                tenants=[
                    TenantConfig("f", [1.0] * 10),
                    TenantConfig("f", [2.0] * 10),
                ]
            )
        )


def test_multi_tenant_config_shape():
    cfg = multi_tenant_config()
    assert len(cfg.tenants) == 8
    assert cfg.vm_pool_size == 2000
    kinds = {fid[:3] for fid in (t.function_id for t in cfg.tenants)}
    assert kinds == {"iot", "gam", "diu", "con"}  # all four trace shapes
    seeds = [t.seed for t in cfg.tenants]
    assert len(set(seeds)) == len(seeds)  # decorrelated arrival jitter
    assert cfg.failover_at is not None
    assert 0 < cfg.failover_at < cfg.duration_s()  # genuinely mid-wave


# ----------------------------------------------------------------------
# The 8-tenant / 2000-VM soak with mid-wave failover (--runslow)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_soak_8_tenants_2000_vms_with_failover():
    """ISSUE 5 soak: mixed traces, one genuinely shared pool, mem-checked.

    ``check_partition=True`` under shared placement asserts at every one of
    the 1500 ticks that every placed instance's memory fits its VM and that
    the occupancy sets agree across FTManager (trees + per-VM records) and
    the replay's instance/provisioning maps — a lost/double reservation or
    a memory-accounting drift anywhere in pick/insert/delete/release/
    failover raises immediately.  After the run the control plane is
    snapshot/restored once more and the restored occupancy must agree too.
    The failed-over run must match the uninterrupted one bit-for-bit at
    full scale.
    """
    replay = MultiTenantReplay(multi_tenant_config(check_partition=True))
    failed_over = replay.run()
    assert failed_over.failovers == 1
    assert len(failed_over.per_tenant) == 8
    for fid, tr in failed_over.per_tenant.items():
        assert tr.completed > 0, fid  # every tenant made real progress
        assert tr.provisioned > 0, fid
    # the waves really overlapped on the shared pool: peak footprints sum
    # past any single tenant's, and the registry saw concurrent egress
    assert sum(t.peak_vms for t in failed_over.per_tenant.values()) > 1000
    assert failed_over.peak_registry_egress > 0
    # cross-tenant co-location really happened (one VM, many trees — §3.1)
    assert any(len(vm.functions) > 1 for vm in replay.mgr.vms.values())
    # occupancy survives one more snapshot/restore round-trip exactly
    import json

    from repro.core import FTManager

    restored = FTManager.restore(
        json.loads(json.dumps(replay.mgr.snapshot(), sort_keys=True))
    )
    for vid, vm in replay.mgr.vms.items():
        r = restored.vms[vid]
        assert r.functions == vm.functions, vid
        assert r.func_mem_mb == vm.func_mem_mb, vid
        assert r.mem_used_mb == vm.mem_used_mb <= vm.mem_mb, vid
    uninterrupted = run_multi_tenant(
        multi_tenant_config(failover_at=None, check_partition=True)
    )
    assert failed_over.timelines == uninterrupted.timelines
    assert failed_over.per_tenant == uninterrupted.per_tenant
    assert failed_over.manager_stats == uninterrupted.manager_stats
