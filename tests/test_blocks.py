"""Block-level provisioning: image model, cache-aware plans, runnable milestone.

Covers the §3.1–§3.2 block/layer path end to end:

  * :class:`~repro.core.image.ImageSpec` geometry — block counts, boot
    working-set prefixes, Fig. 20 read amplification;
  * :class:`~repro.core.image.BlockCache` — max-merge prefixes, eviction,
    missing-bytes math the plan builders consume;
  * the block plan builders — resident blocks never travel, fully cached
    nodes still get their milestone via a zero-byte marker flow;
  * the ``on_node_runnable`` milestone — blocks ON, the three engines
    (incremental / vector / reference) stay equivalent: incremental ==
    vector bit-identical, reference within 1e-9;
  * the harnesses — ``block_wave`` warm-cache reuse, ``run_scale`` with
    images, multi-tenant replay with block provisioning + failover parity,
    and content-aware root election in the FTManager.

Blocks OFF (``image(s)=None``, the default) is pinned bit-identical to the
legacy scalar goldens by the existing suites — nothing here re-tests that.
"""
import pytest

from repro.core import (
    BlockCache,
    FTManager,
    FunctionTree,
    ImageSpec,
    LayerSpec,
    VMInfo,
    baseline_block_plan,
    disjoint_images,
    faasnet_block_plan,
    on_demand_block_plan,
    shared_base_images,
)
from repro.sim import (
    MultiTenantReplay,
    ScaleConfig,
    WaveConfig,
    block_wave,
    multi_tenant_config,
    provision_wave,
    run_scale,
)
from repro.sim.engine import FlowSim, SimConfig
from repro.sim.reference import ReferenceFlowSim
from repro.sim.vector_engine import VectorFlowSim

MB = 1 << 20
REL_TOL = 1e-9


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(1.0, abs(a), abs(b))


# ----------------------------------------------------------------------
# ImageSpec geometry
# ----------------------------------------------------------------------
def _img(block_size=MB, boot_fraction=0.15, sizes=(10 * MB, 5 * MB + 1)):
    layers = tuple(LayerSpec(f"L{i}", s) for i, s in enumerate(sizes))
    return ImageSpec("img", layers, block_size=block_size, boot_fraction=boot_fraction)


def test_image_block_geometry():
    img = _img()
    assert img.total_bytes() == 15 * MB + 1
    assert img.layer_blocks("L0") == 10
    assert img.layer_blocks("L1") == 6  # 5 MiB + 1 byte -> 6 blocks
    g = img.geometry("L1")
    assert g.raw_size == 5 * MB + 1
    assert img.prefix_bytes("L1", 6) == 5 * MB + 1  # tail block is short
    assert img.prefix_bytes("L1", 2) == 2 * MB
    assert img.prefix_bytes("L1", 0) == 0


def test_boot_working_set_is_front_to_back_prefix():
    img = _img(boot_fraction=0.5)  # budget ~7.5 MiB: all in L0
    bb = img.boot_blocks()
    assert bb["L0"] == 8  # ceil(7.5 MiB / 1 MiB) covering blocks
    assert bb["L1"] == 0
    assert img.boot_prefix_bytes("L0") == 8 * MB
    assert img.boot_prefix_bytes("L1") == 0


def test_read_amplification_grows_with_block_size():
    # Fig. 20: bigger blocks round the boot edge up further.
    sizes = (64 * MB, 64 * MB, 32 * MB)
    amps = []
    for bs in (128 * 1024, 512 * 1024, 2 * MB, 8 * MB):
        img = ImageSpec(
            "a", tuple(LayerSpec(f"L{i}", s) for i, s in enumerate(sizes)),
            block_size=bs, boot_fraction=0.17,
        )
        amps.append(img.boot_read_amplification())
    assert all(a >= 1.0 for a in amps)
    assert amps == sorted(amps), f"not monotone: {amps}"
    assert amps[-1] > amps[0]


def test_image_validation():
    with pytest.raises(ValueError):
        ImageSpec("e", ())
    with pytest.raises(ValueError):
        ImageSpec("e", (LayerSpec("a", 1),), block_size=0)
    with pytest.raises(ValueError):
        ImageSpec("e", (LayerSpec("a", 1),), boot_fraction=0.0)
    with pytest.raises(ValueError):
        ImageSpec("e", (LayerSpec("a", 1), LayerSpec("a", 2)))
    with pytest.raises(ValueError):
        LayerSpec("neg", -1)


def test_shared_base_images_share_digests():
    imgs = shared_base_images(6, 2, image_bytes=100 * MB)
    assert len(imgs) == 6
    # fn0 and fn2 share base0's layers; fn0 and fn1 share nothing but names
    assert [la.digest for la in imgs[0].layers[:-1]] == [
        la.digest for la in imgs[2].layers[:-1]
    ]
    assert set(la.digest for la in imgs[0].layers[:-1]).isdisjoint(
        la.digest for la in imgs[1].layers[:-1]
    )
    assert imgs[0].layers[-1].digest != imgs[2].layers[-1].digest
    dis = disjoint_images(4, image_bytes=100 * MB)
    all_digests = [la.digest for im in dis for la in im.layers]
    assert len(all_digests) == len(set(all_digests))


# ----------------------------------------------------------------------
# BlockCache
# ----------------------------------------------------------------------
def test_block_cache_max_merge_and_evict():
    img = _img()
    c = BlockCache()
    assert c.resident_blocks("vm0", "L0") == 0
    c.add_prefix("vm0", "L0", 4)
    c.add_prefix("vm0", "L0", 2)  # max-merge: never shrinks
    assert c.resident_blocks("vm0", "L0") == 4
    c.add_prefix("vm0", "L0", 0)  # no-op
    assert c.resident_blocks("vm0", "L0") == 4
    assert c.resident_bytes("vm0", img) == 4 * MB
    c.add_image("vm0", img)
    assert c.resident_bytes("vm0", img) == img.total_bytes()
    c.evict("vm0")
    assert c.resident_bytes("vm0", img) == 0


def test_missing_layer_bytes():
    img = _img(boot_fraction=0.5)  # boot: 8 blocks of L0, 0 of L1
    c = BlockCache()
    full, boot = c.missing_layer_bytes("vm0", img, "L0")
    assert (full, boot) == (10 * MB, 8 * MB)
    c.add_prefix("vm0", "L0", 3)
    full, boot = c.missing_layer_bytes("vm0", img, "L0")
    assert (full, boot) == (7 * MB, 5 * MB)
    c.add_prefix("vm0", "L0", 10)
    assert c.missing_layer_bytes("vm0", img, "L0") == (0, 0)
    assert c.missing_layer_bytes("vm0", img, "L1") == (5 * MB + 1, 0)


# ----------------------------------------------------------------------
# Plan builders
# ----------------------------------------------------------------------
def test_faasnet_block_plan_skips_resident_blocks():
    imgs = shared_base_images(2, 1, image_bytes=64 * MB)
    cache = BlockCache()
    cache.add_image("vm0", imgs[0])  # vm0 holds fn0 entirely (shares base w/ fn1)
    ft = FunctionTree("fn1")
    for vm in ("vm0", "vm1"):
        ft.insert(vm)
    plan = faasnet_block_plan(ft, image=imgs[1], cache=cache)
    by_dst = {}
    for fl in plan.flows:
        by_dst.setdefault(fl.dst, []).append(fl)
    # vm0 only needs fn1's private app layer; base layers never travel
    assert [f.piece for f in by_dst["vm0"]] == ["fn1:app"]
    # vm1 (cold) pulls every layer, chained under vm0
    assert len(by_dst["vm1"]) == len(imgs[1].layers)
    assert all(f.src == "vm0" for f in by_dst["vm1"])
    assert plan.streaming
    # runnable prefix never exceeds the flow's payload
    for fl in plan.flows:
        assert 0 <= fl.runnable_bytes <= fl.bytes


def test_fully_cached_node_gets_marker_flow():
    img = _img()
    cache = BlockCache()
    cache.add_image("vm0", img)
    plan = on_demand_block_plan(["vm0"], image=img, cache=cache)
    assert len(plan.flows) == 1
    assert plan.flows[0].bytes == 0
    assert plan.flows[0].piece == "img:cached"
    # milestones still fire: both runnable and done
    sim = FlowSim(SimConfig())
    seen = {}
    sim.add_plan(
        plan,
        on_node_done=lambda vm, t: seen.setdefault(("done", vm), t),
        on_node_runnable=lambda vm, t: seen.setdefault(("run", vm), t),
    )
    sim.run()
    assert ("done", "vm0") in seen and ("run", "vm0") in seen
    assert seen[("run", "vm0")] <= seen[("done", "vm0")]


def test_baseline_block_plan_is_all_or_nothing():
    img = _img()
    cache = BlockCache()
    cache.add_prefix("vm0", "L0", 5)  # partial: docker re-pulls the whole layer
    plan = baseline_block_plan(["vm0"], image=img, cache=cache)
    sizes = {f.piece: f.bytes for f in plan.flows}
    assert sizes["L0"] == 10 * MB
    # runnable == full arrival for docker pull
    assert all(f.runnable_bytes == f.bytes for f in plan.flows)
    assert not plan.streaming
    cache.add_prefix("vm0", "L0", 10)  # fully cached: skipped
    plan2 = baseline_block_plan(["vm0"], image=img, cache=cache)
    assert "L0" not in {f.piece for f in plan2.flows}


# ----------------------------------------------------------------------
# Engine differential: blocks ON, three engines agree
# ----------------------------------------------------------------------
def _run_engine(make, imgs, cache_warm):
    sim = make(SimConfig(record_trace=True))
    cache = BlockCache()
    if cache_warm:
        cache.add_image("seed", imgs[0])
    runnable, done = {}, {}
    for i, img in enumerate(imgs):
        ft = FunctionTree(img.name)
        for v in (f"f{i}a", f"f{i}b", f"f{i}c"):
            ft.insert(v)
        plan = faasnet_block_plan(ft, image=img, cache=cache)
        sim.add_plan(
            plan,
            t0=0.01 * i,
            on_node_done=lambda vm, t, i=i: done.__setitem__(
                (i, vm), max(done.get((i, vm), 0.0), t)
            ),
            on_node_runnable=lambda vm, t, i=i: runnable.setdefault((i, vm), t),
        )
    sim.run()
    return runnable, done, sim.now, getattr(sim, "events_processed", None)


@pytest.mark.parametrize("cache_warm", [False, True])
def test_blocks_on_engine_differential(cache_warm):
    imgs = shared_base_images(6, 2, image_bytes=48 * MB)
    inc = _run_engine(FlowSim, imgs, cache_warm)
    vec = _run_engine(VectorFlowSim, imgs, cache_warm)
    ref = _run_engine(ReferenceFlowSim, imgs, cache_warm)
    # incremental == vector: bit-identical milestones, clock and event count
    assert inc == vec
    # reference agrees within 1e-9 on every milestone
    for key in ("runnable", "done"):
        a = inc[0] if key == "runnable" else inc[1]
        b = ref[0] if key == "runnable" else ref[1]
        assert a.keys() == b.keys()
        for k in a:
            assert _close(a[k], b[k]), (key, k, a[k], b[k])
    assert _close(inc[2], ref[2])
    # runnable never trails full arrival
    for k, t in inc[0].items():
        assert t <= inc[1][k] + REL_TOL


def test_runnable_fires_before_done_on_cold_fetch():
    img = _img(boot_fraction=0.15, sizes=(64 * MB, 16 * MB))
    plan = on_demand_block_plan(["vm0"], image=img)
    sim = FlowSim(SimConfig())
    seen = {}
    sim.add_plan(
        plan,
        on_node_done=lambda vm, t: seen.__setitem__("done", max(seen.get("done", 0.0), t)),
        on_node_runnable=lambda vm, t: seen.setdefault("run", t),
    )
    sim.run()
    assert 0.0 < seen["run"] < seen["done"]


# ----------------------------------------------------------------------
# block_wave harness
# ----------------------------------------------------------------------
def test_block_wave_warm_cache_speeds_second_wave():
    imgs = shared_base_images(2, 1, image_bytes=128 * MB)
    cache = BlockCache()
    cold = block_wave("faasnet", 4, images=imgs[0], cache=cache)
    warm = block_wave("faasnet", 4, images=imgs[1], cache=cache)
    cold_done = max(v["done"] for v in cold.values())
    warm_done = max(v["done"] for v in warm.values())
    assert warm_done < cold_done  # base layers resident: only the app layer moves
    for v in list(cold.values()) + list(warm.values()):
        assert v["runnable"] <= v["done"]


def test_block_wave_engines_agree():
    img = shared_base_images(1, 1, image_bytes=64 * MB)[0]
    runs = {
        eng: block_wave("faasnet", 8, WaveConfig(engine=eng), images=img)
        for eng in ("incremental", "vector")
    }
    assert runs["incremental"] == runs["vector"]
    ref = block_wave("faasnet", 8, WaveConfig(engine="reference"), images=img)
    for vm, v in runs["incremental"].items():
        assert _close(v["runnable"], ref[vm]["runnable"])
        assert _close(v["done"], ref[vm]["done"])


def test_block_wave_systems_and_validation():
    img = _img(sizes=(32 * MB, 8 * MB))
    for system in ("faasnet", "on_demand", "baseline"):
        res = block_wave(system, 4, images=img)
        assert len(res) == 4
        for v in res.values():
            assert 0.0 < v["runnable"] <= v["done"]
    base = block_wave("baseline", 4, images=img)
    # docker pull: runnable == done (plus identical extract tail) per VM
    for v in base.values():
        assert _close(v["runnable"], v["done"])
    with pytest.raises(ValueError):
        block_wave("faasnet", 4)  # no image anywhere
    with pytest.raises(ValueError):
        block_wave("faasnet", 4, images=[img] * 3)  # wrong per-VM list length
    with pytest.raises(ValueError):
        block_wave("kraken", 2, images=img)  # not a block system


def test_provision_wave_delegates_to_block_path():
    img = _img(sizes=(32 * MB, 8 * MB))
    cfg = WaveConfig(image=img)
    lat = provision_wave("faasnet", 4, cfg)
    direct = block_wave("faasnet", 4, WaveConfig(), images=img)
    assert lat == {vm: v["runnable"] for vm, v in direct.items()}
    with pytest.raises(ValueError):
        provision_wave("faasnet", 4, WaveConfig(image=img), warm_roots=1)


# ----------------------------------------------------------------------
# run_scale with images
# ----------------------------------------------------------------------
def test_run_scale_blocks_runnable_before_done():
    imgs = shared_base_images(3, 1, image_bytes=64 * MB)
    cfg = ScaleConfig(
        n_vms=24, n_functions=3, containers_per_function=8, images=imgs
    )
    res = run_scale(cfg)
    assert 0.0 < res.runnable_makespan < res.makespan
    vec = run_scale(
        ScaleConfig(
            n_vms=24, n_functions=3, containers_per_function=8, images=imgs,
            wave=WaveConfig(engine="vector"),
        )
    )
    assert vec.runnable_makespan == res.runnable_makespan
    assert vec.makespan == res.makespan
    with pytest.raises(ValueError):
        run_scale(ScaleConfig(n_functions=3, images=imgs[:2]))


# ----------------------------------------------------------------------
# Content-aware root election
# ----------------------------------------------------------------------
def _mgr(n_vms=8, **kw):
    m = FTManager(**kw)
    for i in range(n_vms):
        m.add_free_vm(VMInfo(f"vm{i}"))
    return m


def test_content_root_election_prefers_warm_vm():
    imgs = shared_base_images(2, 1, image_bytes=64 * MB)
    cache = BlockCache()
    cache.add_image("vm3", imgs[0])  # vm3 holds fn0 (shares base with fn1)
    m = _mgr()
    m.set_content_affinity(
        lambda fid, vm: cache.resident_bytes(vm, imgs[int(fid[2:])])
    )
    root = m.pick_vm_for("fn1", now=0.0)
    assert root.vm_id == "vm3"
    assert m.stats["content_roots"] == 1
    assert "vm3" not in m.free_pool  # promoted out of the free pool
    m.insert("fn1", root.vm_id, 0.0)
    # tree exists now: affinity no longer applies to scale-out picks
    second = m.pick_vm_for("fn1", now=0.0)
    assert second.vm_id != "vm3"
    assert m.stats["content_roots"] == 1


def test_content_root_election_cold_falls_back():
    m = _mgr(4)
    m.set_content_affinity(lambda fid, vm: 0)
    v = m.pick_vm_for("fn0", now=0.0)
    assert v.vm_id == "vm0"  # plain FIFO reservation
    assert m.stats["content_roots"] == 0


# ----------------------------------------------------------------------
# Multi-tenant replay with block provisioning
# ----------------------------------------------------------------------
def _mt_cfg(images, **kw):
    cfg = multi_tenant_config(
        n_tenants=len(images), vm_pool_size=60, minutes=2, **kw
    )
    cfg.images = {
        t.function_id: img for t, img in zip(cfg.tenants, images)
    }
    return cfg


def test_multi_tenant_blocks_complete_and_deterministic():
    imgs = shared_base_images(3, 1, image_bytes=48 * MB)
    a = MultiTenantReplay(_mt_cfg(imgs, failover_at=None, check_partition=True)).run()
    b = MultiTenantReplay(_mt_cfg(imgs, failover_at=None, check_partition=True)).run()
    assert a.timelines == b.timelines
    assert all(t.provisioned > 0 for t in a.per_tenant.values())
    assert all(t.mean_prov_s > 0 for t in a.per_tenant.values())


def test_multi_tenant_blocks_failover_parity():
    imgs = shared_base_images(3, 1, image_bytes=48 * MB)
    broken = MultiTenantReplay(
        _mt_cfg(imgs, failover_at=45, check_partition=True)
    ).run()
    unbroken = MultiTenantReplay(
        _mt_cfg(imgs, failover_at=None, check_partition=True)
    ).run()
    assert broken.failovers == 1
    assert broken.timelines == unbroken.timelines


def test_multi_tenant_blocks_missing_tenant_rejected():
    imgs = shared_base_images(2, 1, image_bytes=48 * MB)
    cfg = _mt_cfg(imgs, failover_at=None)
    del cfg.images[cfg.tenants[0].function_id]
    with pytest.raises(ValueError):
        MultiTenantReplay(cfg)


def test_multi_tenant_reclaim_evicts_block_cache():
    imgs = shared_base_images(1, 1, image_bytes=48 * MB)
    cfg = _mt_cfg(imgs, failover_at=None)
    cfg.idle_reclaim_s = 20.0
    # idle tail so instances get reclaimed mid-run
    t = cfg.tenants[0]
    t.trace[:] = [4.0] * 30 + [0.0] * (len(t.trace) - 30)
    rep = MultiTenantReplay(cfg)
    res = rep.run()
    assert res.manager_stats["reclaims"] > 0
    img = cfg.images[t.function_id]
    for vm_id in rep.mgr.vms:
        if vm_id in rep.mgr.free_pool:
            assert rep.block_cache.resident_bytes(vm_id, img) == 0
