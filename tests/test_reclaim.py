"""ReclaimPolicy unit tests: fixed TTL, keep-alive histogram, wire format."""
import json

import pytest

from repro.core.reclaim import (
    FixedTTLReclaim,
    HistogramReclaim,
    resolve_reclaim_policy,
    restore_reclaim_policy,
)


def test_fixed_ttl_semantics():
    pol = FixedTTLReclaim(120.0)
    assert not pol.should_reclaim("f", 119.9, now=0.0)
    assert pol.should_reclaim("f", 120.0, now=0.0)
    pol.observe_gap("f", 5.0)  # fixed policy ignores observations
    assert not pol.should_reclaim("f", 119.9, now=0.0)


def test_resolve_shorthands():
    assert isinstance(resolve_reclaim_policy(None, default_ttl_s=60), FixedTTLReclaim)
    assert resolve_reclaim_policy("fixed", default_ttl_s=60).ttl_s == 60.0
    hist = resolve_reclaim_policy("histogram", default_ttl_s=60)
    assert isinstance(hist, HistogramReclaim)
    assert hist.default_ttl_s == 60.0
    pol = FixedTTLReclaim(5.0)
    assert resolve_reclaim_policy(pol, default_ttl_s=60) is pol
    with pytest.raises(ValueError, match="unknown reclaim policy"):
        resolve_reclaim_policy("lru", default_ttl_s=60)


def test_histogram_cold_start_uses_default_ttl():
    pol = HistogramReclaim(300.0, min_observations=4)
    assert pol.keep_alive_s("f") == 300.0  # no observations yet
    for _ in range(3):
        pol.observe_gap("f", 10.0)
    assert pol.keep_alive_s("f") == 300.0  # still below min_observations
    pol.observe_gap("f", 10.0)
    assert pol.keep_alive_s("f") < 300.0  # learned


def test_histogram_learns_per_function():
    pol = HistogramReclaim(
        600.0, bucket_s=10.0, min_ttl_s=20.0, min_observations=4
    )
    for _ in range(20):
        pol.observe_gap("bursty", 5.0)  # reused within seconds
        pol.observe_gap("slow", 95.0)  # reused every ~95 s
    assert pol.keep_alive_s("bursty") == 20.0  # (0+2)*10 clamped to min_ttl
    assert pol.keep_alive_s("slow") == 110.0  # bucket 9 -> (9+2)*10
    assert pol.keep_alive_s("dead") == 600.0  # never reused: default TTL
    assert pol.should_reclaim("bursty", 25.0, now=0.0)
    assert not pol.should_reclaim("slow", 25.0, now=0.0)


def test_histogram_quantile_tracks_tail():
    pol = HistogramReclaim(
        600.0, bucket_s=10.0, min_ttl_s=0.0, quantile=0.5, min_observations=1
    )
    for _ in range(9):
        pol.observe_gap("f", 5.0)
    pol.observe_gap("f", 205.0)  # one tail gap
    assert pol.keep_alive_s("f") == 20.0  # median stays in bucket 0
    p99 = HistogramReclaim(
        600.0, bucket_s=10.0, min_ttl_s=0.0, quantile=0.99, min_observations=1
    )
    for _ in range(9):
        p99.observe_gap("f", 5.0)
    p99.observe_gap("f", 205.0)
    assert p99.keep_alive_s("f") == 220.0  # p99 protects the tail gap


def test_histogram_clamps_and_overflow_bucket():
    pol = HistogramReclaim(
        100.0, bucket_s=10.0, min_ttl_s=30.0, min_observations=1
    )
    pol.observe_gap("f", 0.0)
    assert pol.keep_alive_s("f") == 30.0  # clamped up to min_ttl
    pol2 = HistogramReclaim(
        100.0, bucket_s=10.0, min_observations=1
    )
    pol2.observe_gap("g", 10_000.0)  # far past max_ttl: overflow bucket
    assert pol2.keep_alive_s("g") == 100.0  # clamped down to max_ttl
    pol2.observe_gap("g", -1.0)  # negative gaps are ignored
    assert pol2.totals["g"] == 1


def test_histogram_validation():
    with pytest.raises(ValueError, match="bucket_s"):
        HistogramReclaim(100.0, bucket_s=0.0)
    with pytest.raises(ValueError, match="quantile"):
        HistogramReclaim(100.0, quantile=1.5)


def test_snapshot_json_roundtrip_is_exact():
    pol = HistogramReclaim(
        240.0, bucket_s=5.0, min_ttl_s=10.0, quantile=0.9, min_observations=2
    )
    for g in (3.0, 7.0, 3.0, 120.0, 9999.0):
        pol.observe_gap("a", g)
    pol.observe_gap("b", 50.0)
    blob = json.loads(json.dumps(pol.snapshot(), sort_keys=True))
    back = restore_reclaim_policy(blob, default_ttl_s=999.0)
    assert isinstance(back, HistogramReclaim)
    assert back.snapshot() == pol.snapshot()
    for fid in ("a", "b", "unseen"):
        assert back.keep_alive_s(fid) == pol.keep_alive_s(fid)
    fixed = restore_reclaim_policy(
        json.loads(json.dumps(FixedTTLReclaim(77.0).snapshot())),
        default_ttl_s=999.0,
    )
    assert isinstance(fixed, FixedTTLReclaim) and fixed.ttl_s == 77.0


def test_restore_legacy_none_is_fixed_default():
    pol = restore_reclaim_policy(None, default_ttl_s=420.0)
    assert isinstance(pol, FixedTTLReclaim) and pol.ttl_s == 420.0
    with pytest.raises(ValueError, match="unknown reclaim policy"):
        restore_reclaim_policy({"policy": "martian"}, default_ttl_s=1.0)


def test_custom_policy_restores_through_registry():
    """Subclasses restore polymorphically via the name registry."""
    from repro.core.reclaim import ReclaimPolicy

    class EagerReclaim(ReclaimPolicy):
        name = "test_eager"

        def __init__(self, threshold_s: float = 1.0) -> None:
            self.threshold_s = threshold_s

        def should_reclaim(self, fid, idle_s, now):
            return idle_s >= self.threshold_s

        def snapshot(self):
            return {"policy": self.name, "threshold_s": self.threshold_s}

        @classmethod
        def from_snapshot(cls, blob, *, default_ttl_s):
            return cls(blob["threshold_s"])

    back = restore_reclaim_policy(
        json.loads(json.dumps(EagerReclaim(3.5).snapshot())), default_ttl_s=900.0
    )
    assert isinstance(back, EagerReclaim) and back.threshold_s == 3.5


def test_custom_policy_without_from_snapshot_fails_with_instruction():
    from repro.core.reclaim import ReclaimPolicy

    class OpaqueReclaim(ReclaimPolicy):
        name = "test_opaque"

        def should_reclaim(self, fid, idle_s, now):
            return False

    with pytest.raises(ValueError, match="must override snapshot"):
        restore_reclaim_policy(OpaqueReclaim().snapshot(), default_ttl_s=1.0)


def test_keep_alive_cache_tracks_new_observations():
    pol = HistogramReclaim(600.0, bucket_s=10.0, min_ttl_s=0.0,
                           min_observations=1)
    pol.observe_gap("f", 5.0)
    assert pol.keep_alive_s("f") == 20.0
    assert pol.keep_alive_s("f") == 20.0  # memoized path
    for _ in range(200):
        pol.observe_gap("f", 155.0)  # the distribution moves
    assert pol.keep_alive_s("f") == 170.0  # cache was invalidated


def test_ftmanager_legacy_restore_honors_reclaim_kwarg():
    """A legacy snapshot (no reclaim key) + explicit reclaim= keeps the
    caller's requested policy instead of silently degrading to fixed."""
    from repro.core import FTManager, VMInfo

    m = FTManager()
    m.add_free_vm(VMInfo("vm0"))
    snap = m.snapshot()
    del snap["reclaim"]  # pre-policy snapshot format
    r = FTManager.restore(snap, reclaim="histogram")
    assert isinstance(r.reclaim, HistogramReclaim)
    r2 = FTManager.restore(m.snapshot(), reclaim="histogram")
    # a recorded policy is authoritative over the kwarg
    assert isinstance(r2.reclaim, FixedTTLReclaim)
