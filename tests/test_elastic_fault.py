"""Elastic scaling + fault tolerance control plane."""
import pytest

from repro.core import FTManager, VMInfo
from repro.distributed.elastic import ElasticConfig, ElasticCoordinator
from repro.distributed.fault import (
    FaultCoordinator,
    HeartbeatMonitor,
    StragglerDetector,
)


def test_elastic_join_uses_peers_not_store():
    ec = ElasticCoordinator(ElasticConfig(payload_bytes=10**9))
    first = ec.join(now=0.0)
    assert first.upstream is None  # root hits the central store
    later = [ec.join(now=float(i)) for i in range(1, 8)]
    assert all(j.upstream is not None for j in later)
    assert len(ec.hosts) == 8
    # tree stays balanced: height = floor(log2(8)) + 1
    assert later[-1].tree_height == 4


def test_elastic_leave_and_fail_repair():
    ec = ElasticCoordinator()
    hosts = [ec.join().host for _ in range(10)]
    ec.leave(hosts[3])
    ec.fail(hosts[1])
    ft = ec.mgr.trees[ec.cfg.model_id]
    ft.check_invariants()
    assert len(ec.hosts) == 8


def test_elastic_mesh_proposal():
    ec = ElasticCoordinator()
    for _ in range(10):
        ec.join()
    assert ec.propose_mesh(16) == (8, 16)  # largest pow2 <= 10
    for _ in range(6):
        ec.join()
    assert ec.propose_mesh(16) == (16, 16)


def test_join_latency_scales_with_payload():
    small = ElasticCoordinator(ElasticConfig(payload_bytes=10**8))
    big = ElasticCoordinator(ElasticConfig(payload_bytes=4 * 10**9))
    small.join(); big.join()
    a = small.join().provision_latency_s
    b = big.join().provision_latency_s
    assert b > a * 5


def test_heartbeat_detection():
    mon = HeartbeatMonitor(timeout_s=5.0)
    mon.beat("h1", 0.0)
    mon.beat("h2", 8.0)
    assert mon.dead_hosts(now=10.0) == ["h1"]


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(threshold=1.5)
    for _ in range(10):
        det.record("fast1", 1.0)
        det.record("fast2", 1.1)
        det.record("slow", 3.0)
    assert det.stragglers() == ["slow"]


def test_fault_coordinator_end_to_end():
    mgr = FTManager()
    for i in range(6):
        mgr.add_free_vm(VMInfo(f"h{i}"))
    for i in range(6):
        vm = mgr.reserve_vm()
        mgr.insert("model", vm.vm_id)
    restarted = []
    fc = FaultCoordinator(mgr, on_restart=lambda dead: restarted.extend(dead))
    for i in range(6):
        fc.monitor.beat(f"h{i}", 0.0)
    fc.monitor.beat("h2", 0.0)  # h2 stops beating after t=0
    for i in range(6):
        if i != 2:
            fc.monitor.beat(f"h{i}", 20.0)
    actions = fc.tick(now=25.0)
    assert actions["dead"] == ["h2"]
    assert restarted == ["h2"]
    mgr.trees["model"].check_invariants()
    assert "h2" not in mgr.trees["model"]


def test_fault_coordinator_demotes_straggler():
    mgr = FTManager()
    for i in range(7):
        mgr.add_free_vm(VMInfo(f"h{i}"))
        mgr.reserve_vm()
        mgr.insert("model", f"h{i}", now=0.0)
    fc = FaultCoordinator(mgr)
    ft = mgr.trees["model"]
    interior = next(n.vm_id for n in ft.bfs() if n.children())
    for h in [f"h{i}" for i in range(7)]:
        fc.detector.record(h, 5.0 if h == interior else 1.0)
    actions = fc.tick(now=1.0)
    assert ("model", interior) in actions["demoted"]
    assert ft.children_of(interior) == []  # now a leaf: stops throttling peers
    ft.check_invariants()
