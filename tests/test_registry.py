"""Sharded registry subsystem (ISSUE 4): spec/resolver semantics, per-shard
engine accounting, and the bit-identical single-shard regression.

Four safety nets:

  * *golden single-shard regression* — the default ``RegistrySpec(shards=1)``
    reproduces the pre-sharding simulator **exactly**: provisioning-wave
    latencies, the scale scenario's makespan, peak egress and full event-log
    hash, and the single-tenant TickStats stream hashes were all captured on
    the commit before the refactor and are pinned here verbatim;
  * *differential per-shard accounting* — the incremental engine matches the
    full-recompute oracle (rates, times, per-shard peaks) on faasnet /
    baseline / kraken plans at 1, 2 and 4 shards;
  * *property* — per-shard egress peaks always sum to >= the aggregate peak
    (shards peak at different times, so the sum over-counts, never under);
  * *failover* — the shard map (spec + resolver state) rides the scheduler
    snapshot; legacy bare-manager snapshots restore as a 1-shard registry.
"""
import hashlib
import json
import statistics as st

import pytest

from repro.core import FunctionTree
from repro.core.registry import (
    GBPS,
    PLACEMENT_POLICIES,
    REGISTRY,
    RegistrySpec,
    ShardResolver,
    as_resolver,
    is_registry_node,
    shard_index,
)
from repro.core.topology import (
    DistributionPlan,
    Flow,
    baseline_plan,
    faasnet_plan,
    kraken_plan,
)
from repro.sim import (
    FlowSim,
    MultiTenantConfig,
    MultiTenantReplay,
    ReferenceFlowSim,
    ReplayConfig,
    SimConfig,
    TenantConfig,
    TraceReplay,
    WaveConfig,
    constant_trace,
    iot_trace,
    provision_wave,
    run_multi_tenant,
    synthetic_gaming_trace,
)
from repro.sim.scale import ScaleConfig, run_scale

from test_scale import _assert_equivalent, _close

MB = 1e6


# ----------------------------------------------------------------------
# RegistrySpec / node-id semantics
# ----------------------------------------------------------------------
def test_spec_validation():
    with pytest.raises(ValueError, match=">= 1 shard"):
        RegistrySpec(shards=0)
    with pytest.raises(ValueError, match="placement policy"):
        RegistrySpec(policy="random")
    with pytest.raises(ValueError, match="egress_caps"):
        RegistrySpec(shards=2, egress_caps=(1e9,))
    with pytest.raises(ValueError, match="qps_caps"):
        RegistrySpec(shards=3, qps_caps=(1.0, 2.0))


def test_single_shard_is_the_legacy_sentinel():
    """1-shard naming == pre-sharding naming: the bit-compat cornerstone."""
    spec = RegistrySpec(shards=1)
    assert spec.shard_id(0) == REGISTRY
    assert spec.shard_ids() == [REGISTRY]
    assert spec.canonical(REGISTRY) == REGISTRY


def test_multi_shard_ids_and_alias():
    spec = RegistrySpec(shards=4)
    ids = spec.shard_ids()
    assert len(set(ids)) == 4
    for i, sid in enumerate(ids):
        assert is_registry_node(sid)
        assert shard_index(sid) == i
        assert spec.canonical(sid) == sid
    # the legacy sentinel stays a valid alias, canonicalized to shard 0
    assert is_registry_node(REGISTRY)
    assert spec.canonical(REGISTRY) == spec.shard_id(0)
    assert not is_registry_node("vm17")
    with pytest.raises(ValueError):
        shard_index("vm17")
    with pytest.raises(IndexError):
        spec.shard_id(4)


def test_shard_count_mismatch_raises_not_clamps():
    """A plan built against a bigger registry than the engine's spec is a
    config bug: it must raise, not silently run at one shard's capacity."""
    spec1 = RegistrySpec(shards=1)
    assert spec1.canonical("__registry_shard0__") == REGISTRY  # valid alias
    with pytest.raises(ValueError, match="does not exist"):
        spec1.canonical("__registry_shard1__")
    plan = baseline_plan(["a", "b"], image_bytes=1_000_000,
                         registry=RegistrySpec(shards=2, policy="replicated"))
    sim = FlowSim(SimConfig())  # default 1-shard engine: mismatched
    sim.add_plan(plan)
    with pytest.raises(ValueError, match="does not exist"):
        sim.run()


def test_registry_spec_resolve_legacy_knobs():
    spec = RegistrySpec(shards=4)
    assert RegistrySpec.resolve(spec, egress_cap=1.0, qps=2.0) is spec
    legacy = RegistrySpec.resolve(None, egress_cap=3e9, qps=500.0)
    assert legacy == RegistrySpec(shards=1, egress_cap=3e9, qps=500.0)


def test_heterogeneous_per_shard_caps():
    spec = RegistrySpec(shards=2, egress_cap=1e9, egress_caps=(5e8, 1e9),
                        qps=100.0, qps_caps=(100.0, 700.0))
    assert spec.egress_of(0) == 5e8 and spec.egress_of(1) == 1e9
    assert spec.qps_of(1) == 700.0
    assert spec.aggregate_egress_cap() == 1.5e9
    # engine side: one egress-bound flow per shard; shard 1 is 2x faster
    cfg = SimConfig(registry=spec)
    cfg.vm_nic.in_cap = float("inf")  # isolate the per-shard egress caps
    sim = FlowSim(cfg)
    done = {}
    sim.add_plan(
        DistributionPlan(
            flows=[Flow(spec.shard_id(0), "a", "img", 1_000_000_000),
                   Flow(spec.shard_id(1), "b", "img", 1_000_000_000)],
            streaming=False,
        ),
        on_node_done=lambda vm, t: done.setdefault(vm, t),
    )
    sim.run()
    assert _close(done["a"], 2.0) and _close(done["b"], 1.0), done


# ----------------------------------------------------------------------
# ShardResolver policies + wire snapshot
# ----------------------------------------------------------------------
def test_hash_by_function_is_stable_and_spreads():
    spec = RegistrySpec(shards=4, policy="hash_by_function")
    a, b = ShardResolver(spec), ShardResolver(spec)
    pieces = [f"fn{i}" for i in range(64)]
    assert [a.shard_for(p) for p in pieces] == [b.shard_for(p) for p in pieces]
    assert {a.shard_for(p) for p in pieces} == {0, 1, 2, 3}  # all shards hit


def test_least_loaded_balances_bytes():
    r = ShardResolver(RegistrySpec(shards=3, policy="least_loaded"))
    for i in range(30):
        r.source_for(f"fn{i}", nbytes=100 + i)  # slightly uneven blobs
    assert max(r.loads) - min(r.loads) <= max(100 + i for i in range(30))


def test_replicated_round_robins():
    spec = RegistrySpec(shards=3, policy="replicated")
    r = ShardResolver(spec)
    got = [r.source_for("img") for _ in range(6)]
    assert got == [spec.shard_id(i % 3) for i in range(6)]


def test_resolver_snapshot_roundtrip_continues_identically():
    for policy in PLACEMENT_POLICIES:
        spec = RegistrySpec(shards=3, policy=policy, qps=float("inf"))
        a = ShardResolver(spec)
        for i in range(7):
            a.source_for(f"fn{i}", nbytes=1000 * i)
        # json round-trip (inf qps must survive the wire)
        b = ShardResolver.restore(json.loads(json.dumps(a.snapshot())))
        assert b.spec == spec
        assert b.loads == a.loads
        tail_a = [a.source_for(f"t{i}", nbytes=10) for i in range(9)]
        tail_b = [b.source_for(f"t{i}", nbytes=10) for i in range(9)]
        assert tail_a == tail_b, policy


def test_as_resolver_coercion():
    assert as_resolver(None).spec == RegistrySpec()
    spec = RegistrySpec(shards=2)
    assert as_resolver(spec).spec is spec
    r = ShardResolver(spec)
    assert as_resolver(r) is r


# ----------------------------------------------------------------------
# Golden single-shard regression (captured on the pre-sharding commit)
# ----------------------------------------------------------------------
def test_golden_provision_wave_unchanged():
    """Default 1-shard waves reproduce the pre-refactor latencies exactly."""
    golden = {
        "faasnet": 7.0702504159999995,
        "baseline": 35.409666666666666,
        "on_demand": 8.931815696022728,
    }
    for system, want in golden.items():
        got = st.mean(provision_wave(system, 32, WaveConfig()).values())
        assert got == want, (system, got, want)


def test_golden_scale_scenario_unchanged():
    """Makespan, peak egress AND the full event-log hash are bit-identical."""
    res = run_scale(ScaleConfig(n_vms=32, n_functions=4,
                                containers_per_function=8, churn_ops=5, seed=3))
    assert res.makespan == 4.475582912
    assert res.peak_registry_egress == 120000000.0
    # 1-shard per-shard telemetry reduces to the legacy aggregate
    assert res.peak_shard_egress == {REGISTRY: 120000000.0}
    h = hashlib.sha256(
        "\n".join(f"{t!r} {e}" for t, e in res.trace).encode()
    ).hexdigest()
    assert h == "bb5965a1fa885edd0aaf968dfec9bad59941edf5c13a367d869ed2eea7954c82"


def test_golden_tickstats_streams_unchanged():
    """Single-tenant replays emit the pre-refactor TickStats bit-for-bit."""
    golden = {
        "faasnet": "b5f8018fe683476756c6b7734b944421bee84190a2bae310e13872268a6c04c2",
        "baseline": "e720b3a4765553aba8cd860d2fe3e82d6caf16d90c44a05253212a8ab9f670d0",
    }
    trace = iot_trace(scale=1 / 3)[: 11 * 60]
    for system, want in golden.items():
        r = TraceReplay(
            ReplayConfig(system=system, idle_reclaim_s=120, vm_pool_size=120)
        )
        tl = r.run(trace)
        h = hashlib.sha256("\n".join(repr(ts) for ts in tl).encode()).hexdigest()
        assert h == want, system
    assert r.sim.peak_registry_egress == sum(r.sim.peak_shard_egress.values())


def test_legacy_rate_literals_use_gbps_constant():
    """The 6.5e9 byte-rate literals are now 52 * GBPS — same float exactly."""
    assert ReplayConfig().registry_out_cap == 52 * GBPS == 6.5e9
    assert MultiTenantConfig().registry_out_cap == 52 * GBPS == 6.5e9


# ----------------------------------------------------------------------
# Differential per-shard accounting: incremental engine vs the oracle
# ----------------------------------------------------------------------
def _spec(shards: int, policy: str = "replicated") -> RegistrySpec:
    return RegistrySpec(shards=shards, egress_cap=2 * GBPS, qps=1100.0,
                        policy=policy)


def _simcfg(spec: RegistrySpec) -> SimConfig:
    return SimConfig(registry=spec, per_stream_cap=30 * MB, hop_latency=0.2)


def _assert_peaks_equivalent(plan, cfg: SimConfig) -> None:
    """Both engines agree on per-shard and aggregate peak egress."""
    peaks = []
    for cls in (FlowSim, ReferenceFlowSim):
        sim = cls(cfg)
        sim.add_plan(plan)
        sim.run()
        peaks.append((sim.peak_registry_egress, dict(sim.peak_shard_egress)))
    (inc_total, inc_shards), (ref_total, ref_shards) = peaks
    assert _close(inc_total, ref_total), (inc_total, ref_total)
    assert inc_shards.keys() == ref_shards.keys()
    for k in inc_shards:
        assert _close(inc_shards[k], ref_shards[k]), (k, inc_shards, ref_shards)


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_differential_baseline_star(shards):
    plan = baseline_plan([f"vm{i}" for i in range(16)],
                         image_bytes=int(100 * MB),
                         registry=ShardResolver(_spec(shards)))
    _assert_equivalent(plan, _simcfg(_spec(shards)))
    _assert_peaks_equivalent(plan, _simcfg(_spec(shards)))


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_differential_faasnet_forest(shards):
    """Three FTs whose roots hash to different shards, one shared sim."""
    resolver = ShardResolver(_spec(shards, policy="hash_by_function"))
    flows, control = [], {}
    for t in range(3):
        ft = FunctionTree(f"fn{t}")
        for i in range(7):
            ft.insert(f"t{t}vm{i}")
        p = faasnet_plan(ft, image_bytes=int(60 * MB), startup_fraction=0.25,
                         piece=f"fn{t}", registry=resolver)
        flows += p.flows
        control.update(p.control_latency)
    plan = DistributionPlan(flows=flows, control_latency=control, streaming=True)
    _assert_equivalent(plan, _simcfg(_spec(shards)))
    _assert_peaks_equivalent(plan, _simcfg(_spec(shards)))


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_differential_kraken_mesh_untouched_by_shards(shards):
    """Kraken never hits the registry: sharding must not move it at all."""
    plan = kraken_plan([f"vm{i}" for i in range(10)],
                       layer_bytes=[int(8 * MB)] * 3, origin="origin", seed=5)
    cfg = _simcfg(_spec(shards))
    cfg.coordinator_cost_s = 0.070
    _assert_equivalent(plan, cfg)
    sim = FlowSim(cfg)
    sim.add_plan(plan)
    sim.run()
    assert sim.peak_registry_egress == 0.0
    assert sim.peak_shard_egress == {}


def test_registry_alias_contends_with_shard0():
    """Legacy ``__registry__`` flows share shard 0's egress, not a free NIC."""
    spec = RegistrySpec(shards=2, egress_cap=10 * MB)
    sim = FlowSim(SimConfig(registry=spec))
    done = {}
    sim.add_plan(
        DistributionPlan(
            flows=[Flow(REGISTRY, "a", "img", 10_000_000),
                   Flow(spec.shard_id(0), "b", "img", 10_000_000)],
            streaming=False,
        ),
        on_node_done=lambda vm, t: done.setdefault(vm, t),
    )
    sim.run()
    # both flows split shard 0's 10 MB/s: 2 s each, not 1 s
    assert _close(done["a"], 2.0) and _close(done["b"], 2.0), done
    assert set(sim.peak_shard_egress) == {spec.shard_id(0)}


# ----------------------------------------------------------------------
# Property: per-shard peaks sum to >= the aggregate peak
# ----------------------------------------------------------------------
def test_per_shard_peaks_sum_geq_aggregate_peak():
    """Shards peak at different instants, so the sum of per-shard peaks can
    only over-count the aggregate (simultaneous) peak, never under-count."""
    import random

    for seed in range(6):
        rng = random.Random(seed)
        shards = rng.choice([1, 2, 3, 4])
        policy = rng.choice(list(PLACEMENT_POLICIES))
        resolver = ShardResolver(_spec(shards, policy=policy))
        nodes = [f"vm{i}" for i in range(12)]
        flows = []
        for i, n in enumerate(nodes):
            if i == 0 or rng.random() < 0.4:
                src = resolver.source_for(f"fn{i % 3}",
                                          nbytes=rng.randrange(1, 40) * 10**6)
            else:
                src = nodes[rng.randrange(i)]
            flows.append(Flow(src, n, f"fn{i % 3}",
                              rng.randrange(1_000_000, 40_000_000)))
        plan = DistributionPlan(
            flows=flows,
            control_latency={n: rng.random() * 0.05 for n in nodes},
            streaming=bool(seed % 2),
        )
        sim = FlowSim(_simcfg(_spec(shards)))
        sim.add_plan(plan)
        sim.run()
        total = sum(sim.peak_shard_egress.values())
        assert total >= sim.peak_registry_egress * (1 - 1e-12), (
            seed, sim.peak_shard_egress, sim.peak_registry_egress
        )


# ----------------------------------------------------------------------
# Sweep shape: the paper's bottleneck-removal claim in miniature
# ----------------------------------------------------------------------
def test_sharding_speeds_up_baseline_not_faasnet():
    def makespan(system, shards):
        cfg = WaveConfig(
            per_stream_cap=float("inf"),
            registry=RegistrySpec(shards=shards, egress_cap=9.5 * GBPS,
                                  qps=1100.0, policy="replicated"),
        )
        return max(provision_wave(system, 64, cfg).values())

    assert makespan("baseline", 1) > 1.9 * makespan("baseline", 4)
    f1, f4 = makespan("faasnet", 1), makespan("faasnet", 4)
    assert abs(f4 - f1) / f1 < 0.05, (f1, f4)


# ----------------------------------------------------------------------
# Failover: the shard map rides the scheduler snapshot
# ----------------------------------------------------------------------
def _mt_cfg(*, registry, system="faasnet", failover_at=None,
            minutes=3) -> MultiTenantConfig:
    dur = minutes * 60
    return MultiTenantConfig(
        tenants=[
            TenantConfig("gaming", synthetic_gaming_trace()[600 : 600 + dur],
                         seed=1),
            TenantConfig("steady", constant_trace(duration_s=dur), seed=2),
        ],
        system=system,
        vm_pool_size=150,
        idle_reclaim_s=90.0,
        failover_at=failover_at,
        registry=registry,
    )


@pytest.mark.parametrize("system", ["faasnet", "baseline"])
def test_sharded_failover_parity(system):
    """Failover with a stateful shard policy must not move one TickStats:
    the resolver's round-robin cursor/loads cross the wire in the snapshot.
    faasnet consults the resolver only for tree roots; baseline consults it
    on *every* provision, so the cursor position genuinely matters there."""
    spec = RegistrySpec(shards=3, egress_cap=2 * GBPS, qps=700.0,
                        policy="replicated")
    broken = run_multi_tenant(
        _mt_cfg(registry=spec, system=system, failover_at=70)
    )
    smooth = run_multi_tenant(
        _mt_cfg(registry=spec, system=system, failover_at=None)
    )
    assert broken.failovers == 1
    assert broken.timelines == smooth.timelines
    assert broken.per_tenant == smooth.per_tenant
    assert broken.peak_shard_egress == smooth.peak_shard_egress
    if system == "baseline":
        # every provision round-robins: all three shards really saw traffic
        assert len(broken.peak_shard_egress) == 3
    else:
        assert len(broken.peak_shard_egress) >= 1  # roots only — by design


def test_legacy_snapshot_restores_as_single_shard():
    """A pre-sharding snapshot (bare FTManager dict) restores with 1 shard."""
    replay = MultiTenantReplay(_mt_cfg(registry=None))
    legacy_blob = json.loads(json.dumps(replay.mgr.snapshot(), sort_keys=True))
    assert "manager" not in legacy_blob  # genuinely the old wire format
    replay.resolver = ShardResolver(RegistrySpec(shards=4))  # clobber
    replay.restore_snapshot(legacy_blob)
    assert replay.resolver.spec.shards == 1
    assert replay.resolver.spec.egress_cap == replay.cfg.registry_out_cap
    assert replay.resolver.spec.qps == replay.cfg.registry_qps


def test_replay_snapshot_roundtrip_carries_spec():
    spec = RegistrySpec(shards=2, egress_cap=3 * GBPS, policy="least_loaded")
    replay = MultiTenantReplay(_mt_cfg(registry=spec))
    replay.resolver.source_for("gaming", nbytes=12345)
    blob = json.loads(json.dumps(replay.snapshot(), sort_keys=True))
    fresh = MultiTenantReplay(_mt_cfg(registry=spec))
    fresh.restore_snapshot(blob)
    assert fresh.resolver.spec == spec
    assert fresh.resolver.loads == replay.resolver.loads
