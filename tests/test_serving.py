"""Serving engine: cold start (lazy restore) + batched generation."""
import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ModelConfig
from repro.models import model_for
from repro.serving.engine import ServeEngine

CFG = ModelConfig(
    name="serve_test", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, attn_impl="full", remat="none",
)


@pytest.fixture(scope="module")
def ckpt(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("serve_ckpt"))
    model = model_for(CFG)
    params = model.init(jax.random.key(0))
    mgr = CheckpointManager(d, block_size=4096)
    mgr.save(0, params)
    return mgr, params


def test_lazy_cold_start_serves_correctly(ckpt):
    mgr, params = ckpt
    eng = ServeEngine(CFG, max_batch=2)
    eng.start(mgr, 0, params, lazy=True)
    s = eng.cold_start_stats
    assert s["first_fetch_compressed_bytes"] <= s["total_fetch_compressed_bytes"]
    # lazy-started engine produces the same tokens as a direct-params engine
    eng2 = ServeEngine(CFG, max_batch=2)
    eng2.set_params(params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=8) for _ in range(2)]
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
        eng2.submit(p, max_new_tokens=4)
    a = eng.step_batch()
    b = eng2.step_batch()
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
    assert all(len(r.out_tokens) == 4 for r in a)


def test_queue_drains_in_fifo_batches(ckpt):
    mgr, params = ckpt
    eng = ServeEngine(CFG, max_batch=2)
    eng.set_params(params)
    rng = np.random.default_rng(1)
    ids = [eng.submit(rng.integers(0, CFG.vocab_size, size=6), 2)
           for _ in range(5)]
    done = []
    while eng.queue:
        done += eng.step_batch()
    assert [r.rid for r in done] == ids
    assert all(r.t_done >= r.t_first_token >= r.t_submit for r in done)
