"""Shared pytest configuration: the ``slow`` marker and ``--runslow`` gate.

Cluster-scale tests (the 1000-VM burst) are marked ``@pytest.mark.slow``
and skipped by default; run them with::

    PYTHONPATH=src python -m pytest --runslow -q

``scripts/ci.sh`` wraps the default (fast) tier-1 invocation so CI and
humans run exactly the same command.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="also run tests marked slow (e.g. the 1000-VM scale burst)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: cluster-scale test, skipped unless --runslow is given"
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
