"""Block-format checkpointing: roundtrip, laziness, crash safety, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(key=0):
    k = jax.random.key(key)
    return {
        "embed": {"table": jax.random.normal(k, (64, 16))},
        "stages": [
            (
                {"w": jax.random.normal(k, (4, 16, 16)).astype(jnp.bfloat16)},
                {"b": jnp.arange(10, dtype=jnp.int32)},
            )
        ],
        "final_norm": {"scale": jnp.ones((16,))},
    }


def _equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        and x.dtype == y.dtype
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_save_restore_bitexact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(100, t)
    assert mgr.latest_step() == 100
    r = mgr.restore(100, t)
    assert _equal(t, r)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree()
    mgr.save(1, t)
    mgr.wait()
    assert _equal(t, mgr.restore(1, t))


def test_gc_keeps_last_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(5, t)
    # simulate a crash mid-save of step 6: blocks written, manifest missing
    bpath, _ = mgr._paths(6)
    with open(bpath, "wb") as f:
        f.write(b"garbage")
    assert mgr.latest_step() == 5


def test_lazy_restore_reads_fewer_bytes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), block_size=4096)
    t = _tree()
    mgr.save(1, t)
    partial, finish, reader = mgr.restore_lazy(
        1, t, first=lambda p: p.startswith("embed")
    )
    first_bytes = reader.stats.fetched_compressed
    # embedding loaded, stage weights still zero
    assert np.array_equal(
        np.asarray(partial["embed"]["table"]), np.asarray(t["embed"]["table"])
    )
    assert float(jnp.abs(partial["stages"][0][0]["w"]).sum()) == 0.0
    full = finish()
    assert _equal(t, full)
    assert reader.stats.fetched_compressed > first_bytes


def test_iter_blocks_covers_payload(tmp_path):
    mgr = CheckpointManager(str(tmp_path), block_size=2048)
    t = _tree()
    mgr.save(1, t)
    blocks = list(mgr.iter_blocks(1))
    assert len(blocks) >= 2  # multi-block payload streams down FTs
