"""FTManager: placement limits, reclaim, failure repair, snapshot sync."""
import pytest

from repro.core import FTManager, VMInfo
from repro.core.provisioning import ProvisionState, ProvisionTask, RPCCosts


def _mgr(n_vms=10, **kw):
    m = FTManager(**kw)
    for i in range(n_vms):
        m.add_free_vm(VMInfo(f"vm{i}"))
    return m


def test_insert_returns_upstream():
    m = _mgr()
    v1 = m.reserve_vm()
    v2 = m.reserve_vm()
    assert m.insert("f", v1.vm_id) is None  # root fetches from registry
    assert m.insert("f", v2.vm_id) == v1.vm_id


def test_placement_limit_enforced():
    m = _mgr(max_functions_per_vm=2)
    vm = m.reserve_vm()
    m.insert("f1", vm.vm_id)
    m.insert("f2", vm.vm_id)
    with pytest.raises(RuntimeError):
        m.insert("f3", vm.vm_id)


def test_one_tree_per_function():
    m = _mgr()
    a, b = m.reserve_vm(), m.reserve_vm()
    m.insert("f1", a.vm_id)
    m.insert("f2", a.vm_id)
    m.insert("f1", b.vm_id)
    assert len(m.trees["f1"]) == 2
    assert len(m.trees["f2"]) == 1


def test_idle_reclaim_rebalances():
    m = _mgr(vm_idle_reclaim_s=100)
    vms = [m.reserve_vm(now=0.0) for _ in range(5)]
    for v in vms:
        m.insert("f", v.vm_id, now=0.0)
    # mark one instance active recently; others idle out
    m.touch_instance("f", vms[0].vm_id, 950.0)
    reclaimed = m.reclaim_idle(now=1000.0)
    assert set(reclaimed) == {v.vm_id for v in vms[1:]}
    ft = m.trees["f"]
    ft.check_invariants()
    assert len(ft) == 1


def test_failure_repairs_all_trees():
    m = _mgr()
    vms = [m.reserve_vm() for _ in range(4)]
    for v in vms:
        m.insert("f1", v.vm_id)
        m.insert("f2", v.vm_id)
    repaired = m.on_vm_failure(vms[1].vm_id)
    assert sorted(repaired) == ["f1", "f2"]
    for fid in ("f1", "f2"):
        m.trees[fid].check_invariants()
        assert vms[1].vm_id not in m.trees[fid]
    assert not m.vms[vms[1].vm_id].alive


def test_ft_aware_placement_prefers_light_vms():
    m = _mgr(ft_aware_placement=True)
    a, b = m.reserve_vm(), m.reserve_vm()
    m.insert("f1", a.vm_id)
    m.insert("f1", b.vm_id)
    m.insert("f2", a.vm_id)  # a now holds 2 functions, b holds 1
    pick = m.pick_vm_for("f3")
    assert pick.vm_id == b.vm_id


def test_snapshot_restore_roundtrip():
    m = _mgr()
    vms = [m.reserve_vm() for _ in range(6)]
    for v in vms:
        m.insert("f", v.vm_id)
    snap = m.snapshot()
    m2 = FTManager.restore(snap)
    assert m2.trees["f"].vm_ids() == m.trees["f"].vm_ids()
    assert m2.free_pool == m.free_pool
    m2.trees["f"].check_invariants()


# ----------------------------------------------------------------------
# provisioning protocol state machine
# ----------------------------------------------------------------------
def test_protocol_happy_path():
    t = ProvisionTask("f", "vm0")
    t.step1_insert("vm1", 0.0)
    t.step2_manifest(0.01)
    t.step3_ready(0.02)
    t.step4_create(0.03)
    t.step7_created(4.0)
    assert t.state is ProvisionState.CREATED
    assert t.provisioning_latency() == pytest.approx(4.0)


def test_protocol_illegal_transition():
    t = ProvisionTask("f", "vm0")
    t.step1_insert(None, 0.0)
    with pytest.raises(ValueError):
        t.step4_create(0.1)  # must do manifest + ready first


def test_protocol_retry_after_failure():
    t = ProvisionTask("f", "vm0")
    t.step1_insert("vm1", 0.0)
    t.step2_manifest(0.01)
    t.fail(0.02)
    t.retry_with("vm2", 1.0)  # tree repaired: new upstream
    assert t.upstream == "vm2"
    assert t.state is ProvisionState.INSERTED


def test_rpc_costs_total():
    c = RPCCosts()
    assert c.control_plane_total() == pytest.approx(
        3 * c.scheduler_rpc + c.manifest_fetch + c.image_load
    )


# ----------------------------------------------------------------------
# PR 2: free-pool guard, incremental seed loads, heap-based placement
# ----------------------------------------------------------------------
def test_release_vm_double_release_is_idempotent():
    """Regression: release → release must not double-append to free_pool."""
    m = _mgr(n_vms=3)
    vm = m.reserve_vm()
    m.insert("f", vm.vm_id)
    m.delete("f", vm.vm_id)
    m.release_vm(vm.vm_id)
    m.release_vm(vm.vm_id)  # double release (two reclaim paths racing)
    assert list(m.free_pool).count(vm.vm_id) == 1


def test_release_reserve_release_churn_no_duplicates():
    """The release→reserve→release loop the churn harness exercises."""
    m = _mgr(n_vms=4)
    for _ in range(10):
        vm = m.reserve_vm()
        m.insert("f", vm.vm_id)
        m.delete("f", vm.vm_id)
        m.release_vm(vm.vm_id)
        m.release_vm(vm.vm_id)
    ids = list(m.free_pool)
    assert len(ids) == len(set(ids)) == 4


def test_seed_loads_incremental_matches_recompute():
    """_seed_loads stays exact through insert/delete churn (AVL rotations)."""
    import random

    rng = random.Random(42)
    m = _mgr(n_vms=30)
    vms = [m.reserve_vm().vm_id for _ in range(30)]
    fids = [f"f{k}" for k in range(4)]
    members = {fid: [] for fid in fids}
    for _ in range(600):
        fid = fids[rng.randrange(len(fids))]
        if members[fid] and rng.random() < 0.45:
            v = members[fid].pop(rng.randrange(len(members[fid])))
            m.delete(fid, v)
        else:
            free = [v for v in vms if fid not in m.vms[v].functions]
            if not free:
                continue
            v = free[rng.randrange(len(free))]
            m.insert(fid, v)
            members[fid].append(v)
        for v in vms:
            assert m._seed_loads.get(v, 0) == m._seed_load_recompute(v), v


def _naive_pick(m, function_id):
    """The seed's O(V log V) placement: full-pool stable sort."""
    candidates = [
        vm
        for vm in m.vms.values()
        if vm.alive
        and vm.functions
        and function_id not in vm.functions
        and len(vm.functions) < m.max_functions_per_vm
    ]
    if not candidates:
        return None
    if m.ft_aware_placement:
        candidates.sort(key=lambda vm: (vm.load(), m._seed_load_recompute(vm.vm_id)))
    else:
        candidates.sort(key=lambda vm: -vm.load())
    return candidates[0]


@pytest.mark.parametrize("ft_aware", [True, False])
def test_heap_placement_matches_full_sort(ft_aware):
    """Differential: the lazy heap returns exactly the seed sort's argmin."""
    import random

    rng = random.Random(7)
    m = _mgr(n_vms=25, ft_aware_placement=ft_aware, max_functions_per_vm=6)
    vms = [m.reserve_vm().vm_id for _ in range(25)]
    fids = [f"f{k}" for k in range(8)]
    members = {fid: [] for fid in fids}
    checked = 0
    for _ in range(500):
        r = rng.random()
        fid = fids[rng.randrange(len(fids))]
        if r < 0.4 and members[fid]:
            v = members[fid].pop(rng.randrange(len(members[fid])))
            m.delete(fid, v)
        elif r < 0.8:
            want = _naive_pick(m, fid)
            got = m.pick_vm_for(fid)
            if want is None:
                assert got is None or not got.functions  # reserve_vm fallback
            else:
                assert got is want, (fid, got.vm_id, want.vm_id)
                checked += 1
                m.insert(fid, got.vm_id)
                members[fid].append(got.vm_id)
        else:
            free = [v for v in vms if fid not in m.vms[v].functions
                    and len(m.vms[v].functions) < m.max_functions_per_vm]
            if free:
                v = free[rng.randrange(len(free))]
                m.insert(fid, v)
                members[fid].append(v)
    assert checked > 100  # the differential really ran


def test_heap_placement_survives_vm_failure():
    m = _mgr(n_vms=6)
    vms = [m.reserve_vm().vm_id for _ in range(6)]
    for v in vms:
        m.insert("f1", v)
    m.on_vm_failure(vms[0])
    pick = m.pick_vm_for("f2")
    assert pick is not None and pick.vm_id != vms[0]
    assert pick is _naive_pick(m, "f2")


def test_free_pool_is_deque_and_snapshot_roundtrips():
    from collections import deque

    m = _mgr(n_vms=5)
    assert isinstance(m.free_pool, deque)
    m.reserve_vm()
    snap = m.snapshot()
    assert snap["free_pool"] == [f"vm{i}" for i in range(1, 5)]
    m2 = FTManager.restore(snap)
    assert list(m2.free_pool) == snap["free_pool"]
    m2.release_vm(m2.reserve_vm().vm_id)  # guard state restored too
    assert len(list(m2.free_pool)) == len(set(m2.free_pool))


# ----------------------------------------------------------------------
# Snapshot/restore round-trip after random churn (scheduler failover)
# ----------------------------------------------------------------------
def _churned_manager(seed: int, steps: int = 400):
    """Random reserve/insert/delete/on_vm_failure churn through the manager."""
    import random

    rng = random.Random(seed)
    m = _mgr(n_vms=80, max_functions_per_vm=6)
    fids = [f"f{j}" for j in range(6)]
    placed: list[tuple[str, str]] = []  # (fid, vm_id) pairs currently in trees
    for step in range(steps):
        op = rng.random()
        if op < 0.45:  # reserve a fresh VM and place a function on it
            vm = m.reserve_vm(float(step))
            if vm is None:
                continue
            fid = fids[rng.randrange(len(fids))]
            m.insert(fid, vm.vm_id, float(step))
            placed.append((fid, vm.vm_id))
        elif op < 0.65 and placed:  # co-locate on an already-active VM
            fid, vm_id = placed[rng.randrange(len(placed))]
            other = fids[rng.randrange(len(fids))]
            vm = m.vms[vm_id]
            if other not in vm.functions and len(vm.functions) < 6:
                m.insert(other, vm_id, float(step))
                placed.append((other, vm_id))
        elif op < 0.9 and placed:  # graceful leave (reclaim path)
            fid, vm_id = placed.pop(rng.randrange(len(placed)))
            m.delete(fid, vm_id)
            vm = m.vms[vm_id]
            if not vm.functions and vm.alive:
                m.release_vm(vm_id)
        elif placed:  # heartbeat miss: drop the VM from every tree
            vm_id = placed[rng.randrange(len(placed))][1]
            m.on_vm_failure(vm_id)
            placed = [(f, v) for f, v in placed if v != vm_id]
    for ft in m.trees.values():
        ft.check_invariants()
    return m


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_snapshot_restore_after_random_churn(seed):
    """A restored manager reproduces trees, seed loads and future placement.

    The failover contract of `repro.sim.multi_tenant`: after arbitrary
    churn, snapshot -> json round-trip -> restore must yield a manager
    whose tree_stats, topologies, free pool, seed loads, telemetry counters
    and next-K `pick_vm_for` decisions are bit-identical to the original's.
    """
    import json

    m = _churned_manager(seed)
    r = FTManager.restore(
        json.loads(json.dumps(m.snapshot(), sort_keys=True)),
        max_functions_per_vm=6,
    )
    assert r.tree_stats() == m.tree_stats()
    for fid, ft in m.trees.items():
        assert r.trees[fid].to_dict() == ft.to_dict()
    assert list(r.free_pool) == list(m.free_pool)
    assert r.stats == m.stats
    for vid in m.vms:
        want = m._seed_load_recompute(vid)
        assert m._seed_loads.get(vid, 0) == want  # incremental stayed exact
        assert r._seed_loads.get(vid, 0) == want  # and the restore rebuilt it
    # Next K placement decisions bit-identical, applying each to both sides
    # (a pick mutates nothing, but the follow-up insert does).
    for k in range(25):
        fid = f"pick{k}"
        a = m.pick_vm_for(fid, now=1e6 + k)
        b = r.pick_vm_for(fid, now=1e6 + k)
        assert (a is None) == (b is None), fid
        if a is None:
            break
        assert a.vm_id == b.vm_id, fid
        if len(a.functions) < 6:
            m.insert(fid, a.vm_id, now=1e6 + k)
            r.insert(fid, b.vm_id, now=1e6 + k)


# ----------------------------------------------------------------------
# PR 5: memory-aware shared-pool placement + pluggable reclaim
# ----------------------------------------------------------------------
def test_insert_charges_and_delete_refunds_memory():
    m = _mgr(n_vms=2)
    m.set_function_mem("big", 3000)
    m.set_function_mem("small", 1000)
    vm = m.reserve_vm()
    m.insert("big", vm.vm_id)
    assert vm.mem_used_mb == 3000 and vm.func_mem_mb == {"big": 3000}
    m.insert("small", vm.vm_id)
    assert vm.mem_used_mb == 4000 and vm.mem_free_mb() == 96
    m.insert("free_rider", vm.vm_id)  # unregistered fn defaults to 0 MB
    assert vm.mem_used_mb == 4000
    m.set_function_mem("big2", 2000)
    with pytest.raises(RuntimeError, match="memory limit"):
        m.insert("big2", vm.vm_id)
    m.delete("big", vm.vm_id)
    assert vm.mem_used_mb == 1000 and "big" not in vm.func_mem_mb
    m.insert("big2", vm.vm_id)  # refunded memory re-admits
    assert vm.mem_used_mb == 3000


def test_pick_vm_for_admits_by_memory():
    """A lighter VM without memory headroom loses to a heavier one with it."""
    m = _mgr(n_vms=4)
    m.set_function_mem("fat", 3500)
    m.set_function_mem("thin", 200)
    a, b = m.reserve_vm(), m.reserve_vm()
    m.insert("fat", a.vm_id)  # a: 1 fn, 3500/4096 used
    m.insert("thin", b.vm_id)
    m.insert("thin2", b.vm_id)  # b: 2 fns, 400/4096 used
    m.set_function_mem("newfat", 1000)
    pick = m.pick_vm_for("newfat")
    assert pick.vm_id == b.vm_id  # a is lighter-loaded but has no room
    pick2 = m.pick_vm_for("thin3")  # 0 MB default fits anywhere: prefer a
    assert pick2.vm_id == a.vm_id


def test_mem_skipped_heap_entries_survive_for_other_functions():
    """Push-back parity (ISSUE 5): a memory-ineligible entry is NOT dropped.

    Skipping for memory is per-function — the same VM must remain a live
    candidate for a later, smaller function even though no insert/delete
    (hence no heap re-push) happens in between.  Mirrors the existing
    ``function_id in vm.functions`` skip handling.
    """
    m = _mgr(n_vms=8)
    m.set_function_mem("resident", 3900)
    vms = [m.reserve_vm() for _ in range(3)]
    for v in vms:
        m.insert("resident", v.vm_id)  # 196 MB free on each
    m.set_function_mem("huge", 1000)
    m.set_function_mem("tiny", 100)
    # pick for "huge": every active VM is memory-ineligible -> free-pool
    # fallback; the skipped entries must be pushed back, not dropped
    pick = m.pick_vm_for("huge")
    assert pick is not None and not pick.functions  # fresh reservation
    # no mutations on vms[0..2] since the skip; "tiny" (100 <= 196) must
    # still find an active VM via the heap (vm1: a leaf of the "resident"
    # tree, so zero seed load beats the root)
    pick2 = m.pick_vm_for("tiny")
    assert pick2 is not None and pick2.vm_id == vms[1].vm_id
    assert pick2.functions  # co-located, not a fresh reservation


def test_binpack_mem_key_prefers_fuller_vm():
    m = _mgr(n_vms=4, ft_aware_placement=False)
    m.set_function_mem("a", 2000)
    m.set_function_mem("b", 500)
    va, vb = m.reserve_vm(), m.reserve_vm()
    m.insert("a", va.vm_id)  # 1 fn, 2000 MB
    m.insert("b", vb.vm_id)  # 1 fn, 500 MB
    m.set_function_mem("c", 100)
    pick = m.pick_vm_for("c")
    assert pick.vm_id == va.vm_id  # equal load: binpack onto the fuller VM


def test_reclaim_instance_releases_only_empty_vms():
    m = _mgr(n_vms=3)
    vm = m.reserve_vm()
    m.insert("f1", vm.vm_id)
    m.insert("f2", vm.vm_id)
    assert m.reclaim_instance("f1", vm.vm_id) is False  # f2 still resident
    assert vm.vm_id not in m.free_pool
    assert m.stats["reclaims"] == 1
    assert m.reclaim_instance("f2", vm.vm_id) is True
    assert vm.vm_id in m.free_pool
    assert m.stats["reclaims"] == 2


def test_reclaim_idle_is_per_instance():
    """One VM, two tenants' instances aging independently (shared pool)."""
    m = _mgr(vm_idle_reclaim_s=100)
    vm = m.reserve_vm(now=0.0)
    m.insert("old", vm.vm_id, now=0.0)
    m.insert("fresh", vm.vm_id, now=0.0)
    m.touch_instance("fresh", vm.vm_id, 950.0)
    released = m.reclaim_idle(now=1000.0)
    assert released == []  # "fresh" keeps the VM out of the free pool
    assert vm.functions == {"fresh"}  # but "old" was reclaimed
    assert m.stats["reclaims"] == 1
    released = m.reclaim_idle(now=2000.0)
    assert released == [vm.vm_id]  # now empty -> released
    assert not vm.functions and vm.mem_used_mb == 0


def test_reclaim_idle_uses_pluggable_policy():
    from repro.core import HistogramReclaim

    pol = HistogramReclaim(500.0, bucket_s=10.0, min_ttl_s=20.0,
                           min_observations=3)
    m = FTManager(vm_idle_reclaim_s=500.0, reclaim=pol)
    for i in range(2):
        m.add_free_vm(VMInfo(f"vm{i}"))
    vm = m.reserve_vm(now=0.0)
    m.insert("f", vm.vm_id, now=0.0)
    # teach the policy that "f" is reused within ~10 s
    for _ in range(5):
        pol.observe_gap("f", 8.0)
    assert pol.keep_alive_s("f") == 20.0  # bucket 0 + safety bucket, >= min_ttl
    assert m.reclaim_idle(now=15.0) == []  # 15 < 20: keep
    assert m.reclaim_idle(now=25.0) == [vm.vm_id]  # learned TTL elapsed


def test_snapshot_roundtrips_memory_and_policy():
    import json

    from repro.core import FTManager as Mgr
    from repro.core import HistogramReclaim

    m = FTManager(reclaim=HistogramReclaim(300.0, bucket_s=10.0))
    for i in range(4):
        m.add_free_vm(VMInfo(f"vm{i}"))
    m.set_function_mem("f1", 1500)
    m.set_function_mem("f2", 700)
    vm = m.reserve_vm(now=1.0)
    m.insert("f1", vm.vm_id, now=1.0)
    m.insert("f2", vm.vm_id, now=2.0)
    m.reclaim.observe_gap("f1", 42.0)
    snap = json.loads(json.dumps(m.snapshot(), sort_keys=True))
    r = Mgr.restore(snap)
    rvm = r.vms[vm.vm_id]
    assert rvm.func_mem_mb == {"f1": 1500, "f2": 700}
    assert rvm.mem_used_mb == 2200
    assert rvm.func_last_active == {"f1": 1.0, "f2": 2.0}
    assert r.function_mem == {"f1": 1500, "f2": 700}
    assert r.reclaim.snapshot() == m.reclaim.snapshot()
    # the restored policy keeps learning from where it stopped
    r.reclaim.observe_gap("f1", 42.0)
    assert r.reclaim.totals["f1"] == 2


def test_snapshot_records_vm_order_and_stats():
    """The placement tie-break order and telemetry counters cross the wire."""
    m = _mgr(n_vms=4)
    for _ in range(3):
        m.insert("f", m.reserve_vm().vm_id)
    snap = m.snapshot()
    assert snap["vm_order"] == [f"vm{i}" for i in range(4)]
    assert snap["stats"]["inserts"] == 3 and snap["stats"]["reservations"] == 3
    m2 = FTManager.restore(snap)
    assert m2.stats == m.stats
    assert m2._vm_order == m._vm_order


def test_restore_accepts_legacy_snapshot():
    """Snapshots without vm_order/stats (pre-failover format) still restore."""
    m = _mgr(n_vms=3)
    m.insert("f", m.reserve_vm().vm_id)
    snap = m.snapshot()
    del snap["vm_order"], snap["stats"]
    for v in snap["vms"].values():
        del v["mem_mb"]
    m2 = FTManager.restore(snap)
    assert m2.tree_stats() == m.tree_stats()
    assert m2._vm_order == m._vm_order  # falls back to vms insertion order
    assert m2.vms["vm0"].mem_mb == 4096
