"""FTManager: placement limits, reclaim, failure repair, snapshot sync."""
import pytest

from repro.core import FTManager, VMInfo
from repro.core.provisioning import ProvisionState, ProvisionTask, RPCCosts


def _mgr(n_vms=10, **kw):
    m = FTManager(**kw)
    for i in range(n_vms):
        m.add_free_vm(VMInfo(f"vm{i}"))
    return m


def test_insert_returns_upstream():
    m = _mgr()
    v1 = m.reserve_vm()
    v2 = m.reserve_vm()
    assert m.insert("f", v1.vm_id) is None  # root fetches from registry
    assert m.insert("f", v2.vm_id) == v1.vm_id


def test_placement_limit_enforced():
    m = _mgr(max_functions_per_vm=2)
    vm = m.reserve_vm()
    m.insert("f1", vm.vm_id)
    m.insert("f2", vm.vm_id)
    with pytest.raises(RuntimeError):
        m.insert("f3", vm.vm_id)


def test_one_tree_per_function():
    m = _mgr()
    a, b = m.reserve_vm(), m.reserve_vm()
    m.insert("f1", a.vm_id)
    m.insert("f2", a.vm_id)
    m.insert("f1", b.vm_id)
    assert len(m.trees["f1"]) == 2
    assert len(m.trees["f2"]) == 1


def test_idle_reclaim_rebalances():
    m = _mgr(vm_idle_reclaim_s=100)
    vms = [m.reserve_vm(now=0.0) for _ in range(5)]
    for v in vms:
        m.insert("f", v.vm_id, now=0.0)
    # mark one VM active recently; others idle out
    m.vms[vms[0].vm_id].last_active = 950.0
    reclaimed = m.reclaim_idle(now=1000.0)
    assert set(reclaimed) == {v.vm_id for v in vms[1:]}
    ft = m.trees["f"]
    ft.check_invariants()
    assert len(ft) == 1


def test_failure_repairs_all_trees():
    m = _mgr()
    vms = [m.reserve_vm() for _ in range(4)]
    for v in vms:
        m.insert("f1", v.vm_id)
        m.insert("f2", v.vm_id)
    repaired = m.on_vm_failure(vms[1].vm_id)
    assert sorted(repaired) == ["f1", "f2"]
    for fid in ("f1", "f2"):
        m.trees[fid].check_invariants()
        assert vms[1].vm_id not in m.trees[fid]
    assert not m.vms[vms[1].vm_id].alive


def test_ft_aware_placement_prefers_light_vms():
    m = _mgr(ft_aware_placement=True)
    a, b = m.reserve_vm(), m.reserve_vm()
    m.insert("f1", a.vm_id)
    m.insert("f1", b.vm_id)
    m.insert("f2", a.vm_id)  # a now holds 2 functions, b holds 1
    pick = m.pick_vm_for("f3")
    assert pick.vm_id == b.vm_id


def test_snapshot_restore_roundtrip():
    m = _mgr()
    vms = [m.reserve_vm() for _ in range(6)]
    for v in vms:
        m.insert("f", v.vm_id)
    snap = m.snapshot()
    m2 = FTManager.restore(snap)
    assert m2.trees["f"].vm_ids() == m.trees["f"].vm_ids()
    assert m2.free_pool == m.free_pool
    m2.trees["f"].check_invariants()


# ----------------------------------------------------------------------
# provisioning protocol state machine
# ----------------------------------------------------------------------
def test_protocol_happy_path():
    t = ProvisionTask("f", "vm0")
    t.step1_insert("vm1", 0.0)
    t.step2_manifest(0.01)
    t.step3_ready(0.02)
    t.step4_create(0.03)
    t.step7_created(4.0)
    assert t.state is ProvisionState.CREATED
    assert t.provisioning_latency() == pytest.approx(4.0)


def test_protocol_illegal_transition():
    t = ProvisionTask("f", "vm0")
    t.step1_insert(None, 0.0)
    with pytest.raises(ValueError):
        t.step4_create(0.1)  # must do manifest + ready first


def test_protocol_retry_after_failure():
    t = ProvisionTask("f", "vm0")
    t.step1_insert("vm1", 0.0)
    t.step2_manifest(0.01)
    t.fail(0.02)
    t.retry_with("vm2", 1.0)  # tree repaired: new upstream
    assert t.upstream == "vm2"
    assert t.state is ProvisionState.INSERTED


def test_rpc_costs_total():
    c = RPCCosts()
    assert c.control_plane_total() == pytest.approx(
        3 * c.scheduler_rpc + c.manifest_fetch + c.image_load
    )
