"""Blockstore: roundtrip, on-demand ranges, read amplification (Fig. 20).

Property-based variants require ``hypothesis`` and are skipped when it is
absent; deterministic example-based equivalents always run.
"""
import os
import random

import pytest

from repro.core import BlockReader, read_manifest, write_blockstore
from repro.core.blockstore import default_codec, have_zstd

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False


def test_roundtrip(tmp_path):
    payload = os.urandom(1_000_000)
    path = str(tmp_path / "p.blocks")
    m = write_blockstore(payload, path, block_size=64 * 1024)
    assert m.raw_size == len(payload)
    assert m.n_blocks == -(-len(payload) // (64 * 1024))
    r = BlockReader(path)
    assert r.read_all() == payload


def test_manifest_reload(tmp_path):
    payload = b"hello" * 10_000
    path = str(tmp_path / "p.blocks")
    m = write_blockstore(payload, path, block_size=8192)
    m2 = read_manifest(path)
    assert m2 == m
    assert m2.codec == default_codec()


def test_zlib_codec_roundtrip(tmp_path):
    """The stdlib fallback codec must roundtrip regardless of zstd presence."""
    payload = os.urandom(300_000)
    path = str(tmp_path / "p.blocks")
    m = write_blockstore(payload, path, block_size=32 * 1024, codec="zlib")
    assert m.codec == "zlib"
    assert read_manifest(path).codec == "zlib"
    assert BlockReader(path).read_all() == payload


@pytest.mark.skipif(not have_zstd(), reason="zstandard not installed")
def test_zstd_codec_roundtrip(tmp_path):
    payload = os.urandom(300_000)
    path = str(tmp_path / "p.blocks")
    m = write_blockstore(payload, path, block_size=32 * 1024, codec="zstd")
    assert m.codec == "zstd"
    assert BlockReader(path).read_all() == payload


def test_unknown_codec_raises(tmp_path):
    with pytest.raises(ValueError):
        write_blockstore(b"x", str(tmp_path / "p.blocks"), codec="lz77")


def test_range_read_exact(tmp_path):
    payload = bytes(range(256)) * 4096  # 1 MiB deterministic
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=32 * 1024)
    r = BlockReader(path)
    assert r.read_range(100_000, 50_000) == payload[100_000:150_000]
    assert r.read_range(0, 1) == payload[:1]
    assert r.read_range(len(payload) - 7, 7) == payload[-7:]


def test_out_of_range_raises(tmp_path):
    path = str(tmp_path / "p.blocks")
    write_blockstore(b"x" * 100, path, block_size=64)
    r = BlockReader(path)
    with pytest.raises(ValueError):
        r.read_range(90, 20)


def test_on_demand_fetches_only_covering_blocks(tmp_path):
    payload = os.urandom(1 << 20)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=64 * 1024)  # 16 blocks
    r = BlockReader(path)
    r.read_range(0, 1000)  # one block
    assert r.stats.blocks_fetched == 1
    r.read_range(60_000, 10_000)  # spans blocks 0-1; block 0 cached
    assert r.stats.blocks_fetched == 2


def test_read_amplification_grows_with_block_size(tmp_path):
    """Paper Fig. 20: bigger blocks => more useless bytes at range edges."""
    payload = os.urandom(16 << 20)
    amps = []
    for bs in (64 * 1024, 512 * 1024, 2 << 20):
        path = str(tmp_path / f"p{bs}.blocks")
        write_blockstore(payload, path, block_size=bs)
        r = BlockReader(path)
        # stride > largest block so no read hits a cached block
        for off in range(0, len(payload) - 1000, 3_000_000):
            r.read_range(off, 1000)
        amps.append(r.stats.amplification())
    assert amps[0] < amps[1] < amps[2]


def test_block_cache_counts_network_bytes_once(tmp_path):
    payload = os.urandom(256 * 1024)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=64 * 1024)
    r = BlockReader(path)
    r.read_range(0, 1000)
    first = r.stats.fetched_compressed
    r.read_range(500, 1000)  # same block, cached
    assert r.stats.fetched_compressed == first


# ----------------------------------------------------------------------
# Deterministic example-based variants of the property tests: always run,
# even without hypothesis (seeded random, fixed corner cases).
# ----------------------------------------------------------------------
def test_roundtrip_examples(tmp_path):
    rng = random.Random(42)
    cases = [
        (b"\x00", 1024),
        (b"a" * 1023, 1024),
        (b"b" * 1024, 1024),
        (b"c" * 1025, 1024),
        (rng.randbytes(199_999), 4096),
        (rng.randbytes(65_536), 65536),
        (bytes(range(256)) * 300, 1024),
    ]
    for i, (data, block_size) in enumerate(cases):
        path = str(tmp_path / f"p{i}.blocks")
        write_blockstore(data, path, block_size=block_size)
        assert BlockReader(path).read_all() == data, (i, len(data), block_size)


def test_arbitrary_range_examples(tmp_path):
    rng = random.Random(7)
    payload = rng.randbytes(100_000)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=4096)
    r = BlockReader(path)
    ranges = [(0, 0), (0, 1), (0, len(payload)), (len(payload) - 1, 1), (4095, 2)]
    ranges += [
        (rng.randrange(len(payload)), 0) for _ in range(5)
    ]
    for _ in range(40):
        off = rng.randrange(len(payload))
        ranges.append((off, rng.randrange(len(payload) - off + 1)))
    for off, ln in ranges:
        assert r.read_range(off, ln) == payload[off : off + ln], (off, ln)


# ----------------------------------------------------------------------
# hypothesis property tests (skipped without the package)
# ----------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        data=st.binary(min_size=1, max_size=200_000),
        block_size=st.sampled_from([1024, 4096, 65536]),
    )
    def test_roundtrip_property(tmp_path_factory, data, block_size):
        path = str(tmp_path_factory.mktemp("bs") / "p.blocks")
        write_blockstore(data, path, block_size=block_size)
        assert BlockReader(path).read_all() == data

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def test_arbitrary_range_property(tmp_path_factory, data):
        payload = data.draw(st.binary(min_size=10, max_size=100_000))
        path = str(tmp_path_factory.mktemp("bs") / "p.blocks")
        write_blockstore(payload, path, block_size=4096)
        r = BlockReader(path)
        off = data.draw(st.integers(0, len(payload) - 1))
        ln = data.draw(st.integers(0, len(payload) - off))
        assert r.read_range(off, ln) == payload[off : off + ln]


# ----------------------------------------------------------------------
# PR 2: persistent handle + coalesced reads
# ----------------------------------------------------------------------
def test_cold_sequential_range_coalesces_to_one_read(tmp_path):
    payload = os.urandom(400_000)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=32 * 1024)
    r = BlockReader(path)
    got = r.read_range(0, 300_000)  # covers 10 uncached blocks
    assert got == payload[:300_000]
    assert r.stats.blocks_fetched == 10
    assert r.file_reads == 1  # one seek+read for the whole contiguous run


def test_coalescing_splits_around_cached_blocks(tmp_path):
    payload = os.urandom(10 * 8192)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=8192)
    r = BlockReader(path)
    r.get_block(4)  # warm the middle block
    assert r.file_reads == 1
    out = r.read_range(0, len(payload))
    assert out == payload
    # blocks 0-3 and 5-9 are two contiguous uncached runs
    assert r.file_reads == 3
    assert r.stats.blocks_fetched == 10  # accounting identical to per-block path


def test_coalesced_stats_match_per_block_path(tmp_path):
    payload = os.urandom(123_456)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=4096)
    a, b = BlockReader(path), BlockReader(path)
    a.read_range(1000, 100_000)  # coalesced
    first, last = b.manifest.block_range_for(1000, 100_000)
    b.stats.useful_bytes += 100_000
    for i in range(first, last + 1):  # the old per-block fetch order
        b.get_block(i)
    assert (a.stats.useful_bytes, a.stats.fetched_compressed,
            a.stats.fetched_raw, a.stats.blocks_fetched) == (
        b.stats.useful_bytes, b.stats.fetched_compressed,
        b.stats.fetched_raw, b.stats.blocks_fetched)
    assert a.stats.amplification() == b.stats.amplification()


# ----------------------------------------------------------------------
# PR 8: guard + codec-default bugfixes, edge-case coverage
# ----------------------------------------------------------------------
def test_negative_length_rejected(tmp_path):
    """Regression: read_range(5, -3) used to pass the guard and *decrement*
    useful_bytes, corrupting amplification()."""
    payload = os.urandom(10_000)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=1024)
    r = BlockReader(path)
    r.read_range(0, 1000)
    before = r.stats.useful_bytes
    with pytest.raises(ValueError):
        r.read_range(5, -3)
    assert r.stats.useful_bytes == before  # stats untouched by the rejection
    assert r.read_range(5, 0) == b""  # zero length stays a valid no-op


def test_default_level_is_per_codec(tmp_path, monkeypatch):
    """Regression: level defaulted to zstd's 3 and was forced onto the zlib
    fallback, under-compressing vs _ZlibCodec's documented default 6."""
    from repro.core import blockstore as bs

    payload = (bytes(range(256)) * 2000) + os.urandom(100_000)
    default = str(tmp_path / "default.blocks")
    pinned6 = str(tmp_path / "pinned6.blocks")
    m_default = write_blockstore(payload, default, block_size=64 * 1024, codec="zlib")
    m_pinned = write_blockstore(
        payload, pinned6, block_size=64 * 1024, codec="zlib", level=6
    )
    # zlib default is 6: an unpinned write must match an explicit level-6 one
    # (the old code silently wrote level 3 here).
    assert m_default.offsets == m_pinned.offsets
    import zlib

    blob = payload[: 64 * 1024]
    assert m_default.block_compressed_size(0) == len(zlib.compress(blob, 6))
    if have_zstd():
        m_zstd = write_blockstore(payload, str(tmp_path / "z.blocks"), block_size=64 * 1024, codec="zstd")
        m_zstd3 = write_blockstore(
            payload, str(tmp_path / "z3.blocks"), block_size=64 * 1024, codec="zstd", level=3
        )
        assert m_zstd.offsets == m_zstd3.offsets  # zstd default is 3


def test_empty_payload_roundtrip(tmp_path):
    path = str(tmp_path / "empty.blocks")
    m = write_blockstore(b"", path, block_size=1024)
    assert m.raw_size == 0
    assert m.n_blocks == 1  # format always carries >= 1 block
    r = BlockReader(path)
    assert r.read_all() == b""
    assert r.read_range(0, 0) == b""


def test_read_range_at_exact_block_boundaries(tmp_path):
    payload = bytes(range(256)) * 64  # 16 KiB
    bs = 4096
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=bs)
    r = BlockReader(path)
    # exactly one block, starting on a boundary
    assert r.read_range(bs, bs) == payload[bs : 2 * bs]
    assert r.stats.blocks_fetched == 1
    # range ending exactly on a boundary must not touch the next block
    r2 = BlockReader(path)
    assert r2.read_range(0, bs) == payload[:bs]
    assert r2.stats.blocks_fetched == 1
    # one byte past the boundary pulls exactly one extra block
    r3 = BlockReader(path)
    assert r3.read_range(0, bs + 1) == payload[: bs + 1]
    assert r3.stats.blocks_fetched == 2


def test_closed_reader_read_range_raises(tmp_path):
    payload = os.urandom(10_000)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=1024)
    r = BlockReader(path)
    r.close()
    with pytest.raises(ValueError):
        r.read_range(0, 100)


def test_fetch_run_splits_on_cached_hole(tmp_path):
    """_fetch_run over [0..9] with block 5 cached must issue two coalesced
    file reads (0-4 and 6-9), not ten."""
    payload = os.urandom(10 * 4096)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=4096)
    r = BlockReader(path)
    r.get_block(5)
    reads_before = r.file_reads
    r._fetch_run(0, 9)
    assert r.file_reads - reads_before == 2
    assert r.stats.blocks_fetched == 10
    assert r.read_range(0, len(payload)) == payload  # all cached now
    assert r.stats.blocks_fetched == 10  # and no refetches


def test_reader_close_and_context_manager(tmp_path):
    payload = os.urandom(50_000)
    path = str(tmp_path / "p.blocks")
    write_blockstore(payload, path, block_size=8192)
    with BlockReader(path) as r:
        assert r.read_range(0, 1000) == payload[:1000]
    with pytest.raises(ValueError):
        r.fetch_block_compressed(0)  # closed handle refuses cleanly
    r.close()  # idempotent
