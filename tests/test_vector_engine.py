"""VectorFlowSim: differential verification against the other engines.

The vector engine is the third member of the oracle chain (``reference`` →
``incremental`` → ``vector`` → ``vector_jax``, see
``repro.sim.engine.ENGINES``) and is held to a *stricter* bar than the
incremental engine was:

  * against the incremental engine it must be **bit-identical** — event
    logs compare equal as exact floats (run_scale trace, provision-wave
    latencies, TraceReplay TickStats) and peak-egress telemetry matches
    exactly;
  * against the reference oracle it must agree to ±1e-9 on completion
    times and peak egress, like the incremental engine does;
  * the ``vector_jax`` tier (fused pallas cap-chain kernel; numpy fallback
    when jax is absent) must be bit-identical to ``vector`` — and both
    must be cutoff-invariant: forcing every ready front down the wide
    vectorized/pallas path (``vector_scalar_cutoff=0``) may not change a
    single bit.

Randomized plans + churn (seeded always; hypothesis variant when the
package is installed) drive the engines through the same scenarios,
including mid-flight ``set_parent`` and slow-VM re-rating.  The
``_done_heap`` compaction satellite is pinned here for both heap-based
engines; the wide-front dispatch telemetry (``dispatch_stats``) is fuzzed
for internal consistency against event counts.
"""
import dataclasses
import random

import pytest

from repro.core import FunctionTree
from repro.core.topology import (
    REGISTRY,
    DistributionPlan,
    Flow,
    baseline_plan,
    faasnet_plan,
    kraken_plan,
    on_demand_plan,
)
from repro.kernels.cap_chain import have_jax
from repro.sim import ScaleConfig, WaveConfig, provision_wave, run_scale
from repro.sim.engine import ENGINES, FlowSim, SimConfig, make_sim
from repro.sim.reference import ReferenceFlowSim
from repro.sim.vector_engine import VectorFlowSim, VectorJaxFlowSim

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False

MB = 1e6
REL_TOL = 1e-9


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(1.0, abs(a), abs(b))


def _wave_simconfig(**kw) -> SimConfig:
    base = dict(per_stream_cap=30 * MB, hop_latency=0.2, registry_qps=1100.0)
    base.update(kw)
    return SimConfig(**base)


def _run_engine(cls, plan, cfg, *, slow_vms=None):
    sim = cls(cfg, record_rates=True)
    for vm, cap in (slow_vms or {}).items():
        sim.set_slow_vm(vm, cap)
    states = sim.add_plan(plan)
    sim.run()
    return sim, states


def _assert_bit_identical(inc, inc_states, other, other_states):
    """``other`` (a vector-family engine) matches ``inc`` exactly."""
    assert other.now == inc.now
    assert other.trace == inc.trace
    assert other.events_processed == inc.events_processed
    assert other.completion_times() == inc.completion_times()
    assert other.peak_registry_egress == inc.peak_registry_egress
    assert other.peak_shard_egress == inc.peak_shard_egress
    assert other.peak_nic_utilization == inc.peak_nic_utilization
    for a, b in zip(other_states, inc_states):
        assert a.flow == b.flow
        assert a.t_start == b.t_start and a.t_done == b.t_done
        assert a.remaining == b.remaining and a.rate == b.rate


def _base_stats(sim):
    """dispatch_stats minus the jax-only counters (subset of vector's)."""
    return {
        k: v
        for k, v in sim.dispatch_stats.items()
        if k not in ("fronts_jax", "flows_jax")
    }


def _assert_four_way(plan, cfg: SimConfig, *, slow_vms=None):
    """One plan through all four engines: pairwise agreement.

    vector and vector_jax vs incremental are exact (same floats); vector
    vs reference is ±1e-9 — the reference engine re-rates after every
    single event, so a batch of same-instant completions can take a
    microscopically different arithmetic path.  Both vector tiers are also
    re-run with ``vector_scalar_cutoff=0`` so every ready front takes the
    wide vectorized (resp. pallas, when jax is present) path — the cutoff
    is a pure performance knob and may not change a single bit.
    """
    inc, inc_states = _run_engine(FlowSim, plan, cfg, slow_vms=slow_vms)
    vec, vec_states = _run_engine(VectorFlowSim, plan, cfg, slow_vms=slow_vms)
    ref, ref_states = _run_engine(ReferenceFlowSim, plan, cfg, slow_vms=slow_vms)
    jx, jx_states = _run_engine(VectorJaxFlowSim, plan, cfg, slow_vms=slow_vms)

    # vector / vector_jax vs incremental: bit-identical
    _assert_bit_identical(inc, inc_states, vec, vec_states)
    _assert_bit_identical(inc, inc_states, jx, jx_states)
    assert _base_stats(jx) == _base_stats(vec)

    # cutoff invariance: every front forced down the wide path
    wide = dataclasses.replace(cfg, vector_scalar_cutoff=0)
    vec0, vec0_states = _run_engine(VectorFlowSim, plan, wide, slow_vms=slow_vms)
    jx0, jx0_states = _run_engine(VectorJaxFlowSim, plan, wide, slow_vms=slow_vms)
    _assert_bit_identical(inc, inc_states, vec0, vec0_states)
    _assert_bit_identical(inc, inc_states, jx0, jx0_states)
    s, s0 = vec.dispatch_stats, vec0.dispatch_stats
    # the front decomposition is cutoff-independent; only the path differs
    assert s0["fronts_scalar"] == 0 and s0["flows_scalar"] == 0
    assert s0["fronts_vector"] == s["fronts_scalar"] + s["fronts_vector"]
    assert s0["front_width_hist"] == s["front_width_hist"]
    if jx0.jax_active:
        # with jax present, every wide front went through the pallas kernel
        assert jx0.dispatch_stats["fronts_jax"] == s0["fronts_vector"]
        assert jx0.dispatch_stats["flows_jax"] == s0["flows_vector"]

    # vector vs reference: 1e-9 completion times + peak egress
    assert _close(vec.now, ref.now)
    for a, b in zip(vec_states, ref_states):
        assert a.flow == b.flow
        assert a.done and b.done
        assert _close(a.t_start, b.t_start), (a.flow, a.t_start, b.t_start)
        assert _close(a.t_done, b.t_done), (a.flow, a.t_done, b.t_done)
    assert _close(vec.peak_registry_egress, ref.peak_registry_egress)
    assert set(vec.peak_shard_egress) == set(ref.peak_shard_egress)
    for k, v in vec.peak_shard_egress.items():
        assert _close(v, ref.peak_shard_egress[k]), (k, v)
    return vec


# ----------------------------------------------------------------------
# Canonical topologies through all four engines
# ----------------------------------------------------------------------
def test_four_way_faasnet_tree():
    ft = FunctionTree("f")
    for i in range(15):
        ft.insert(f"vm{i}")
    plan = faasnet_plan(ft, image_bytes=int(100 * MB), startup_fraction=0.2)
    _assert_four_way(plan, _wave_simconfig())


def test_four_way_faasnet_tree_with_straggler():
    ft = FunctionTree("f")
    for i in range(15):
        ft.insert(f"vm{i}")
    plan = faasnet_plan(ft, image_bytes=int(100 * MB), startup_fraction=0.2)
    _assert_four_way(plan, _wave_simconfig(), slow_vms={"vm1": 2 * MB})


def test_four_way_registry_star():
    plan = on_demand_plan(
        [f"vm{i}" for i in range(16)],
        image_bytes=int(100 * MB),
        startup_fraction=0.2,
    )
    _assert_four_way(plan, _wave_simconfig())


def test_four_way_kraken_mesh():
    plan = kraken_plan(
        [f"vm{i}" for i in range(12)],
        layer_bytes=[int(10 * MB)] * 4,
        origin="origin",
        seed=7,
    )
    _assert_four_way(plan, _wave_simconfig(coordinator_cost_s=0.070))


def test_four_way_sharded_registry():
    from repro.core.registry import RegistrySpec

    spec = RegistrySpec(shards=3, egress_cap=2.0 * 125e6, qps=500.0)
    plan = on_demand_plan(
        [f"vm{i}" for i in range(18)],
        image_bytes=int(60 * MB),
        startup_fraction=0.25,
        registry=spec,
    )
    _assert_four_way(plan, _wave_simconfig(registry=spec))


# ----------------------------------------------------------------------
# Golden bit-identity with engine="vector" on the existing goldens
# ----------------------------------------------------------------------
def test_provision_wave_golden_all_systems():
    from repro.sim import SYSTEMS

    for system in SYSTEMS:
        a = provision_wave(system, 32, WaveConfig())
        b = provision_wave(system, 32, WaveConfig(engine="vector"))
        assert a == b, system


def test_run_scale_trace_sha_golden():
    """The pinned run_scale event-log SHA-256 holds under engine="vector"."""
    import hashlib

    cfg = ScaleConfig(
        n_vms=32,
        n_functions=4,
        containers_per_function=8,
        churn_ops=5,
        seed=3,
        wave=WaveConfig(engine="vector"),
    )
    res = run_scale(cfg)
    digest = hashlib.sha256(
        "\n".join(f"{t!r} {e}" for t, e in res.trace).encode()
    ).hexdigest()
    assert (
        digest == "bb5965a1fa885edd0aaf968dfec9bad59941edf5c13a367d869ed2eea7954c82"
    )
    assert res.engine == "vector"


def test_trace_replay_tickstats_identical():
    """TickStats bit-identical across engines on a short trace replay."""
    from repro.sim import ReplayConfig, TraceReplay
    from repro.sim.traces import iot_trace

    trace = iot_trace(scale=0.2)[: 4 * 60]
    out = {}
    for eng in ("incremental", "vector"):
        tl = TraceReplay(
            ReplayConfig(
                system="faasnet",
                idle_reclaim_s=120,
                vm_pool_size=60,
                wave=WaveConfig(engine=eng),
            )
        ).run(trace)
        out[eng] = [repr(ts) for ts in tl]
    assert out["incremental"] == out["vector"]


# ----------------------------------------------------------------------
# Engine selection seam
# ----------------------------------------------------------------------
def test_make_sim_selects_backend():
    assert isinstance(make_sim(SimConfig()), FlowSim)
    assert isinstance(make_sim(SimConfig(engine="vector")), VectorFlowSim)
    assert isinstance(make_sim(SimConfig(engine="reference")), ReferenceFlowSim)
    jx = make_sim(SimConfig(engine="vector_jax"))
    assert isinstance(jx, VectorJaxFlowSim)
    assert isinstance(jx, VectorFlowSim)  # subclass: shares the whole engine
    assert jx.jax_active == have_jax()  # graceful numpy fallback otherwise
    assert set(ENGINES) == {"incremental", "vector", "vector_jax", "reference"}


def test_make_sim_rejects_unknown_engine():
    with pytest.raises(ValueError, match="unknown engine"):
        make_sim(SimConfig(engine="gpu"))


def test_giga_burst_config_shape():
    """Fast sanity: the giga tier is 100× the paper's §4.2 burst."""
    from repro.sim import giga_burst_config

    cfg = giga_burst_config()
    assert cfg.n_vms == 100_000
    assert cfg.total_containers() == 1_000_000
    assert cfg.stagger_s > 0  # burst train, not one instant
    assert cfg.wave.engine == "vector"
    assert cfg.wave.record_trace is False
    assert cfg.max_functions_per_vm >= cfg.n_functions


# ----------------------------------------------------------------------
# Mid-flight mutation paths
# ----------------------------------------------------------------------
def test_set_parent_mid_flight_matches_incremental():
    results = []
    for cls in (FlowSim, VectorFlowSim, ReferenceFlowSim):
        sim = cls(SimConfig(registry_out_cap=5e6))
        [p] = sim.add_plan(
            DistributionPlan(
                flows=[Flow(REGISTRY, "A", "img", 200_000_000)], streaming=False
            )
        )
        [c] = sim.add_plan(
            DistributionPlan(
                flows=[Flow("A", "B", "img", 125_000_000)], streaming=False
            )
        )
        sim.run(until=0.1)  # both flows start, uncapped
        sim.set_parent(c, p)  # the TraceReplay mid-flight attach path
        sim.run()
        results.append(c.t_done)
    inc, vec, ref = results
    assert vec == inc  # bit-identical
    assert _close(vec, ref)
    assert vec > 20.0  # capped at the parent's 5 MB/s


def test_slow_vm_injected_mid_run_matches():
    """set_slow_vm / clear_slow_vm while flows are live re-rates identically."""
    ft = FunctionTree("f")
    for i in range(15):
        ft.insert(f"vm{i}")
    plan = faasnet_plan(ft, image_bytes=int(200 * MB), startup_fraction=0.2)
    times = {}
    for name, cls in (("inc", FlowSim), ("vec", VectorFlowSim)):
        sim = cls(_wave_simconfig())
        sim.add_plan(plan)
        sim.run(until=1.0)
        sim.set_slow_vm("vm0", 1 * MB)
        sim.run(until=2.0)
        sim.clear_slow_vm("vm0")
        sim.run()
        times[name] = (sim.now, sim.completion_times(), sim.trace)
    assert times["inc"] == times["vec"]


# ----------------------------------------------------------------------
# Satellite: _done_heap compaction under repeated re-rating
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cls", [FlowSim, VectorFlowSim])
def test_done_heap_stays_bounded_under_rerating(cls):
    """Churny rate flapping must not grow the completion heap unboundedly.

    Every re-rate pushes a fresh ``(t, fid, epoch)`` entry; before the
    compaction fix the stale ones survived until they surfaced at the heap
    head, so N re-rates of K flows held O(N*K) entries live.  Now the heap
    is compacted once stale entries exceed ~4x the live flows.
    """
    sim = cls(SimConfig())
    plan = baseline_plan([f"vm{i}" for i in range(32)], image_bytes=10**12)
    sim.add_plan(plan)
    sim.run(until=0.1)  # everything started, far from completion
    n_active = sum(1 for f in sim._flows if f.started and not f.done)
    assert n_active == 32
    for k in range(200):
        # flap the shared source: every flow re-rates twice per iteration
        sim.set_slow_vm("vm0", (1 + k % 7) * MB)
        sim.run(until=0.1 + (k + 1) * 1e-6)
    sim.clear_slow_vm("vm0")
    bound = max(64, 4 * n_active) + n_active  # one batch may land pre-compaction
    assert len(sim._done_heap) <= bound, (len(sim._done_heap), bound)
    sim.run()  # still terminates correctly
    assert all(f.done for f in sim._flows)


def test_done_heap_compaction_preserves_results():
    """Same flapping scenario: compacting engines agree with the reference."""
    plan = baseline_plan([f"vm{i}" for i in range(8)], image_bytes=int(50 * MB))
    ends = []
    for cls in (FlowSim, VectorFlowSim, ReferenceFlowSim):
        sim = cls(SimConfig())
        sim.add_plan(plan)
        for k in range(40):
            sim.run(until=0.01 * (k + 1))
            sim.set_slow_vm("vm0", (1 + k % 5) * 20 * MB)
        sim.clear_slow_vm("vm0")
        sim.run()
        ends.append((sim.now, sim.completion_times()))
    assert ends[0] == ends[1]  # incremental == vector, exact
    assert _close(ends[0][0], ends[2][0])


# ----------------------------------------------------------------------
# Event-queue internals: the bulk fold path
# ----------------------------------------------------------------------
def test_bulk_event_fold_matches_incremental():
    """>2048 scheduled starts exercise the sorted-snapshot fold path."""
    plan = baseline_plan([f"vm{i}" for i in range(2500)], image_bytes=int(5 * MB))
    out = []
    for cls in (FlowSim, VectorFlowSim):
        sim = cls(SimConfig())
        sim.add_plan(plan)
        sim.run()
        out.append((sim.now, sim.events_processed, sim.completion_times()))
    assert out[0] == out[1]


def test_interleaved_add_plan_and_run():
    """Waves added between runs land in heap + snapshot; order must hold."""
    out = []
    for cls in (FlowSim, VectorFlowSim):
        sim = cls(_wave_simconfig())
        for wave in range(3):
            ft = FunctionTree(f"f{wave}")
            for i in range(10):
                ft.insert(f"w{wave}vm{i}")
            plan = faasnet_plan(
                ft,
                image_bytes=int(40 * MB),
                startup_fraction=0.2,
                piece=f"f{wave}",
            )
            sim.add_plan(plan, t0=0.5 * wave)
            sim.run(until=0.5 * wave + 0.25)
        sim.run()
        out.append((sim.now, sim.trace, sim.completion_times()))
    assert out[0] == out[1]


# ----------------------------------------------------------------------
# Randomized differential suite (seeded always; hypothesis when present)
# ----------------------------------------------------------------------
def _random_plan(rng: random.Random, n_nodes: int) -> DistributionPlan:
    nodes = [f"vm{i}" for i in range(n_nodes)]
    flows = []
    for i, n in enumerate(nodes):
        src = REGISTRY if i == 0 or rng.random() < 0.3 else nodes[rng.randrange(i)]
        flows.append(Flow(src, n, "img", rng.randrange(1_000_000, 50_000_000)))
    return DistributionPlan(
        flows=flows,
        control_latency={n: rng.random() * 0.05 for n in nodes},
        streaming=bool(rng.getrandbits(1)),
    )


def _churned_run(cls, plan, cfg, churn_script):
    """Run a plan with a deterministic mid-flight churn script applied."""
    sim = cls(cfg)
    sim.add_plan(plan)
    for t, vm, cap in churn_script:
        sim.run(until=t)
        if cap is None:
            sim.clear_slow_vm(vm)
        else:
            sim.set_slow_vm(vm, cap)
    sim.run()
    return sim


def test_random_plan_churn_four_way_fuzz():
    for seed in range(6):
        rng = random.Random(1000 + seed)
        plan = _random_plan(rng, 12)
        churn = []
        for k in range(rng.randrange(4)):
            vm = f"vm{rng.randrange(12)}"
            cap = None if rng.random() < 0.3 else rng.uniform(1, 40) * MB
            churn.append((0.2 + 0.3 * k, vm, cap))
        cfg = _wave_simconfig()
        inc = _churned_run(FlowSim, plan, cfg, churn)
        vec = _churned_run(VectorFlowSim, plan, cfg, churn)
        ref = _churned_run(ReferenceFlowSim, plan, cfg, churn)
        jx = _churned_run(VectorJaxFlowSim, plan, cfg, churn)
        assert vec.trace == inc.trace, seed
        assert vec.completion_times() == inc.completion_times(), seed
        assert vec.peak_shard_egress == inc.peak_shard_egress, seed
        assert jx.trace == vec.trace, seed
        assert jx.completion_times() == vec.completion_times(), seed
        assert _base_stats(jx) == _base_stats(vec), seed
        ct_v, ct_r = vec.completion_times(), ref.completion_times()
        assert set(ct_v) == set(ct_r), seed
        for k, v in ct_v.items():
            assert _close(v, ct_r[k]), (seed, k, v, ct_r[k])
        assert _close(vec.peak_registry_egress, ref.peak_registry_egress), seed


def test_dispatch_telemetry_fuzz_consistency():
    """Seeded fuzz: dispatch telemetry is internally consistent and
    consistent with event counts on every scenario.

    Invariants pinned (see ``VectorFlowSim._recompute``):
      * every counted recompute processed at least one front, every front
        at least one flow;
      * fronts never exceed ``legacy_levels`` — the per-depth sweeps the
        retired algorithm would have dispatched on the same closures (that
        inequality *is* the wide-front claim);
      * the width histogram buckets (keyed by ``width.bit_length()``)
        account for every front and bound the flow totals;
      * recomputes are driven by events and churn only.
    """
    for seed in range(8):
        rng = random.Random(4242 + seed)
        n_nodes = rng.randrange(8, 40)
        plan = _random_plan(rng, n_nodes)
        churn = []
        for k in range(rng.randrange(4)):
            vm = f"vm{rng.randrange(n_nodes)}"
            cap = None if rng.random() < 0.3 else rng.uniform(1, 40) * MB
            churn.append((0.2 + 0.3 * k, vm, cap))
        cutoff = rng.choice([0, 2, 64])
        cfg = _wave_simconfig(vector_scalar_cutoff=cutoff)
        vec = _churned_run(VectorFlowSim, plan, cfg, churn)
        s = vec.dispatch_stats
        fronts = s["fronts_scalar"] + s["fronts_vector"]
        flows = s["flows_scalar"] + s["flows_vector"]
        assert s["recompute_calls"] >= 1, seed
        assert fronts >= s["recompute_calls"], seed
        assert flows >= fronts, seed
        assert s["legacy_levels"] >= fronts, seed  # the wide-front claim
        hist = s["front_width_hist"]
        assert sum(hist.values()) == fronts, seed
        assert all(b >= 1 for b in hist), seed  # fronts are never empty
        lo = sum(c * (1 << (b - 1) if b > 1 else 1) for b, c in hist.items())
        hi = sum(c * ((1 << b) - 1) for b, c in hist.items())
        assert lo <= flows <= hi, (seed, lo, flows, hi)
        if cutoff == 0:
            assert s["fronts_scalar"] == 0, seed
        # recomputes fire only after event batches, plan adds, or churn ops
        assert (
            s["recompute_calls"] <= vec.events_processed + len(churn) + 2
        ), (seed, s["recompute_calls"], vec.events_processed)


def test_blocks_on_warm_cache_four_way():
    """Blocks-on provisioning with a warm block cache: four-way agreement.

    Block-granular flows exercise the QPS-throttle leg of the cap chain
    (`block_size * qps / n_out`), and a warm cache makes the plans sparse
    and irregular — the worst case for front batching.
    """
    from repro.core import BlockCache, faasnet_block_plan, shared_base_images

    imgs = shared_base_images(6, 2, image_bytes=int(48 * MB))
    results = {}
    for name, cls in (
        ("inc", FlowSim),
        ("vec", VectorFlowSim),
        ("jax", VectorJaxFlowSim),
    ):
        for cutoff in (0, 64):
            if name == "inc" and cutoff == 0:
                continue  # the knob only exists on the vector tiers
            sim = cls(SimConfig(record_trace=True, vector_scalar_cutoff=cutoff))
            cache = BlockCache()
            cache.add_image("seed", imgs[0])  # warm: base layers resident
            runnable, done = {}, {}
            for i, img in enumerate(imgs):
                ft = FunctionTree(img.name)
                for v in (f"f{i}a", f"f{i}b", f"f{i}c"):
                    ft.insert(v)
                sim.add_plan(
                    faasnet_block_plan(ft, image=img, cache=cache),
                    t0=0.01 * i,
                    on_node_done=lambda vm, t, i=i: done.__setitem__(
                        (i, vm), max(done.get((i, vm), 0.0), t)
                    ),
                    on_node_runnable=lambda vm, t, i=i: runnable.setdefault(
                        (i, vm), t
                    ),
                )
            sim.run()
            results[(name, cutoff)] = (
                runnable,
                done,
                sim.now,
                sim.events_processed,
                sim.trace,
            )
    base = results[("inc", 64)]
    for key, got in results.items():
        assert got == base, key


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        n_nodes=st.integers(min_value=2, max_value=16),
        n_churn=st.integers(min_value=0, max_value=3),
    )
    def test_hypothesis_three_way_equivalence(seed, n_nodes, n_churn):
        rng = random.Random(seed)
        plan = _random_plan(rng, n_nodes)
        churn = []
        for k in range(n_churn):
            vm = f"vm{rng.randrange(n_nodes)}"
            cap = None if rng.random() < 0.3 else rng.uniform(1, 40) * MB
            churn.append((0.15 + 0.25 * k, vm, cap))
        cfg = _wave_simconfig()
        inc = _churned_run(FlowSim, plan, cfg, churn)
        vec = _churned_run(VectorFlowSim, plan, cfg, churn)
        ref = _churned_run(ReferenceFlowSim, plan, cfg, churn)
        assert vec.trace == inc.trace
        assert vec.completion_times() == inc.completion_times()
        assert vec.peak_registry_egress == inc.peak_registry_egress
        assert vec.peak_shard_egress == inc.peak_shard_egress
        ct_v, ct_r = vec.completion_times(), ref.completion_times()
        assert set(ct_v) == set(ct_r)
        for k, v in ct_v.items():
            assert _close(v, ct_r[k])
