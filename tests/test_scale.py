"""Scale harness: golden determinism + incremental-vs-reference equivalence.

Two safety nets for the incremental-rate engine refactor:

  * *golden determinism* — the scale scenario run twice produces bit-identical
    event logs and makespans (the engine's (time, seq) + fid ordering);
  * *differential equivalence* — on a FaaSNet tree, a registry star and a
    Kraken mesh, the incremental engine's per-flow rate trajectories and
    completion times match the old full-recompute oracle
    (:class:`repro.sim.reference.ReferenceFlowSim`) to ±1e-9.

The full 2500-containers / 1000-VM burst is marked ``slow`` (run with
``--runslow``); ``benchmarks/bench_scale_1000.py`` is its CLI twin.
"""
import random
import time

import pytest

from repro.core import FunctionTree
from repro.core.topology import faasnet_plan, kraken_plan, on_demand_plan
from repro.sim import ScaleConfig, run_scale
from repro.sim.engine import FlowSim, SimConfig
from repro.sim.reference import ReferenceFlowSim

MB = 1e6

REL_TOL = 1e-9


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= REL_TOL * max(1.0, abs(a), abs(b))


def _collapse(entries, t_done: float):
    """Reduce a per-flow [(t, rate), ...] log to its piecewise-constant form.

    The reference engine recomputes after *every* event, so a batch of
    same-timestamp completions logs several intermediate rates where the
    incremental engine logs one — including a zero-duration "bump" for a
    flow whose sibling finished at the very instant it did.  Keep only the
    final rate at each distinct timestamp, drop entries at/after the flow's
    own completion (they transport no bytes), then drop no-op repeats —
    what remains is the trajectory as a function of time, which both
    engines must agree on.
    """
    out = []
    for t, r in entries:
        if t >= t_done or _close(t, t_done):
            continue
        if out and _close(out[-1][0], t):
            out[-1] = (out[-1][0], r)
        else:
            out.append((t, r))
    dedup = []
    for t, r in out:
        if dedup and _close(dedup[-1][1], r):
            continue
        dedup.append((t, r))
    return dedup


def _assert_equivalent(plan, cfg: SimConfig, *, slow_vms=None):
    """Run one plan through both engines; rates and times must match."""
    sims = []
    for cls in (FlowSim, ReferenceFlowSim):
        sim = cls(cfg, record_rates=True)
        for vm, cap in (slow_vms or {}).items():
            sim.set_slow_vm(vm, cap)
        states = sim.add_plan(plan)
        sim.run()
        sims.append((sim, states))
    (inc, inc_states), (ref, ref_states) = sims
    assert _close(inc.now, ref.now), (inc.now, ref.now)
    assert len(inc_states) == len(ref_states)
    for a, b in zip(inc_states, ref_states):
        assert a.flow == b.flow
        assert a.done and b.done, (a.flow, a.done, b.done)
        assert _close(a.t_start, b.t_start), (a.flow, a.t_start, b.t_start)
        assert _close(a.t_done, b.t_done), (a.flow, a.t_done, b.t_done)
    # per-flow rate trajectories
    by_fid_inc: dict[int, list] = {}
    by_fid_ref: dict[int, list] = {}
    for t, fid, r in inc.rate_log:
        by_fid_inc.setdefault(fid, []).append((t, r))
    for t, fid, r in ref.rate_log:
        by_fid_ref.setdefault(fid, []).append((t, r))
    for fid in range(len(inc_states)):
        t_done = inc_states[fid].t_done
        ta = _collapse(by_fid_inc.get(fid, []), t_done)
        tb = _collapse(by_fid_ref.get(fid, []), t_done)
        assert len(ta) == len(tb), (fid, ta, tb)
        for (t1, r1), (t2, r2) in zip(ta, tb):
            assert _close(t1, t2), (fid, t1, t2)
            assert _close(r1, r2), (fid, r1, r2)


def _wave_simconfig(**kw) -> SimConfig:
    base = dict(
        per_stream_cap=30 * MB,
        hop_latency=0.2,
        registry_qps=1100.0,
    )
    base.update(kw)
    return SimConfig(**base)


# ----------------------------------------------------------------------
# Equivalence on the three canonical topologies
# ----------------------------------------------------------------------
def test_equivalence_faasnet_tree():
    ft = FunctionTree("f")
    for i in range(15):
        ft.insert(f"vm{i}")
    plan = faasnet_plan(ft, image_bytes=int(100 * MB), startup_fraction=0.2)
    _assert_equivalent(plan, _wave_simconfig())


def test_equivalence_faasnet_tree_with_straggler():
    ft = FunctionTree("f")
    for i in range(15):
        ft.insert(f"vm{i}")
    plan = faasnet_plan(ft, image_bytes=int(100 * MB), startup_fraction=0.2)
    _assert_equivalent(plan, _wave_simconfig(), slow_vms={"vm1": 2 * MB})


def test_equivalence_registry_star():
    plan = on_demand_plan(
        [f"vm{i}" for i in range(16)],
        image_bytes=int(100 * MB),
        startup_fraction=0.2,
    )
    _assert_equivalent(plan, _wave_simconfig())


def test_equivalence_kraken_mesh():
    plan = kraken_plan(
        [f"vm{i}" for i in range(12)],
        layer_bytes=[int(10 * MB)] * 4,
        origin="origin",
        seed=7,
    )
    _assert_equivalent(plan, _wave_simconfig(coordinator_cost_s=0.070))


# ----------------------------------------------------------------------
# Golden determinism of the scale scenario
# ----------------------------------------------------------------------
def _small_cfg(seed=3) -> ScaleConfig:
    return ScaleConfig(
        n_vms=32, n_functions=8, containers_per_function=8, churn_ops=10, seed=seed
    )


def test_scale_golden_determinism():
    """Two runs of the same config: bit-identical event logs and makespan."""
    a = run_scale(_small_cfg())
    b = run_scale(_small_cfg())
    assert a.trace == b.trace  # full (time, event) log, exact float equality
    assert a.makespan == b.makespan
    assert a.per_function == b.per_function
    assert a.events == b.events
    assert a.peak_registry_egress == b.peak_registry_egress


def test_scale_seed_changes_trace():
    """Different seeds genuinely change the scenario (no vacuous golden test)."""
    a = run_scale(_small_cfg(seed=3))
    b = run_scale(_small_cfg(seed=4))
    assert a.trace != b.trace


def test_scale_churn_fires_reparents_and_keeps_invariants():
    cfg = _small_cfg()
    res = run_scale(cfg)
    assert res.reparents > 0  # churn really exercised AVL repair
    assert res.n_containers == cfg.total_containers()
    for st in res.tree_stats.values():
        assert st["size"] == cfg.containers_per_function


def test_scale_all_functions_complete():
    res = run_scale(_small_cfg())
    assert set(res.per_function) == {f"fn{i}" for i in range(8)}
    assert all(t > 0 for t in res.per_function.values())
    assert res.provision_makespan > res.makespan


# ----------------------------------------------------------------------
# The paper-scale burst (gated: ~0.3 s today, but guards the perf budget)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_scale_1000_vm_burst_under_budget():
    """Paper §4.2 shape: 2500 containers / 1000 VMs, one CPU core, < 60 s."""
    t0 = time.perf_counter()
    res = run_scale(ScaleConfig(churn_ops=100))
    wall = time.perf_counter() - t0
    assert res.n_containers == 2500
    assert wall < 60.0, f"scale harness took {wall:.1f} s"
    # network fetch makespan in the provisioning regime the paper reports
    assert 4.0 < res.makespan < 30.0, res.makespan
    assert res.peak_registry_egress > 0


@pytest.mark.slow
def test_scale_1000_vm_deterministic():
    a = run_scale(ScaleConfig(churn_ops=50, seed=11))
    b = run_scale(ScaleConfig(churn_ops=50, seed=11))
    assert a.makespan == b.makespan
    assert a.trace == b.trace


# ----------------------------------------------------------------------
# The 10× mega-burst (PR 2): 10k VMs / 25 functions / 100k containers
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_scale_10k_mega_burst_under_budget():
    """10× paper scale end-to-end in < 30 s: the O(log n) control plane plus
    the incremental engine.  The seed BFS-scan control plane alone would
    blow this budget standing up the trees."""
    from repro.sim.scale import mega_burst_config

    t0 = time.perf_counter()
    res = run_scale(mega_burst_config())
    wall = time.perf_counter() - t0
    assert res.n_containers == 100_000
    assert wall < 30.0, f"mega burst took {wall:.1f} s"
    # control-plane build of 25 × 4000-node trees must stay sub-linear-ish
    assert res.build_s < 5.0, f"control-plane build took {res.build_s:.1f} s"
    assert res.churn_op_s < 0.001, f"churn op latency {res.churn_op_s*1e3:.2f} ms"
    assert res.reparents > 0
    # every tree is a 4000-node AVL: height must be logarithmic (<= 1.44 log2 n)
    for st in res.tree_stats.values():
        assert st["size"] == 4000
        assert st["height"] <= 18
    assert 4.0 < res.makespan < 120.0, res.makespan
    assert res.peak_registry_egress > 0


def test_mega_burst_config_shape():
    """Fast sanity: the mega config is 10× the paper's §4.2 burst."""
    from repro.sim.scale import mega_burst_config

    cfg = mega_burst_config()
    assert cfg.n_vms == 10_000
    assert cfg.total_containers() == 100_000
    assert cfg.max_functions_per_vm >= cfg.n_functions  # placement can't wedge


def test_scale_result_reports_control_plane_timings():
    res = run_scale(_small_cfg())
    assert res.build_s > 0.0
    assert res.churn_s > 0.0 and res.churn_op_s > 0.0


# ----------------------------------------------------------------------
# Incremental engine internals worth pinning
# ----------------------------------------------------------------------
def test_same_timestamp_completions_batched():
    """A symmetric star completes all flows in one settle pass."""
    from repro.core.topology import baseline_plan

    sim = FlowSim(SimConfig())
    plan = baseline_plan([f"vm{i}" for i in range(8)], image_bytes=10_000_000)
    sim.add_plan(plan)
    sim.run()
    done_times = {f.t_done for f in sim._flows}
    assert len(done_times) == 1  # all end at the same instant
    assert sim.events_processed == 8 + 8  # 8 starts + 8 completions


def test_registry_egress_peak_tracked():
    from repro.core.topology import baseline_plan

    sim = FlowSim(SimConfig(registry_out_cap=5 * 125e6))
    sim.add_plan(baseline_plan([f"vm{i}" for i in range(8)], image_bytes=10_000_000))
    sim.run()
    # 8 concurrent flows, each NIC-limited to 125 MB/s in, registry cap 625 MB/s
    assert sim.peak_registry_egress == pytest.approx(5 * 125e6, rel=1e-9)


def test_set_parent_mid_flight_applies_cap():
    """Attaching a parent to an already-started flow caps it immediately."""
    from repro.core.topology import REGISTRY, DistributionPlan, Flow

    results = []
    for cls in (FlowSim, ReferenceFlowSim):
        sim = cls(SimConfig(registry_out_cap=5e6))
        [p] = sim.add_plan(
            DistributionPlan(
                flows=[Flow(REGISTRY, "A", "img", 200_000_000)], streaming=False
            )
        )
        [c] = sim.add_plan(
            DistributionPlan(flows=[Flow("A", "B", "img", 125_000_000)], streaming=False)
        )
        sim.run(until=0.1)  # both flows start, uncapped
        sim.set_parent(c, p)  # the TraceReplay mid-flight attach path
        sim.run()
        results.append(c.t_done)
    inc, ref = results
    assert _close(inc, ref), (inc, ref)
    assert inc > 20.0  # capped at the parent's 5 MB/s, not B's NIC rate


def test_random_plan_fuzz_equivalence():
    """Seeded random flow graphs: both engines agree end-to-end."""
    from repro.core.topology import REGISTRY, DistributionPlan, Flow

    for seed in range(4):
        rng = random.Random(seed)
        nodes = [f"vm{i}" for i in range(10)]
        flows = []
        for i, n in enumerate(nodes):
            src = REGISTRY if i == 0 or rng.random() < 0.3 else nodes[rng.randrange(i)]
            flows.append(Flow(src, n, "img", rng.randrange(1_000_000, 50_000_000)))
        plan = DistributionPlan(
            flows=flows,
            control_latency={n: rng.random() * 0.05 for n in nodes},
            streaming=bool(seed % 2),
        )
        _assert_equivalent(plan, _wave_simconfig())
