"""Request-level serving benchmark (sub-tick dispatch + cold-start herd control).

Two scenarios, one artifact (``BENCH_serving.json``):

  * **mix** — the 8-tenant production mix (``repro.sim.scale.serving_config``)
    replayed through the request-level serving layer: per-tenant p50/p99
    response latency, pooled platform percentiles, and the faasnet-vs-baseline
    platform p99 (full-image pulls stretch every cold request under baseline).
  * **cold_burst** — a 10k-request scale-from-zero burst (whole-VM memory
    footprint) landing next to a diurnal background tenant whose daily ramp
    starts right after the burst.  Herd control admits ONE provisioning wave
    sized to sustainable throughput; the naive per-tick deficit rule grabs
    the entire free pool, starving the background tenant's ramp.

Asserted IN-BENCH (not just reported): with herd control on, the platform
provisions strictly fewer instances, wastes fewer (an instance is "wasted"
when its lifetime service time never repays its provisioning latency), and
holds equal-or-better platform p99 (worst tenant) than the naive rule.

Usage::

    PYTHONPATH=src python benchmarks/bench_serving.py           # ~30 s
    PYTHONPATH=src python benchmarks/bench_serving.py --quick
    PYTHONPATH=src python benchmarks/bench_serving.py --skip-asserts
"""
from __future__ import annotations

import argparse
import json
import time


def _pctl(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    return sorted_vals[min(len(sorted_vals) - 1, int(q * len(sorted_vals)))]


def _tenant_row(tr) -> dict:
    return {
        "requests": tr.requests,
        "completed": tr.completed,
        "p50_response_s": tr.p50_response_s,
        "p99_response_s": tr.p99_response_s,
        "peak_vms": tr.peak_vms,
        "provisioned": tr.provisioned,
        "wasted_provisions": tr.wasted_provisions,
    }


def _platform_row(res, replay) -> dict:
    lats = sorted(
        lat for ts in replay.tenants for (_, lat) in ts.responses
    )
    return {
        "per_tenant": {
            fid: _tenant_row(tr) for fid, tr in sorted(res.per_tenant.items())
        },
        "total_provisioned": sum(tr.provisioned for tr in res.per_tenant.values()),
        "total_wasted": sum(tr.wasted_provisions for tr in res.per_tenant.values()),
        # worst tenant tail: the starvation-sensitive platform SLO
        "platform_p99_s": max(tr.p99_response_s for tr in res.per_tenant.values()),
        # pooled request population (what a platform-wide dashboard shows)
        "pooled_p50_s": _pctl(lats, 0.50),
        "pooled_p99_s": _pctl(lats, 0.99),
        "waves": res.manager_stats.get("waves", 0),
        "vm_hours": res.vm_hours(),
    }


# ----------------------------------------------------------------------
# Scenario 1: the 8-tenant production mix under request-level serving
# ----------------------------------------------------------------------
def run_mix(args) -> dict:
    from repro.sim import MultiTenantReplay, serving_config

    out: dict = {"minutes": args.minutes, "seed": args.seed}
    for system in ("faasnet", "baseline"):
        cfg = serving_config(
            args.seed,
            minutes=args.minutes,
            system=system,
            failover_at=None,
            check_partition=not args.skip_asserts,
        )
        t0 = time.perf_counter()
        replay = MultiTenantReplay(cfg)
        res = replay.run()
        row = _platform_row(res, replay)
        row["wall_s"] = time.perf_counter() - t0
        out[system] = row
    out["n_tenants"] = len(out["faasnet"]["per_tenant"])
    return out


# ----------------------------------------------------------------------
# Scenario 2: scale-from-zero cold burst, herd control vs naive deficit rule
# ----------------------------------------------------------------------
def _cold_burst_cfg(herd: bool, *, burst: int, pool: int, dur_s: int, check: bool):
    from repro.sim.multi_tenant import (
        MultiTenantConfig,
        ServingConfig,
        TenantConfig,
    )
    from repro.sim.traces import diurnal_trace

    # Background sits at its 50-RPS night until t=40, then ramps to a
    # 400-RPS peak at t=70 — i.e. AFTER the burst tenant's t=20 pool grab.
    bg = diurnal_trace(
        duration_s=dur_s, base_rps=50.0, peak_rps=400.0, period_s=120, phase_s=80
    )
    burst_trace = [0.0] * 20 + [float(burst)] + [0.0] * (dur_s - 21)
    return MultiTenantConfig(
        tenants=[
            TenantConfig("background", bg, seed=1),
            # Whole-VM memory footprint: the cold tenant cannot co-locate,
            # so every instance it grabs is a VM the background loses.
            TenantConfig(
                "coldstart",
                burst_trace,
                seed=3,
                mem_mb=4096,
                function_duration_s=1.0,
                max_reserve_per_tick=100_000,
            ),
        ],
        vm_pool_size=pool,
        serving=ServingConfig(herd_control=herd),
        check_partition=check,
    )


def run_cold_burst(args) -> dict:
    from repro.sim import MultiTenantReplay

    out: dict = {
        "burst_requests": args.burst,
        "vm_pool_size": args.pool,
        "duration_s": args.dur,
    }
    for herd in (True, False):
        cfg = _cold_burst_cfg(
            herd,
            burst=args.burst,
            pool=args.pool,
            dur_s=args.dur,
            check=not args.skip_asserts,
        )
        t0 = time.perf_counter()
        replay = MultiTenantReplay(cfg)
        res = replay.run()
        row = _platform_row(res, replay)
        row["wall_s"] = time.perf_counter() - t0
        out["herd" if herd else "naive"] = row
    h, n = out["herd"], out["naive"]
    out["herd_fewer_provisions"] = h["total_provisioned"] < n["total_provisioned"]
    out["herd_p99_not_worse"] = h["platform_p99_s"] <= n["platform_p99_s"]
    out["herd_fewer_wasted"] = h["total_wasted"] < n["total_wasted"]
    if not args.skip_asserts:
        assert out["herd_fewer_provisions"], (
            f"herd provisioned {h['total_provisioned']} >= "
            f"naive {n['total_provisioned']}"
        )
        assert out["herd_p99_not_worse"], (
            f"herd platform p99 {h['platform_p99_s']:.2f} s worse than "
            f"naive {n['platform_p99_s']:.2f} s"
        )
        assert out["herd_fewer_wasted"], (
            f"herd wasted {h['total_wasted']} >= naive {n['total_wasted']}"
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--minutes", type=int, default=8, help="mix replay length")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--burst", type=int, default=10_000)
    ap.add_argument("--pool", type=int, default=3000)
    ap.add_argument("--dur", type=int, default=180, help="cold-burst replay length (s)")
    ap.add_argument("--quick", action="store_true", help="smaller burst + shorter mix")
    ap.add_argument(
        "--skip-asserts",
        action="store_true",
        help="skip per-tick partition checks and the herd-vs-naive assertions",
    )
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.quick:
        args.minutes, args.burst, args.pool, args.dur = 4, 4000, 1500, 150

    mix = run_mix(args)
    cold = run_cold_burst(args)
    out = {"mix": mix, "cold_burst": cold}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")

    fa, ba = mix["faasnet"], mix["baseline"]
    print(
        f"mix: {mix['n_tenants']} tenants x {mix['minutes']} min: faasnet "
        f"pooled p50/p99 {fa['pooled_p50_s']:.2f}/{fa['pooled_p99_s']:.2f} s, "
        f"platform p99 {fa['platform_p99_s']:.2f} s "
        f"(baseline {ba['platform_p99_s']:.2f} s) -> {args.out}"
    )
    h, n = cold["herd"], cold["naive"]
    print(
        f"cold burst {cold['burst_requests']} reqs / {cold['vm_pool_size']} VMs: "
        f"herd prov {h['total_provisioned']} wasted {h['total_wasted']} "
        f"plat p99 {h['platform_p99_s']:.2f} s  vs  naive prov "
        f"{n['total_provisioned']} wasted {n['total_wasted']} plat p99 "
        f"{n['platform_p99_s']:.2f} s"
    )
    for fid in sorted(h["per_tenant"]):
        ht, nt = h["per_tenant"][fid], n["per_tenant"][fid]
        print(
            f"  {fid:12s} herd done {ht['completed']:6d}/{ht['requests']:6d} "
            f"p99 {ht['p99_response_s']:7.2f}s | naive done "
            f"{nt['completed']:6d}/{nt['requests']:6d} p99 {nt['p99_response_s']:7.2f}s"
        )


if __name__ == "__main__":
    main()
