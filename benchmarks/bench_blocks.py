"""Block-level provisioning: layer sharing, runnable-at-prefix, Fig. 20 sweep.

Three experiments against the block/layer image model
(:mod:`repro.core.image` + the block plan builders), written to
``BENCH_blocks.json``:

  * **layer sharing** — 25 functions deployed as consecutive waves onto one
    warm VM pool, built from 3 shared base images vs 25 disjoint ones.
    Shared bases dedup in the per-VM block caches, so only each function's
    private app layer travels after the first wave per base; the bench
    asserts the shared stack is >= 2x faster on total time-to-runnable.
  * **runnable at prefix** — one cold FaaSNet wave on the paper's 758 MB
    image: the §3.2 boot-working-set milestone (`runnable`) must land well
    before full image arrival (`done`), and the incremental and vector
    engines must agree bit-for-bit on both.
  * **read amplification** — paper Fig. 20: the boot working set is rounded
    up to whole blocks per layer, so fetched/useful grows with block size;
    the sweep records the curve and asserts monotonicity.

Usage::

    PYTHONPATH=src python benchmarks/bench_blocks.py           # full size
    PYTHONPATH=src python benchmarks/bench_blocks.py --quick   # 8 functions
"""
from __future__ import annotations

import argparse
import json
import time

MB = 1 << 20


def layer_sharing(n_functions: int, n_bases: int, n_vms: int) -> dict:
    """Sequential deployment waves on one pool: shared bases vs disjoint."""
    from repro.core import BlockCache, disjoint_images, shared_base_images
    from repro.sim import WaveConfig, block_wave

    image_bytes = 256 * MB
    cfg = WaveConfig(container_start=0.5)  # production runc start (RPCCosts)

    def deploy(images) -> tuple[float, float]:
        cache = BlockCache()
        runnable = done = 0.0
        for img in images:
            res = block_wave("faasnet", n_vms, cfg, images=img, cache=cache)
            runnable += max(v["runnable"] for v in res.values())
            done += max(v["done"] for v in res.values())
        return runnable, done

    sr, sd = deploy(shared_base_images(n_functions, n_bases, image_bytes=image_bytes))
    dr, dd = deploy(disjoint_images(n_functions, image_bytes=image_bytes))
    speedup = dr / sr
    assert speedup >= 2.0, (
        f"layer sharing only {speedup:.2f}x on time-to-runnable "
        f"(shared {sr:.1f}s vs disjoint {dr:.1f}s) — block-cache dedup of "
        f"shared base layers is not paying"
    )
    return {
        "n_functions": n_functions,
        "n_bases": n_bases,
        "n_vms_per_wave": n_vms,
        "image_bytes": image_bytes,
        "shared_runnable_total_s": sr,
        "shared_done_total_s": sd,
        "disjoint_runnable_total_s": dr,
        "disjoint_done_total_s": dd,
        "runnable_speedup_shared_vs_disjoint": speedup,
        "done_speedup_shared_vs_disjoint": dd / sd,
    }


def runnable_at_prefix(n_vms: int) -> dict:
    """Cold FaaSNet wave, paper-size image: runnable beats full arrival."""
    from repro.core import LayerSpec, ImageSpec
    from repro.sim import WaveConfig, block_wave

    # The paper's 758 MB PyStan image as a 4-layer stack, 512 KB blocks
    # (the block size Fig. 20 picks), 15 % boot working set.
    sizes = (256 * MB, 256 * MB, 128 * MB, 758 * MB - 640 * MB)
    img = ImageSpec(
        "pystan",
        tuple(LayerSpec(f"pystan:L{i}", s) for i, s in enumerate(sizes)),
        block_size=512 * 1024,
        boot_fraction=0.15,
    )
    res = {
        eng: block_wave("faasnet", n_vms, WaveConfig(engine=eng), images=img)
        for eng in ("incremental", "vector")
    }
    assert res["incremental"] == res["vector"], (
        "engine divergence on the block wave"
    )
    r = max(v["runnable"] for v in res["incremental"].values())
    d = max(v["done"] for v in res["incremental"].values())
    assert r < d, (
        f"runnable-at-prefix milestone ({r:.2f}s) did not beat full-image "
        f"arrival ({d:.2f}s)"
    )
    return {
        "n_vms": n_vms,
        "image_bytes": img.total_bytes(),
        "boot_fraction": img.boot_fraction,
        "block_size": img.block_size,
        "runnable_makespan_s": r,
        "full_arrival_makespan_s": d,
        "runnable_vs_full_ratio": r / d,
        "engines_match": True,
    }


def read_amplification_sweep() -> dict:
    """Paper Fig. 20: fetched/useful bytes of the boot set vs block size."""
    from repro.core import LayerSpec, ImageSpec

    sizes = (256 * MB, 256 * MB, 128 * MB, 758 * MB - 640 * MB)
    points = {}
    for bs in (128 * 1024, 256 * 1024, 512 * 1024, MB, 2 * MB, 4 * MB, 8 * MB):
        img = ImageSpec(
            "pystan",
            tuple(LayerSpec(f"pystan:L{i}", s) for i, s in enumerate(sizes)),
            block_size=bs,
            boot_fraction=0.15,
        )
        points[str(bs)] = {
            "read_amplification": img.boot_read_amplification(),
            "boot_fetched_bytes": sum(
                img.boot_prefix_bytes(la.digest) for la in img.layers
            ),
            "fetched_fraction_of_image": sum(
                img.boot_prefix_bytes(la.digest) for la in img.layers
            )
            / img.total_bytes(),
        }
    amps = [p["read_amplification"] for p in points.values()]
    assert amps == sorted(amps), f"read amplification not monotone: {amps}"
    # Fig. 20's operating point: at 512 KB blocks the boot fetch stays a
    # small fraction of the image (the paper reports ~84 % I/O reduction).
    frac_512k = points[str(512 * 1024)]["fetched_fraction_of_image"]
    assert frac_512k < 0.2, f"512 KB boot fetch is {frac_512k:.1%} of the image"
    return {"boot_fraction": 0.15, "by_block_size": points}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="8 functions, 4 VMs")
    ap.add_argument("--out", default="BENCH_blocks.json")
    args = ap.parse_args()
    n_fns, n_vms = (8, 4) if args.quick else (25, 8)

    t0 = time.perf_counter()
    sharing = layer_sharing(n_fns, 3, n_vms)
    prefix = runnable_at_prefix(n_vms=16)
    fig20 = read_amplification_sweep()
    out = {
        "quick": args.quick,
        "wall_s": time.perf_counter() - t0,
        "layer_sharing": sharing,
        "runnable_at_prefix": prefix,
        "read_amplification": fig20,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(
        f"layer sharing: {sharing['n_functions']} fns on {sharing['n_bases']} "
        f"bases {sharing['runnable_speedup_shared_vs_disjoint']:.2f}x faster "
        f"to runnable than disjoint "
        f"({sharing['shared_runnable_total_s']:.1f}s vs "
        f"{sharing['disjoint_runnable_total_s']:.1f}s)"
    )
    print(
        f"runnable at prefix: {prefix['runnable_makespan_s']:.2f}s vs full "
        f"arrival {prefix['full_arrival_makespan_s']:.2f}s "
        f"({prefix['runnable_vs_full_ratio']:.0%}) on "
        f"{prefix['image_bytes'] / MB:.0f} MB x {prefix['n_vms']} VMs"
    )
    amp = fig20["by_block_size"]
    lo, hi = str(128 * 1024), str(8 * MB)
    print(
        f"read amplification (Fig. 20): {amp[lo]['read_amplification']:.3f} @ "
        f"128 KB -> {amp[hi]['read_amplification']:.3f} @ 8 MB blocks; "
        f"512 KB boot fetch = "
        f"{amp[str(512 * 1024)]['fetched_fraction_of_image']:.1%} of the image"
    )
    print(f"wrote {args.out} in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
