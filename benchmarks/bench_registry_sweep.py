"""Registry shard sweep: the paper's bottleneck-removal claim, quantified.

Paper §4.3's central claim is that FaaSNet makes provisioning latency
*insensitive* to registry bandwidth, while ``docker pull`` (baseline) and
on-demand fetch scale only as fast as the registry does.  This benchmark
sweeps the registry from 1 to 8 shards (replicas) for
{faasnet, baseline, on_demand} and writes ``BENCH_registry.json`` showing
both directions of the claim:

  * baseline / on_demand provisioning makespan improves ~monotonically as
    shards are added (their throughput is registry-bound);
  * faasnet's makespan moves < 5 % across the whole sweep (only the tree
    root ever touches the registry, and it is NIC-bound, not registry-bound).

The sweep uses the ``replicated`` placement policy — every shard holds every
image and fetchers round-robin across replicas — which is exactly the
"add registry replicas" configuration the paper's claim is about
(``hash_by_function`` would pin one function's image to one shard and
measure blob *sharding*, not replica scaling).  ``per_stream_cap`` is lifted
for the sweep so the registry (not the 30 MB/s app-level stream cap) is the
binding resource for the registry-bound systems; VM NICs stay at 1 Gbps.

Usage::

    PYTHONPATH=src python benchmarks/bench_registry_sweep.py           # 128 VMs
    PYTHONPATH=src python benchmarks/bench_registry_sweep.py --vms 64
    PYTHONPATH=src python benchmarks/bench_registry_sweep.py --no-check
"""
from __future__ import annotations

import argparse
import json
import statistics as st
import time

SHARD_COUNTS = (1, 2, 4, 8)
SYSTEMS = ("faasnet", "baseline", "on_demand")


def run_cell(system: str, shards: int, args) -> dict:
    from repro.sim import RegistrySpec, WaveConfig, provision_wave
    from repro.sim.engine import GBPS

    cfg = WaveConfig(
        per_stream_cap=float("inf"),
        registry=RegistrySpec(
            shards=shards,
            egress_cap=args.shard_gbps * GBPS,
            qps=args.shard_qps,
            policy=args.policy,
        ),
    )
    t0 = time.perf_counter()
    lat = sorted(provision_wave(system, args.vms, cfg).values())
    return {
        "makespan_s": lat[-1],
        "mean_s": st.mean(lat),
        "p50_s": lat[len(lat) // 2],
        "wall_s": time.perf_counter() - t0,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vms", type=int, default=128)
    ap.add_argument("--shard-gbps", type=float, default=9.5,
                    help="per-shard egress in Gbit/s (paper §4.1 calibration)")
    ap.add_argument("--shard-qps", type=float, default=1100.0,
                    help="per-shard block-request throttle")
    ap.add_argument("--policy", default="replicated",
                    choices=("replicated", "least_loaded", "hash_by_function"))
    ap.add_argument("--no-check", action="store_true",
                    help="skip the bottleneck-removal assertions")
    ap.add_argument("--out", default="BENCH_registry.json")
    args = ap.parse_args()

    sweep: dict[str, dict[str, dict]] = {}
    for system in SYSTEMS:
        sweep[system] = {str(s): run_cell(system, s, args) for s in SHARD_COUNTS}

    def makespans(system: str) -> list[float]:
        return [sweep[system][str(s)]["makespan_s"] for s in SHARD_COUNTS]

    f = makespans("faasnet")
    faasnet_variation_pct = (max(f) - min(f)) / min(f) * 100.0
    checks = {
        "baseline_monotone_improving": all(
            a > b for a, b in zip(makespans("baseline"), makespans("baseline")[1:])
        ),
        "on_demand_monotone_improving": all(
            a > b for a, b in zip(makespans("on_demand"), makespans("on_demand")[1:])
        ),
        "faasnet_variation_pct": faasnet_variation_pct,
        "faasnet_flat_within_5pct": faasnet_variation_pct < 5.0,
    }
    out = {
        "n_vms": args.vms,
        "shard_counts": list(SHARD_COUNTS),
        "per_shard_egress_gbps": args.shard_gbps,
        "per_shard_qps": args.shard_qps,
        "policy": args.policy,
        "sweep": sweep,
        "speedup_vs_1_shard": {
            system: {
                str(s): sweep[system]["1"]["makespan_s"]
                / sweep[system][str(s)]["makespan_s"]
                for s in SHARD_COUNTS
            }
            for system in SYSTEMS
        },
        "checks": checks,
        "paper_claim": (
            "§4.3: baseline/on-demand provisioning scales with registry "
            "bandwidth; FaaSNet is insensitive to it"
        ),
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2, sort_keys=True)
        fh.write("\n")

    print(f"{args.vms} VMs, {args.shard_gbps} Gbps x {args.policy} shards "
          f"-> {args.out}")
    print(f"{'system':10s} " + " ".join(f"{s:>4d}sh" for s in SHARD_COUNTS)
          + "   speedup@8")
    for system in SYSTEMS:
        m = makespans(system)
        print(f"{system:10s} " + " ".join(f"{x:6.1f}" for x in m)
              + f"   {m[0] / m[-1]:6.2f}x")
    print(f"faasnet variation across sweep: {faasnet_variation_pct:.2f}% "
          f"(claim: < 5%)")

    if not args.no_check:
        assert checks["baseline_monotone_improving"], makespans("baseline")
        assert checks["on_demand_monotone_improving"], makespans("on_demand")
        assert checks["faasnet_flat_within_5pct"], faasnet_variation_pct


if __name__ == "__main__":
    main()
