"""Benchmark driver: one benchmark per paper table/figure + roofline.

Prints ``name,value,derived`` CSV.  ``--quick`` shrinks the expensive
simulations; ``--only fig14`` runs a single figure.  The roofline section
reads results/dryrun/*.json produced by ``python -m repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args()

    from benchmarks import paper_figures
    from benchmarks.roofline import csv_rows

    print("name,value,derived")
    for fn in paper_figures.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.monotonic()
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # pragma: no cover
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}", flush=True)
            continue
        for name, value, derived in rows:
            print(f"{name},{value:.4f},{derived}", flush=True)
        print(f"_timing/{fn.__name__}_s,{time.monotonic() - t0:.2f},", flush=True)

    if args.only is None or "roofline" in args.only:
        try:
            for name, value, derived in csv_rows(args.dryrun_dir):
                print(f"{name},{value:.5f},{derived}", flush=True)
        except Exception as e:  # pragma: no cover
            print(f"roofline,ERROR,{e}", flush=True)


if __name__ == "__main__":
    main()
