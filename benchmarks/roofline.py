"""Aggregate dry-run JSONs into the §Roofline table (+ CSV rows)."""
from __future__ import annotations

import glob
import json
import os

from repro.configs import LONG_CTX_ARCHS, SHAPES, cells


def load_results(outdir: str = "results/dryrun") -> dict[tuple, dict]:
    out = {}
    for path in glob.glob(os.path.join(outdir, "*.json")):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        if len(parts) < 3:
            continue
        arch, shape, mesh = parts[0], parts[1], parts[2]
        with open(path) as f:
            out[(arch, shape, mesh)] = json.load(f)
    return out


def table_rows(outdir: str = "results/dryrun", mesh: str = "single"):
    res = load_results(outdir)
    rows = []
    for arch, shape, skip in cells(include_skipped=True):
        key = (arch, shape, mesh)
        if skip is not None:
            rows.append({"arch": arch, "shape": shape, "skip": skip})
            continue
        d = res.get(key)
        if d is None:
            rows.append({"arch": arch, "shape": shape, "skip": "MISSING"})
            continue
        r = d.get("roofline", {})
        rows.append({
            "arch": arch,
            "shape": shape,
            "mesh": mesh,
            "compute_s": r.get("compute_s"),
            "memory_s": r.get("memory_s"),
            "collective_s": r.get("collective_s"),
            "dominant": r.get("dominant"),
            "fraction": r.get("roofline_fraction"),
            "useful_flops_ratio": r.get("useful_flops_ratio"),
            "mem_gib": d.get("memory", {}).get("peak_per_device_bytes", 0) / 2**30,
            "compile_s": d.get("compile_s"),
            "n_devices": d.get("n_devices"),
        })
    return rows


def markdown_table(outdir: str = "results/dryrun", mesh: str = "single") -> str:
    rows = table_rows(outdir, mesh)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | roofline frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — |"
                         f" <!-- {r['skip']} -->")
            continue
        lines.append(
            "| {arch} | {shape} | {compute_s:.3f} | {memory_s:.3f} | "
            "{collective_s:.3f} | {dominant} | {useful_flops_ratio:.3f} | "
            "{fraction:.4f} | {mem_gib:.2f} |".format(**r)
        )
    return "\n".join(lines)


def csv_rows(outdir: str = "results/dryrun") -> list[tuple[str, float, str]]:
    out = []
    for mesh in ("single", "multi"):
        for r in table_rows(outdir, mesh):
            if "skip" in r:
                continue
            name = f"roofline/{r['arch']}/{r['shape']}/{mesh}"
            out.append((f"{name}/fraction", r["fraction"] or 0.0,
                        f"dom={r['dominant']}"))
    return out
