"""One benchmark per paper table/figure (FaaSNet, USENIX ATC'21).

Each function returns a list of CSV rows ``(name, value, derived)`` and the
paper's reference number where one exists, so EXPERIMENTS.md can report
reproduction deltas.  All timings are deterministic simulator outputs.
"""
from __future__ import annotations

import statistics as st

from repro.sim import (
    ReplayConfig,
    TraceReplay,
    WaveConfig,
    iot_trace,
    provision_wave,
    scalability_table,
    startup_timeline,
    synthetic_gaming_trace,
)

Row = tuple[str, float, str]


def fig11_iot_trace(quick: bool = False) -> list[Row]:
    """IoT trace replay: peak response + recovery (paper Fig. 11)."""
    rows: list[Row] = []
    trace = iot_trace(scale=1 / 3)[: (20 if quick else 35) * 60]
    burst_t = 9 * 60
    for system in ("faasnet", "on_demand", "baseline"):
        r = TraceReplay(ReplayConfig(system=system, idle_reclaim_s=420))
        tl = r.run(trace)
        peak = max(ts.mean_response_s for ts in tl if ts.t >= burst_t)
        rec = r.recovery_time(burst_t + 60, normal_s=3.5)
        pl = r.prov_latencies
        rows.append((f"fig11/{system}/peak_resp_s", peak, "paper: faasnet 6, baseline 28"))
        rows.append((f"fig11/{system}/recovery_s", rec,
                     "paper: faasnet 28, on-demand 112, baseline 113"))
        if pl:
            rows.append((f"fig11/{system}/prov_mean_s", st.mean(pl), ""))
    return rows


def fig12_synthetic_trace(quick: bool = False) -> list[Row]:
    """Synthetic gaming burst: FT height adaptation (paper Fig. 12)."""
    trace = synthetic_gaming_trace(scale=1.0)[: (15 if quick else 26) * 60]
    # short gaming functions (paper's synthetic burst grows to 82 VMs at
    # 100 RPS => sub-second effective service time)
    r = TraceReplay(ReplayConfig(system="faasnet", idle_reclaim_s=420,
                                 function_duration_s=0.8))
    tl = r.run(trace)
    h_burst1 = max(ts.ft_height for ts in tl if 11 * 60 <= ts.t < 14 * 60)
    vm_peak = max(ts.active_vms for ts in tl)
    between = [ts.ft_height for ts in tl if 18 * 60 <= ts.t < 21 * 60]
    rows = [
        ("fig12/ft_height_burst1", h_burst1, "paper: 7 (82 VMs)"),
        ("fig12/active_vms_peak", vm_peak, "paper: ~82-102"),
    ]
    if between:
        rows.append(("fig12/ft_height_after_reclaim", min(between),
                     "paper: shrinks to 5-6 (~30 VMs)"))
    if len(tl) > 22 * 60:
        h_burst2 = max(ts.ft_height for ts in tl if 21 * 60 <= ts.t < 24 * 60)
        rows.append(("fig12/ft_height_burst2", h_burst2, "paper: 7 (102 VMs)"))
    return rows


def fig13_provisioning_cdf(quick: bool = False) -> list[Row]:
    """Container provisioning latency distribution (paper Fig. 13)."""
    rows: list[Row] = []
    for name, system in (("faasnet", "faasnet"), ("on_demand", "on_demand")):
        lat = sorted(provision_wave(system, 64 if quick else 128).values())
        p = lambda q: lat[int(q * (len(lat) - 1))]
        rows.append((f"fig13/{name}/p50_s", p(0.5), ""))
        rows.append((f"fig13/{name}/p96_s", p(0.96),
                     "paper: faasnet 5.8-7.9 tight; on-demand 7-21 wide"))
        rows.append((f"fig13/{name}/spread_s", lat[-1] - lat[0], ""))
    return rows


def fig14_scalability(quick: bool = False) -> list[Row]:
    """Provisioning latency vs concurrency, five systems (paper Fig. 14)."""
    ns = (8, 32) if quick else (8, 16, 32, 64, 128)
    table = scalability_table(ns=ns)
    rows: list[Row] = []
    for system, per_n in table.items():
        for n, d in per_n.items():
            rows.append((f"fig14/{system}/n{n}_mean_s", d["mean"], ""))
    nmax = max(ns)
    f = table["faasnet"][nmax]["mean"]
    rows.append(("fig14/speedup_vs_baseline", table["baseline"][nmax]["mean"] / f,
                 "paper: 13.4x"))
    rows.append(("fig14/speedup_vs_kraken", table["kraken"][nmax]["max"] / f,
                 "paper: 16.3x"))
    rows.append(("fig14/speedup_vs_on_demand", table["on_demand"][nmax]["mean"] / f,
                 "paper: 5x"))
    rows.append(("fig14/speedup_vs_dadi", table["dadi_p2p"][nmax]["mean"] / f,
                 "paper: 2.8x"))
    return rows


def fig15_startup_timeline(quick: bool = False) -> list[Row]:
    """Wall-clock span from first to last function start (paper Fig. 15)."""
    n = 64 if quick else 128
    rows: list[Row] = []
    for system in ("faasnet", "on_demand", "dadi_p2p"):
        tl = startup_timeline(system, n)
        rows.append((f"fig15/{system}/first_start_s", tl[0],
                     "paper: faasnet first at 5.5s"))
        rows.append((f"fig15/{system}/span_s", tl[-1] - tl[0],
                     "paper: faasnet 1.5s, on-demand 16.4s, dadi 19s"))
    return rows


def fig16_bandwidth(quick: bool = False) -> list[Row]:
    """Interior-VM in/out bandwidth during a wave (paper Fig. 16)."""
    from repro.core import FunctionTree
    from repro.core.topology import faasnet_plan
    from repro.sim import FlowSim, SimConfig

    cfg = WaveConfig()
    ft = FunctionTree("f")
    for i in range(64):
        ft.insert(f"vm{i}")
    interior = next(
        n.vm_id for n in ft.bfs() if len(n.children()) == 2 and n.parent is not None
    )
    plan = faasnet_plan(ft, image_bytes=cfg.image_bytes,
                        startup_fraction=cfg.startup_fraction)
    sim = FlowSim(SimConfig(per_stream_cap=cfg.per_stream_cap,
                            hop_latency=cfg.hop_latency))
    states = sim.add_plan(plan)
    # sample rates while running
    peak_in = peak_out = 0.0
    for t in range(1, 80):
        sim.run(until=float(t) * 0.1)
        rin = sum(f.rate for f in states
                  if f.flow.dst == interior and f.started and not f.done)
        rout = sum(f.rate for f in states
                   if f.flow.src == interior and f.started and not f.done)
        peak_in, peak_out = max(peak_in, rin), max(peak_out, rout)
    return [
        ("fig16/interior_peak_in_MBps", peak_in / 1e6, "paper: ~15 MB/s"),
        ("fig16/interior_peak_out_MBps", peak_out / 1e6, "paper: ~30 MB/s"),
        ("fig16/out_over_in", peak_out / max(peak_in, 1e-9),
         "paper: outbound ≈ 2x inbound (binary fan-out)"),
    ]


def fig17_large_scale(quick: bool = False) -> list[Row]:
    """2,500 functions on 1,000 VMs (paper Fig. 17)."""
    from repro.core import FTManager, VMInfo
    from repro.core.topology import faasnet_plan
    from repro.sim import FlowSim, SimConfig

    n_vms = 200 if quick else 1000
    n_funcs = 500 if quick else 2500
    cfg = WaveConfig(image_bytes=int(428e6), container_start=2.5)
    mgr = FTManager()
    for i in range(n_vms):
        mgr.add_free_vm(VMInfo(f"vm{i}"))
        mgr.reserve_vm()
    # 3 distinct functions spread over the pool, 2-3 instances per VM
    sim = FlowSim(SimConfig(per_stream_cap=cfg.per_stream_cap,
                            hop_latency=cfg.hop_latency,
                            registry_out_cap=cfg.registry_out_cap))
    done: dict[str, float] = {}
    fn_of_vm = {}
    for f in range(n_funcs // n_vms + 1):
        fid = f"f{f}"
        for i in range(n_vms):
            if f * n_vms + i >= n_funcs:
                break
            mgr.insert(fid, f"vm{i}")
        ft = mgr.trees.get(fid)
        if ft is None:
            continue
        plan = faasnet_plan(ft, image_bytes=cfg.image_bytes,
                            startup_fraction=cfg.startup_fraction)
        sim.add_plan(
            plan, t0=cfg.rpc.control_plane_total(),
            on_node_done=lambda vm, t, fid=fid: done.setdefault(f"{fid}@{vm}", t),
        )
    sim.run()
    extra = cfg.container_start + cfg.rpc.image_load
    lats = [t + extra for t in done.values()]
    return [
        ("fig17/n_functions", float(len(lats)), ""),
        ("fig17/first_start_s", min(lats), "paper: 5.1s"),
        ("fig17/last_start_s", max(lats), "paper: 8.3s"),
    ]


def fig18_placement(quick: bool = False) -> list[Row]:
    """8 functions packed onto N VMs: FaaSNet vs DADI (paper Fig. 18)."""
    from repro.core import FunctionTree
    from repro.core.topology import dadi_plan, faasnet_plan
    from repro.sim import FlowSim, SimConfig

    img = int(75.4e6)
    rows: list[Row] = []
    for n_vms in (4, 2, 1):
        for system in ("faasnet", "dadi_p2p"):
            sim = FlowSim(SimConfig(per_stream_cap=30e6, hop_latency=0.05,
                                    coordinator_cost_s=0.1 if system != "faasnet" else 0.0))
            done: dict[str, float] = {}
            for f in range(8):
                nodes = [f"vm{i}" for i in range(n_vms)]
                if system == "faasnet":
                    ft = FunctionTree(f"f{f}")
                    for v in nodes:
                        ft.insert(v)
                    plan = faasnet_plan(ft, image_bytes=img, startup_fraction=0.16)
                else:
                    plan = dadi_plan(nodes, image_bytes=img, root="vm0",
                                     startup_fraction=0.16)
                sim.add_plan(plan, on_node_done=lambda vm, t, f=f: done.setdefault(
                    f"{f}@{vm}", t))
            sim.run()
            lat = list(done.values())
            rows.append((f"fig18/{system}/vms{n_vms}_max_s", max(lat),
                         "paper: dadi variance blows up at 1-2 VMs"))
    return rows


def fig19_code_packages(quick: bool = False) -> list[Row]:
    """I/O-efficient format vs .zip for code packages (paper Fig. 19)."""
    import io
    import os
    import time
    import zipfile

    from repro.core import BlockReader, write_blockstore

    rows: list[Row] = []
    cases = {
        "helloworld": (11 * 1024, 1.0),  # tiny package, reads all
        "video": (2 << 20 if quick else 50 << 20, 0.2),  # reads 20% on start
        "ai": (4 << 20 if quick else 100 << 20, 0.1),
    }
    for name, (size, need) in cases.items():
        payload = os.urandom(size // 2) + b"\x00" * (size - size // 2)
        t0 = time.monotonic()
        buf = io.BytesIO()
        with zipfile.ZipFile(buf, "w", zipfile.ZIP_DEFLATED) as z:
            z.writestr("pkg", payload)
        zbuf = buf.getvalue()
        with zipfile.ZipFile(io.BytesIO(zbuf)) as z:
            _ = z.read("pkg")  # .zip must extract everything
        t_zip = time.monotonic() - t0
        path = f"/tmp/bench_{name}.blocks"
        t0 = time.monotonic()
        write_blockstore(payload, path)
        r = BlockReader(path)
        _ = r.read_range(0, int(size * need))  # on-demand subset
        t_blocks = time.monotonic() - t0
        rows.append((f"fig19/{name}/zip_s", t_zip, ""))
        rows.append((f"fig19/{name}/blocks_s", t_blocks,
                     "paper: I/O-efficient ≥ zip only for tiny packages"))
        os.remove(path)
    return rows


def fig20_read_amplification(quick: bool = False) -> list[Row]:
    """Bytes fetched vs block size on real block stores (paper Fig. 20)."""
    import os

    from repro.core import BlockReader, write_blockstore

    rows: list[Row] = []
    img = os.urandom((8 if quick else 64) << 20)
    startup = 0.15  # fraction of the image actually read at container start
    reads = [(int(len(img) * i / 37), 80_000) for i in range(0, 30)]
    for bs in (128 << 10, 512 << 10, 2 << 20):
        path = f"/tmp/bench_amp_{bs}.blocks"
        write_blockstore(img, path, block_size=bs)
        r = BlockReader(path)
        for off, ln in reads:
            r.read_range(min(off, len(img) - ln), ln)
        rows.append((f"fig20/bs{bs >> 10}k/fetched_over_needed",
                     r.stats.amplification(),
                     "paper: amplification grows with block size"))
        rows.append((f"fig20/bs{bs >> 10}k/net_reduction_vs_full",
                     1.0 - r.stats.fetched_compressed / len(img),
                     "paper: 83.9% reduction at 512KB"))
        os.remove(path)
    return rows


ALL = [
    fig11_iot_trace,
    fig12_synthetic_trace,
    fig13_provisioning_cdf,
    fig14_scalability,
    fig15_startup_timeline,
    fig16_bandwidth,
    fig17_large_scale,
    fig18_placement,
    fig19_code_packages,
    fig20_read_amplification,
]
