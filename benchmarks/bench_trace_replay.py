"""Trace-driven multi-tenant replay benchmark (paper §4.2, Figures 11-18).

Replays N overlapping tenant traces (IoT / synthetic gaming / diurnal /
constant, via ``repro.sim.scale.multi_tenant_config``) against ONE shared
FlowSim + VM pool and writes ``BENCH_trace.json`` with:

  * per-tenant request p99/mean response, provisioning latency p99/mean,
    provisioning makespan and peak VM footprint;
  * platform aggregates: whole-run provisioning makespan, total
    provisioning time, peak registry egress;
  * the faasnet-vs-baseline provisioning-time ratio (the paper reports
    75.2% less provisioning time, i.e. a ratio of ~0.248);
  * failover parity: the run (with its mid-wave FTManager
    snapshot/json/restore) re-executed without failover must produce a
    bit-identical TickStats stream;
  * two-run determinism of the failover run itself.

Request-level serving (sub-tick dispatch, per-VM CPU slots, herd-controlled
admission) is ON by default, so the response percentiles are real
distributions; ``--no-serving`` reverts to the legacy tick-quantized
dispatch loop, whose p99 collapses to integer seconds.

Usage::

    PYTHONPATH=src python benchmarks/bench_trace_replay.py           # 8 x 2000
    PYTHONPATH=src python benchmarks/bench_trace_replay.py --quick   # 3 x 300
    PYTHONPATH=src python benchmarks/bench_trace_replay.py --no-serving
    PYTHONPATH=src python benchmarks/bench_trace_replay.py --skip-checks
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time


def _run(args, *, system: str, failover_at):
    from repro.sim import MultiTenantReplay, multi_tenant_config, serving_config

    factory = multi_tenant_config if args.no_serving else serving_config
    cfg = factory(
        args.seed,
        n_tenants=args.tenants,
        vm_pool_size=args.pool,
        minutes=args.minutes,
        scale=args.scale,
        system=system,
        failover_at=failover_at,
        check_partition=not args.skip_checks,
        placement=args.placement,
        reclaim=args.reclaim,
    )
    t0 = time.perf_counter()
    res = MultiTenantReplay(cfg).run()
    return res, time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--pool", type=int, default=2000)
    ap.add_argument("--minutes", type=int, default=25)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--failover-at", type=int, default=12 * 60)
    from repro.sim import PLACEMENTS, RECLAIM_POLICIES

    ap.add_argument(
        "--placement",
        choices=PLACEMENTS,
        default="shared",
        help="shared = memory-aware cross-tenant pool (default); "
        "exclusive = legacy one-VM-one-tenant leasing",
    )
    ap.add_argument(
        "--reclaim",
        choices=RECLAIM_POLICIES,
        default="fixed",
        help="idle-instance reclaim policy",
    )
    ap.add_argument("--quick", action="store_true", help="3 tenants / 300 VMs / 8 min")
    ap.add_argument(
        "--no-serving",
        action="store_true",
        help="legacy tick-quantized dispatch (pre-serving response tails; "
        "p99 collapses to integer seconds)",
    )
    ap.add_argument(
        "--skip-checks",
        action="store_true",
        help="skip the parity/determinism re-runs and per-tick partition checks",
    )
    ap.add_argument("--out", default="BENCH_trace.json")
    args = ap.parse_args()
    if args.quick:
        args.tenants, args.pool, args.minutes = 3, 300, 8
        args.failover_at = min(args.failover_at, 4 * 60)

    res, wall = _run(args, system="faasnet", failover_at=args.failover_at)
    base, base_wall = _run(args, system="baseline", failover_at=args.failover_at)

    ratio = (
        res.total_prov_time_s / base.total_prov_time_s
        if base.total_prov_time_s > 0
        else float("nan")
    )
    out = {
        "n_tenants": args.tenants,
        "vm_pool_size": args.pool,
        "minutes": args.minutes,
        "trace_scale": args.scale,
        "seed": args.seed,
        "failover_at_s": args.failover_at,
        "placement": args.placement,
        "reclaim": args.reclaim,
        "serving": not args.no_serving,
        "vm_hours": res.vm_hours(),
        "peak_nic_utilization": res.peak_nic_utilization,
        "failovers": res.failovers,
        "wall_s": wall,
        "baseline_wall_s": base_wall,
        "per_tenant": {
            fid: dataclasses.asdict(tr) for fid, tr in sorted(res.per_tenant.items())
        },
        "prov_makespan_s": res.prov_makespan_s,
        "total_prov_time_s": res.total_prov_time_s,
        "peak_registry_egress_bytes_per_s": res.peak_registry_egress,
        "peak_registry_egress_gbps": res.peak_registry_egress * 8 / 1e9,
        "free_vms_at_end": res.free_vms,
        "manager_stats": res.manager_stats,
        "baseline_total_prov_time_s": base.total_prov_time_s,
        "baseline_prov_makespan_s": base.prov_makespan_s,
        "baseline_peak_registry_egress_gbps": base.peak_registry_egress * 8 / 1e9,
        "prov_time_ratio_vs_baseline": ratio,
        "prov_time_reduction_pct": (1.0 - ratio) * 100.0,
        "paper_reduction_pct": 75.2,  # §4.2: 75.2% less provisioning time
    }

    if not args.skip_checks:
        uninterrupted, _ = _run(args, system="faasnet", failover_at=None)
        rerun, _ = _run(args, system="faasnet", failover_at=args.failover_at)
        out["failover_parity"] = res.timelines == uninterrupted.timelines
        out["two_run_deterministic"] = (
            res.timelines == rerun.timelines and res.per_tenant == rerun.per_tenant
        )
        assert out["failover_parity"], "failover run diverged from uninterrupted run"
        assert out["two_run_deterministic"], "replay is not two-run deterministic"

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(
        f"{args.tenants} tenants / {args.pool} VMs / {args.minutes} min: "
        f"faasnet total prov {res.total_prov_time_s:.0f} s vs baseline "
        f"{base.total_prov_time_s:.0f} s -> ratio {ratio:.3f} "
        f"({(1-ratio)*100:.1f}% less; paper: 75.2%) -> {args.out}"
    )
    print(
        f"peak registry egress {res.peak_registry_egress*8/1e9:.2f} Gbps "
        f"(baseline {base.peak_registry_egress*8/1e9:.2f} Gbps), "
        f"failovers={res.failovers}"
        + (
            f", parity={out['failover_parity']}, "
            f"deterministic={out['two_run_deterministic']}"
            if not args.skip_checks
            else ""
        )
    )
    for fid, tr in sorted(res.per_tenant.items()):
        print(
            f"  {fid:12s} req={tr.requests:6d} p99resp={tr.p99_response_s:6.2f}s "
            f"p99prov={tr.p99_prov_s:6.2f}s makespan={tr.prov_makespan_s:7.1f}s "
            f"peak_vms={tr.peak_vms}"
        )


if __name__ == "__main__":
    main()
