"""Reproduce the paper's §4.2 burst: 2500 containers across 1000 VMs.

Runs the ``repro.sim.scale`` harness at deployment size on the incremental
fluid-flow engine and writes ``BENCH_scale.json`` with the provisioning
makespan, simulator event throughput, and peak registry egress.  The paper
reports 8.3 s for this wave on production infrastructure; the simulated
provisioning makespan lands in the same regime (the gap is container
start/runtime-init calibration, not network behaviour).

Usage::

    PYTHONPATH=src python benchmarks/bench_scale_1000.py            # full size
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --quick    # 100 VMs
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --compare-reference

``--compare-reference`` also times the old full-recompute engine on a
scaled-down wave (it is quadratic — full size would take hours) so the
speedup of the incremental engine is recorded alongside the results.
"""
from __future__ import annotations

import argparse
import json
import time


def _result_dict(cfg, res) -> dict:
    return {
        "n_vms": cfg.n_vms,
        "n_functions": cfg.n_functions,
        "containers_per_function": cfg.containers_per_function,
        "n_containers": res.n_containers,
        "churn_ops": cfg.churn_ops,
        "seed": cfg.seed,
        "fetch_makespan_s": res.makespan,
        "provision_makespan_s": res.provision_makespan,
        "per_function_makespan_s": res.per_function,
        "n_flows": res.n_flows,
        "events": res.events,
        "wall_s": res.wall_s,
        "events_per_s": res.events_per_s,
        "peak_registry_egress_bytes_per_s": res.peak_registry_egress,
        "peak_registry_egress_gbps": res.peak_registry_egress * 8 / 1e9,
        "reparents_during_churn": res.reparents,
        "ft_heights": {
            fid: st["height"] for fid, st in sorted(res.tree_stats.items())
        },
    }


def _time_reference(cfg) -> dict:
    """Time the full-recompute oracle on the same (scaled-down) scenario."""
    from repro.core.topology import faasnet_plan
    from repro.sim.reference import ReferenceFlowSim
    from repro.sim.engine import SimConfig
    from repro.sim.scale import apply_churn, build_manager, _function_ids

    w = cfg.wave
    mgr, members = build_manager(cfg)
    apply_churn(mgr, members, cfg)
    sim = ReferenceFlowSim(
        SimConfig(
            registry_out_cap=w.registry_out_cap,
            registry_qps=w.registry_qps,
            per_stream_cap=w.per_stream_cap,
            hop_latency=w.hop_latency,
        )
    )
    control = w.rpc.control_plane_total()
    for i, fid in enumerate(_function_ids(cfg)):
        plan = faasnet_plan(
            mgr.trees[fid],
            image_bytes=w.image_bytes,
            startup_fraction=w.startup_fraction,
            manifest_latency=w.rpc.manifest_fetch,
            piece=fid,
        )
        sim.add_plan(plan, t0=control + i * cfg.stagger_s)
    t0 = time.perf_counter()
    sim.run()
    return {"wall_s": time.perf_counter() - t0, "makespan_s": sim.now}


def main() -> None:
    from repro.sim.scale import ScaleConfig, run_scale

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vms", type=int, default=1000)
    ap.add_argument("--functions", type=int, default=5)
    ap.add_argument("--containers-per-function", type=int, default=500)
    ap.add_argument("--churn", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true", help="100 VMs / 250 containers")
    ap.add_argument("--compare-reference", action="store_true")
    ap.add_argument("--out", default="BENCH_scale.json")
    args = ap.parse_args()

    if args.quick:
        args.vms, args.containers_per_function, args.churn = 100, 50, 10

    cfg = ScaleConfig(
        n_vms=args.vms,
        n_functions=args.functions,
        containers_per_function=args.containers_per_function,
        churn_ops=args.churn,
        seed=args.seed,
    )
    t0 = time.perf_counter()
    res = run_scale(cfg)
    total_wall = time.perf_counter() - t0
    out = _result_dict(cfg, res)
    out["total_wall_s"] = total_wall
    out["paper_reference_s"] = 8.3  # §4.2: 2500 containers / 1000 VMs

    if args.compare_reference:
        ref_cfg = ScaleConfig(
            n_vms=min(args.vms, 100),
            n_functions=args.functions,
            containers_per_function=min(args.containers_per_function, 50),
            churn_ops=0,
            seed=args.seed,
        )
        inc = run_scale(ref_cfg)
        ref = _time_reference(ref_cfg)
        out["reference_compare"] = {
            "n_vms": ref_cfg.n_vms,
            "n_containers": ref_cfg.total_containers(),
            "incremental_wall_s": inc.wall_s,
            "reference_wall_s": ref["wall_s"],
            "speedup": ref["wall_s"] / inc.wall_s if inc.wall_s > 0 else float("inf"),
            "makespan_delta_s": abs(inc.makespan - ref["makespan_s"]),
        }

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(
        f"{res.n_containers} containers / {cfg.n_vms} VMs: "
        f"fetch makespan {res.makespan:.2f} s, provisioned {res.provision_makespan:.2f} s "
        f"(paper: 8.3 s), {res.events} events in {res.wall_s:.3f} s "
        f"({res.events_per_s:,.0f} ev/s), peak registry egress "
        f"{res.peak_registry_egress * 8 / 1e9:.2f} Gbps -> {args.out}"
    )


if __name__ == "__main__":
    main()
