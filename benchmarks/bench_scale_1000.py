"""Reproduce the paper's §4.2 burst: 2500 containers across 1000 VMs.

Runs the ``repro.sim.scale`` harness at deployment size on the incremental
fluid-flow engine and writes ``BENCH_scale.json`` with the provisioning
makespan, simulator event throughput, and peak registry egress.  The paper
reports 8.3 s for this wave on production infrastructure; the simulated
provisioning makespan lands in the same regime (the gap is container
start/runtime-init calibration, not network behaviour).

Usage::

    PYTHONPATH=src python benchmarks/bench_scale_1000.py            # full size
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --quick    # 100 VMs
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --compare-reference
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --mega     # + 10k-VM burst
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --giga     # + 100k-VM tier
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --profile  # cProfile run

``--compare-reference`` also times the old full-recompute engine on a
scaled-down wave (it is quadratic — full size would take hours) so the
speedup of the incremental engine is recorded alongside the results.

Every tier is run on both the incremental and the vectorized engine
(``SimConfig.engine``) and the JSON records one result block per engine —
the events/s trajectory the README's engine table quotes.  ``--giga``
appends the 100k-VM / 1M-container burst-train tier
(``repro.sim.scale.giga_burst_config``), vector-only: the incremental
engine takes that tier at ~20k events/s, so it is benchmarked at the mega
tier and the giga block records the vector speedup against it.  The burst's
events/s is asserted against ``--min-giga-evs`` (a regression gate, set at
a floor this hardware actually clears), and the tier also runs
``giga_replay_config`` — the full serving + block-provisioning trace replay
against the same 100k-VM fleet — recorded as ``giga_replay``.
``--profile`` wraps the main run in cProfile and prints the top-15
cumulative hotspots plus a ``_fold_events``/``_compact_done_heap``
queue-maintenance microbenchmark so engine regressions are diagnosable
without ad-hoc scripts.

Every run additionally records a control-plane microbenchmark: building one
10,000-node FunctionTree via ``FTManager.bulk_insert`` (``ft_build_s``),
mean churn-op (delete + re-insert) latency at that size, and mean
``pick_vm_for`` placement latency over a warm 10k-VM pool — the numbers the
O(log n) frontier/index/heap control plane (PR 2) is accountable for.
``--mega`` appends the 10× mega-burst (10k VMs / 25 functions / 100k
containers) end-to-end results.
"""
from __future__ import annotations

import argparse
import json
import time


def _result_dict(cfg, res) -> dict:
    d = {
        "engine": res.engine,
        "n_vms": cfg.n_vms,
        "n_functions": cfg.n_functions,
        "containers_per_function": cfg.containers_per_function,
        "n_containers": res.n_containers,
        "churn_ops": cfg.churn_ops,
        "seed": cfg.seed,
        "fetch_makespan_s": res.makespan,
        "provision_makespan_s": res.provision_makespan,
        "per_function_makespan_s": res.per_function,
        "n_flows": res.n_flows,
        "events": res.events,
        "wall_s": res.wall_s,
        "events_per_s": res.events_per_s,
        "peak_registry_egress_bytes_per_s": res.peak_registry_egress,
        "peak_registry_egress_gbps": res.peak_registry_egress * 8 / 1e9,
        "reparents_during_churn": res.reparents,
        "control_plane_build_s": res.build_s,
        "churn_wall_s": res.churn_s,
        "churn_op_latency_s": res.churn_op_s,
        "ft_heights": {
            fid: st["height"] for fid, st in sorted(res.tree_stats.items())
        },
    }
    if res.dispatch_stats:
        # Vector engines: scalar-vs-vector front counts, the front-width
        # histogram and the retired per-depth sweep's dispatch count —
        # ``dispatch_reduction`` is the wide-front batching factor.
        d["dispatch_stats"] = res.dispatch_stats
    return d


def _control_plane_micro(n: int = 10_000, churn: int = 500, picks: int = 1000) -> dict:
    """Time the control plane in isolation: FT build, churn ops, placement."""
    import random

    from repro.core import FTManager, VMInfo

    mgr = FTManager(max_functions_per_vm=30)
    vm_ids = [f"vm{i:05d}" for i in range(n)]
    for v in vm_ids:
        mgr.add_free_vm(VMInfo(v))
    for _ in vm_ids:
        mgr.reserve_vm()
    t0 = time.perf_counter()
    ft = mgr.bulk_insert("bench", vm_ids)
    ft_build_s = time.perf_counter() - t0
    ft.check_invariants()

    rng = random.Random(0)
    t0 = time.perf_counter()
    for _ in range(churn):
        v = vm_ids[rng.randrange(n)]
        mgr.delete("bench", v)
        mgr.insert("bench", v)
    churn_op_s = (time.perf_counter() - t0) / churn

    t0 = time.perf_counter()
    for k in range(picks):
        mgr.pick_vm_for(f"pick{k}")
    pick_s = (time.perf_counter() - t0) / picks
    return {
        "ft_nodes": n,
        "ft_build_s": ft_build_s,
        "churn_op_latency_s": churn_op_s,
        "pick_vm_latency_s": pick_s,
    }


def _queue_micro(n: int = 200_000) -> dict:
    """Time the vector engine's event-queue maintenance in isolation.

    ``_fold_events`` merges the staged ``schedule()`` backlog plus the live
    heap into one (t, seq)-sorted snapshot; ``_compact_done_heap`` rebuilds
    the completion heap without its stale (lazily-invalidated) entries.
    Both are O(n) passes over burst-sized queues on the engine's hot path,
    so ``--profile`` prints them as standalone numbers — a queue-maintenance
    regression shows up here before it is visible in end-to-end events/s.
    """
    import heapq
    import random

    import numpy as np

    from repro.sim.engine import SimConfig
    from repro.sim.vector_engine import VectorFlowSim

    rng = random.Random(0)
    sim = VectorFlowSim(SimConfig(engine="vector", record_trace=False))
    # A half-consumed sorted snapshot plus a heap of fresh arrivals — the
    # state _fold_events sees mid-burst when a bulk schedule() lands.
    ts = sorted(rng.random() * 100.0 for _ in range(n))
    sim._sts = ts
    sim._sseq = list(range(n))
    sim._spay = [None] * n
    sim._sptr = n // 2
    heap = [(rng.random() * 100.0, n + i, None) for i in range(n // 4)]
    heapq.heapify(heap)
    sim._ev_heap = heap
    t0 = time.perf_counter()
    sim._fold_events()
    fold_s = time.perf_counter() - t0

    # A completion heap where half the entries are stale epochs — the
    # steady-state ratio the lazy-invalidation compaction runs against.
    m = n
    sim._fdone = np.zeros(m, dtype=bool)
    sim._fstarted = np.ones(m, dtype=bool)
    sim._epoch = np.zeros(m, dtype=np.int64)
    done = [
        (rng.random() * 100.0, fid, 1 if rng.random() < 0.5 else 0)
        for fid in range(m)
    ]
    heapq.heapify(done)
    sim._done_heap = done
    t0 = time.perf_counter()
    sim._compact_done_heap()
    compact_s = time.perf_counter() - t0
    return {
        "n_events": n + n // 4 - n // 2,
        "fold_events_s": fold_s,
        "done_heap_entries": m,
        "compact_done_heap_s": compact_s,
    }


def _time_reference(cfg) -> dict:
    """Time the full-recompute oracle on the same (scaled-down) scenario."""
    from repro.core.topology import faasnet_plan
    from repro.sim.reference import ReferenceFlowSim
    from repro.sim.engine import SimConfig
    from repro.sim.scale import apply_churn, build_manager, _function_ids

    w = cfg.wave
    mgr, members = build_manager(cfg)
    apply_churn(mgr, members, cfg)
    sim = ReferenceFlowSim(
        SimConfig(
            registry_out_cap=w.registry_out_cap,
            registry_qps=w.registry_qps,
            per_stream_cap=w.per_stream_cap,
            hop_latency=w.hop_latency,
        )
    )
    control = w.rpc.control_plane_total()
    for i, fid in enumerate(_function_ids(cfg)):
        plan = faasnet_plan(
            mgr.trees[fid],
            image_bytes=w.image_bytes,
            startup_fraction=w.startup_fraction,
            manifest_latency=w.rpc.manifest_fetch,
            piece=fid,
        )
        sim.add_plan(plan, t0=control + i * cfg.stagger_s)
    t0 = time.perf_counter()
    sim.run()
    return {"wall_s": time.perf_counter() - t0, "makespan_s": sim.now}


def _run_vector_twin(cfg, base, run_scale) -> dict:
    """Re-run a tier with ``engine="vector"`` and record the comparison."""
    import dataclasses

    vcfg = dataclasses.replace(
        cfg, wave=dataclasses.replace(cfg.wave, engine="vector")
    )
    t0 = time.perf_counter()
    vres = run_scale(vcfg)
    d = _result_dict(vcfg, vres)
    d["total_wall_s"] = time.perf_counter() - t0
    d["matches_incremental"] = (
        vres.makespan == base.makespan
        and vres.peak_registry_egress == base.peak_registry_egress
    )
    d["speedup_vs_incremental"] = (
        base.wall_s / vres.wall_s if vres.wall_s > 0 else float("inf")
    )
    return d


def main() -> None:
    from repro.sim.scale import ScaleConfig, run_scale

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vms", type=int, default=1000)
    ap.add_argument("--functions", type=int, default=5)
    ap.add_argument("--containers-per-function", type=int, default=500)
    ap.add_argument("--churn", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true", help="100 VMs / 250 containers")
    ap.add_argument("--compare-reference", action="store_true")
    ap.add_argument(
        "--mega",
        action="store_true",
        help="also run the 10k-VM / 25-function / 100k-container mega-burst",
    )
    ap.add_argument(
        "--giga",
        action="store_true",
        help="also run the 100k-VM / 1M-container burst train (vector engine)",
    )
    ap.add_argument(
        "--profile",
        action="store_true",
        help="wrap the main run in cProfile and print the top-15 hotspots "
        "plus the _fold_events/_compact_done_heap queue microbenchmark",
    )
    ap.add_argument(
        "--min-giga-evs",
        type=float,
        default=60_000.0,
        help="events/s floor asserted on the --giga burst (vector engine)",
    )
    ap.add_argument("--out", default="BENCH_scale.json")
    args = ap.parse_args()

    if args.quick:
        args.vms, args.containers_per_function, args.churn = 100, 50, 10

    cfg = ScaleConfig(
        n_vms=args.vms,
        n_functions=args.functions,
        containers_per_function=args.containers_per_function,
        churn_ops=args.churn,
        seed=args.seed,
    )
    profiler = None
    if args.profile:
        import cProfile

        profiler = cProfile.Profile()
        profiler.enable()
    t0 = time.perf_counter()
    res = run_scale(cfg)
    total_wall = time.perf_counter() - t0
    if profiler is not None:
        import pstats

        profiler.disable()
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(15)
        qm = _queue_micro()
        out_queue_micro = qm
        print(
            f"queue micro: _fold_events merges {qm['n_events']:,} events in "
            f"{qm['fold_events_s'] * 1e3:.1f} ms, _compact_done_heap rebuilds "
            f"{qm['done_heap_entries']:,} entries in "
            f"{qm['compact_done_heap_s'] * 1e3:.1f} ms"
        )
    else:
        out_queue_micro = None
    out = _result_dict(cfg, res)
    out["total_wall_s"] = total_wall
    if out_queue_micro is not None:
        out["queue_micro"] = out_queue_micro
    out["paper_reference_s"] = 8.3  # §4.2: 2500 containers / 1000 VMs
    out["vector"] = _run_vector_twin(cfg, res, run_scale)

    micro = _control_plane_micro()
    out["control_plane_micro"] = micro
    out["ft_build_s"] = micro["ft_build_s"]  # 10k-node FT via bulk_insert

    if args.mega:
        from repro.sim.scale import mega_burst_config

        mcfg = mega_burst_config(seed=args.seed)
        t0 = time.perf_counter()
        mres = run_scale(mcfg)
        mwall = time.perf_counter() - t0
        mega = _result_dict(mcfg, mres)
        mega["total_wall_s"] = mwall
        mega["vector"] = _run_vector_twin(mcfg, mres, run_scale)
        out["mega_burst"] = mega

    if args.giga:
        from repro.sim.multi_tenant import run_multi_tenant
        from repro.sim.scale import giga_burst_config, giga_replay_config

        gcfg = giga_burst_config(seed=args.seed)
        t0 = time.perf_counter()
        gres = run_scale(gcfg)
        gwall = time.perf_counter() - t0
        giga = _result_dict(gcfg, gres)
        giga["total_wall_s"] = gwall
        giga["floor_events_per_s"] = args.min_giga_evs
        mega_inc = out.get("mega_burst")
        if mega_inc:
            giga["speedup_vs_mega_incremental"] = (
                gres.events_per_s / mega_inc["events_per_s"]
            )
        out["giga_burst"] = giga

        # Full trace replay at the same fleet size: serving + block-level
        # provisioning + failover on one shared vector FlowSim.
        rcfg = giga_replay_config(seed=args.seed)
        t0 = time.perf_counter()
        rres = run_multi_tenant(rcfg)
        rwall = time.perf_counter() - t0
        out["giga_replay"] = {
            "n_tenants": len(rcfg.tenants),
            "vm_pool_size": rcfg.vm_pool_size,
            "duration_s": rcfg.duration_s(),
            "engine": rcfg.wave.engine,
            "serving": rcfg.serving is not None,
            "blocks": rcfg.images is not None,
            "total_wall_s": rwall,
            "requests": sum(t.requests for t in rres.per_tenant.values()),
            "completed": sum(t.completed for t in rres.per_tenant.values()),
            "cold_starts": rres.cold_starts,
            "failovers": rres.failovers,
            "prov_makespan_s": rres.prov_makespan_s,
            "vm_hours": rres.vm_hours(),
            "peak_nic_utilization": rres.peak_nic_utilization,
            "worst_p99_response_s": max(
                t.p99_response_s for t in rres.per_tenant.values()
            ),
            "peak_registry_egress_gbps": rres.peak_registry_egress * 8 / 1e9,
        }

    if args.compare_reference:
        ref_cfg = ScaleConfig(
            n_vms=min(args.vms, 100),
            n_functions=args.functions,
            containers_per_function=min(args.containers_per_function, 50),
            churn_ops=0,
            seed=args.seed,
        )
        inc = run_scale(ref_cfg)
        ref = _time_reference(ref_cfg)
        out["reference_compare"] = {
            "n_vms": ref_cfg.n_vms,
            "n_containers": ref_cfg.total_containers(),
            "incremental_wall_s": inc.wall_s,
            "reference_wall_s": ref["wall_s"],
            "speedup": ref["wall_s"] / inc.wall_s if inc.wall_s > 0 else float("inf"),
            "makespan_delta_s": abs(inc.makespan - ref["makespan_s"]),
        }

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(
        f"{res.n_containers} containers / {cfg.n_vms} VMs: "
        f"fetch makespan {res.makespan:.2f} s, provisioned {res.provision_makespan:.2f} s "
        f"(paper: 8.3 s), {res.events} events in {res.wall_s:.3f} s "
        f"({res.events_per_s:,.0f} ev/s), peak registry egress "
        f"{res.peak_registry_egress * 8 / 1e9:.2f} Gbps -> {args.out}"
    )
    v = out["vector"]
    print(
        f"vector engine: {v['events_per_s']:,.0f} ev/s "
        f"({v['speedup_vs_incremental']:.1f}x incremental, "
        f"match={v['matches_incremental']})"
    )
    print(
        f"control plane: 10k-node FT build {micro['ft_build_s']*1e3:.1f} ms, "
        f"churn op {micro['churn_op_latency_s']*1e6:.1f} us, "
        f"pick_vm_for {micro['pick_vm_latency_s']*1e6:.1f} us"
    )
    if args.mega:
        m = out["mega_burst"]
        print(
            f"mega burst: {m['n_containers']} containers / {m['n_vms']} VMs "
            f"in {m['total_wall_s']:.1f} s wall (build {m['control_plane_build_s']:.2f} s, "
            f"engine {m['wall_s']:.2f} s), fetch makespan {m['fetch_makespan_s']:.2f} s; "
            f"vector {m['vector']['events_per_s']:,.0f} ev/s "
            f"(match={m['vector']['matches_incremental']})"
        )
    if args.giga:
        g = out["giga_burst"]
        extra = (
            f", {g['speedup_vs_mega_incremental']:.1f}x the mega-tier "
            f"incremental events/s"
            if "speedup_vs_mega_incremental" in g
            else ""
        )
        print(
            f"giga burst: {g['n_containers']} containers / {g['n_vms']} VMs "
            f"in {g['total_wall_s']:.1f} s wall (engine {g['wall_s']:.2f} s, "
            f"{g['events_per_s']:,.0f} ev/s{extra})"
        )
        r = out["giga_replay"]
        print(
            f"giga replay: {r['n_tenants']} tenants / {r['vm_pool_size']} VM "
            f"pool / {r['duration_s']} s trace (serving+blocks) in "
            f"{r['total_wall_s']:.1f} s wall: {r['requests']:,} requests, "
            f"{r['cold_starts']} cold starts, {r['failovers']} failover(s), "
            f"worst p99 {r['worst_p99_response_s']:.2f} s"
        )
        if g["events_per_s"] < args.min_giga_evs:
            raise SystemExit(
                f"giga burst regression: {g['events_per_s']:,.0f} ev/s is "
                f"below the {args.min_giga_evs:,.0f} ev/s floor"
            )


if __name__ == "__main__":
    main()
