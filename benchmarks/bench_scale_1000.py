"""Reproduce the paper's §4.2 burst: 2500 containers across 1000 VMs.

Runs the ``repro.sim.scale`` harness at deployment size on the incremental
fluid-flow engine and writes ``BENCH_scale.json`` with the provisioning
makespan, simulator event throughput, and peak registry egress.  The paper
reports 8.3 s for this wave on production infrastructure; the simulated
provisioning makespan lands in the same regime (the gap is container
start/runtime-init calibration, not network behaviour).

Usage::

    PYTHONPATH=src python benchmarks/bench_scale_1000.py            # full size
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --quick    # 100 VMs
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --compare-reference
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --mega     # + 10k-VM burst
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --giga     # + 100k-VM tier
    PYTHONPATH=src python benchmarks/bench_scale_1000.py --profile  # cProfile run

``--compare-reference`` also times the old full-recompute engine on a
scaled-down wave (it is quadratic — full size would take hours) so the
speedup of the incremental engine is recorded alongside the results.

Every tier is run on both the incremental and the vectorized engine
(``SimConfig.engine``) and the JSON records one result block per engine —
the events/s trajectory the README's engine table quotes.  ``--giga``
appends the 100k-VM / 1M-container burst-train tier
(``repro.sim.scale.giga_burst_config``), vector-only: the incremental
engine takes that tier at ~20k events/s, so it is benchmarked at the mega
tier and the giga block records the vector speedup against it.
``--profile`` wraps the main run in cProfile and prints the top-15
cumulative hotspots so engine regressions are diagnosable without ad-hoc
scripts.

Every run additionally records a control-plane microbenchmark: building one
10,000-node FunctionTree via ``FTManager.bulk_insert`` (``ft_build_s``),
mean churn-op (delete + re-insert) latency at that size, and mean
``pick_vm_for`` placement latency over a warm 10k-VM pool — the numbers the
O(log n) frontier/index/heap control plane (PR 2) is accountable for.
``--mega`` appends the 10× mega-burst (10k VMs / 25 functions / 100k
containers) end-to-end results.
"""
from __future__ import annotations

import argparse
import json
import time


def _result_dict(cfg, res) -> dict:
    return {
        "engine": res.engine,
        "n_vms": cfg.n_vms,
        "n_functions": cfg.n_functions,
        "containers_per_function": cfg.containers_per_function,
        "n_containers": res.n_containers,
        "churn_ops": cfg.churn_ops,
        "seed": cfg.seed,
        "fetch_makespan_s": res.makespan,
        "provision_makespan_s": res.provision_makespan,
        "per_function_makespan_s": res.per_function,
        "n_flows": res.n_flows,
        "events": res.events,
        "wall_s": res.wall_s,
        "events_per_s": res.events_per_s,
        "peak_registry_egress_bytes_per_s": res.peak_registry_egress,
        "peak_registry_egress_gbps": res.peak_registry_egress * 8 / 1e9,
        "reparents_during_churn": res.reparents,
        "control_plane_build_s": res.build_s,
        "churn_wall_s": res.churn_s,
        "churn_op_latency_s": res.churn_op_s,
        "ft_heights": {
            fid: st["height"] for fid, st in sorted(res.tree_stats.items())
        },
    }


def _control_plane_micro(n: int = 10_000, churn: int = 500, picks: int = 1000) -> dict:
    """Time the control plane in isolation: FT build, churn ops, placement."""
    import random

    from repro.core import FTManager, VMInfo

    mgr = FTManager(max_functions_per_vm=30)
    vm_ids = [f"vm{i:05d}" for i in range(n)]
    for v in vm_ids:
        mgr.add_free_vm(VMInfo(v))
    for _ in vm_ids:
        mgr.reserve_vm()
    t0 = time.perf_counter()
    ft = mgr.bulk_insert("bench", vm_ids)
    ft_build_s = time.perf_counter() - t0
    ft.check_invariants()

    rng = random.Random(0)
    t0 = time.perf_counter()
    for _ in range(churn):
        v = vm_ids[rng.randrange(n)]
        mgr.delete("bench", v)
        mgr.insert("bench", v)
    churn_op_s = (time.perf_counter() - t0) / churn

    t0 = time.perf_counter()
    for k in range(picks):
        mgr.pick_vm_for(f"pick{k}")
    pick_s = (time.perf_counter() - t0) / picks
    return {
        "ft_nodes": n,
        "ft_build_s": ft_build_s,
        "churn_op_latency_s": churn_op_s,
        "pick_vm_latency_s": pick_s,
    }


def _time_reference(cfg) -> dict:
    """Time the full-recompute oracle on the same (scaled-down) scenario."""
    from repro.core.topology import faasnet_plan
    from repro.sim.reference import ReferenceFlowSim
    from repro.sim.engine import SimConfig
    from repro.sim.scale import apply_churn, build_manager, _function_ids

    w = cfg.wave
    mgr, members = build_manager(cfg)
    apply_churn(mgr, members, cfg)
    sim = ReferenceFlowSim(
        SimConfig(
            registry_out_cap=w.registry_out_cap,
            registry_qps=w.registry_qps,
            per_stream_cap=w.per_stream_cap,
            hop_latency=w.hop_latency,
        )
    )
    control = w.rpc.control_plane_total()
    for i, fid in enumerate(_function_ids(cfg)):
        plan = faasnet_plan(
            mgr.trees[fid],
            image_bytes=w.image_bytes,
            startup_fraction=w.startup_fraction,
            manifest_latency=w.rpc.manifest_fetch,
            piece=fid,
        )
        sim.add_plan(plan, t0=control + i * cfg.stagger_s)
    t0 = time.perf_counter()
    sim.run()
    return {"wall_s": time.perf_counter() - t0, "makespan_s": sim.now}


def _run_vector_twin(cfg, base, run_scale) -> dict:
    """Re-run a tier with ``engine="vector"`` and record the comparison."""
    import dataclasses

    vcfg = dataclasses.replace(
        cfg, wave=dataclasses.replace(cfg.wave, engine="vector")
    )
    t0 = time.perf_counter()
    vres = run_scale(vcfg)
    d = _result_dict(vcfg, vres)
    d["total_wall_s"] = time.perf_counter() - t0
    d["matches_incremental"] = (
        vres.makespan == base.makespan
        and vres.peak_registry_egress == base.peak_registry_egress
    )
    d["speedup_vs_incremental"] = (
        base.wall_s / vres.wall_s if vres.wall_s > 0 else float("inf")
    )
    return d


def main() -> None:
    from repro.sim.scale import ScaleConfig, run_scale

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vms", type=int, default=1000)
    ap.add_argument("--functions", type=int, default=5)
    ap.add_argument("--containers-per-function", type=int, default=500)
    ap.add_argument("--churn", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true", help="100 VMs / 250 containers")
    ap.add_argument("--compare-reference", action="store_true")
    ap.add_argument(
        "--mega",
        action="store_true",
        help="also run the 10k-VM / 25-function / 100k-container mega-burst",
    )
    ap.add_argument(
        "--giga",
        action="store_true",
        help="also run the 100k-VM / 1M-container burst train (vector engine)",
    )
    ap.add_argument(
        "--profile",
        action="store_true",
        help="wrap the main run in cProfile and print the top-15 hotspots",
    )
    ap.add_argument("--out", default="BENCH_scale.json")
    args = ap.parse_args()

    if args.quick:
        args.vms, args.containers_per_function, args.churn = 100, 50, 10

    cfg = ScaleConfig(
        n_vms=args.vms,
        n_functions=args.functions,
        containers_per_function=args.containers_per_function,
        churn_ops=args.churn,
        seed=args.seed,
    )
    profiler = None
    if args.profile:
        import cProfile

        profiler = cProfile.Profile()
        profiler.enable()
    t0 = time.perf_counter()
    res = run_scale(cfg)
    total_wall = time.perf_counter() - t0
    if profiler is not None:
        import pstats

        profiler.disable()
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(15)
    out = _result_dict(cfg, res)
    out["total_wall_s"] = total_wall
    out["paper_reference_s"] = 8.3  # §4.2: 2500 containers / 1000 VMs
    out["vector"] = _run_vector_twin(cfg, res, run_scale)

    micro = _control_plane_micro()
    out["control_plane_micro"] = micro
    out["ft_build_s"] = micro["ft_build_s"]  # 10k-node FT via bulk_insert

    if args.mega:
        from repro.sim.scale import mega_burst_config

        mcfg = mega_burst_config(seed=args.seed)
        t0 = time.perf_counter()
        mres = run_scale(mcfg)
        mwall = time.perf_counter() - t0
        mega = _result_dict(mcfg, mres)
        mega["total_wall_s"] = mwall
        mega["vector"] = _run_vector_twin(mcfg, mres, run_scale)
        out["mega_burst"] = mega

    if args.giga:
        from repro.sim.scale import giga_burst_config

        gcfg = giga_burst_config(seed=args.seed)
        t0 = time.perf_counter()
        gres = run_scale(gcfg)
        gwall = time.perf_counter() - t0
        giga = _result_dict(gcfg, gres)
        giga["total_wall_s"] = gwall
        mega_inc = out.get("mega_burst")
        if mega_inc:
            giga["speedup_vs_mega_incremental"] = (
                gres.events_per_s / mega_inc["events_per_s"]
            )
        out["giga_burst"] = giga

    if args.compare_reference:
        ref_cfg = ScaleConfig(
            n_vms=min(args.vms, 100),
            n_functions=args.functions,
            containers_per_function=min(args.containers_per_function, 50),
            churn_ops=0,
            seed=args.seed,
        )
        inc = run_scale(ref_cfg)
        ref = _time_reference(ref_cfg)
        out["reference_compare"] = {
            "n_vms": ref_cfg.n_vms,
            "n_containers": ref_cfg.total_containers(),
            "incremental_wall_s": inc.wall_s,
            "reference_wall_s": ref["wall_s"],
            "speedup": ref["wall_s"] / inc.wall_s if inc.wall_s > 0 else float("inf"),
            "makespan_delta_s": abs(inc.makespan - ref["makespan_s"]),
        }

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(
        f"{res.n_containers} containers / {cfg.n_vms} VMs: "
        f"fetch makespan {res.makespan:.2f} s, provisioned {res.provision_makespan:.2f} s "
        f"(paper: 8.3 s), {res.events} events in {res.wall_s:.3f} s "
        f"({res.events_per_s:,.0f} ev/s), peak registry egress "
        f"{res.peak_registry_egress * 8 / 1e9:.2f} Gbps -> {args.out}"
    )
    v = out["vector"]
    print(
        f"vector engine: {v['events_per_s']:,.0f} ev/s "
        f"({v['speedup_vs_incremental']:.1f}x incremental, "
        f"match={v['matches_incremental']})"
    )
    print(
        f"control plane: 10k-node FT build {micro['ft_build_s']*1e3:.1f} ms, "
        f"churn op {micro['churn_op_latency_s']*1e6:.1f} us, "
        f"pick_vm_for {micro['pick_vm_latency_s']*1e6:.1f} us"
    )
    if args.mega:
        m = out["mega_burst"]
        print(
            f"mega burst: {m['n_containers']} containers / {m['n_vms']} VMs "
            f"in {m['total_wall_s']:.1f} s wall (build {m['control_plane_build_s']:.2f} s, "
            f"engine {m['wall_s']:.2f} s), fetch makespan {m['fetch_makespan_s']:.2f} s; "
            f"vector {m['vector']['events_per_s']:,.0f} ev/s "
            f"(match={m['vector']['matches_incremental']})"
        )
    if args.giga:
        g = out["giga_burst"]
        extra = (
            f", {g['speedup_vs_mega_incremental']:.1f}x the mega-tier "
            f"incremental events/s"
            if "speedup_vs_mega_incremental" in g
            else ""
        )
        print(
            f"giga burst: {g['n_containers']} containers / {g['n_vms']} VMs "
            f"in {g['total_wall_s']:.1f} s wall (engine {g['wall_s']:.2f} s, "
            f"{g['events_per_s']:,.0f} ev/s{extra})"
        )


if __name__ == "__main__":
    main()
