"""Shared-pool placement benchmark (ISSUE 5; paper §3.1 + §5).

Replays ``multi_tenant_config()`` (8 mixed-trace tenants, one 2000-VM pool)
under four scheduler configurations and writes ``BENCH_placement.json``:

  * **exclusive** — the legacy leasing: every instance takes a whole VM;
  * **shared** — memory-aware cross-tenant placement through
    ``FTManager.pick_vm_for`` with the §5 FT-aware refinement;
  * **shared_binpack** — same shared pool, pure binpack placement
    (fullest-VM-first), the §5 comparison baseline;
  * **shared_histogram** — shared + the predictive keep-alive-histogram
    reclaim policy (vs the fixed idle-TTL the other rows use).

Reported per row: VM-hours (∫ pool-out-of-free dt), cold-start count,
per-tenant p99 provisioning latency, peak per-VM NIC utilization and peak
registry egress.  Two claims are asserted in-bench:

  1. the shared pool spends fewer VM-hours than exclusive leasing;
  2. FT-aware placement matches or beats binpack on the worst tenant's
     p99 provisioning latency (the §5 refinement, measured on a shared
     pool under the trace mix).

Usage::

    PYTHONPATH=src python benchmarks/bench_placement.py            # 8 x 2000
    PYTHONPATH=src python benchmarks/bench_placement.py --quick    # 3 x 300
    PYTHONPATH=src python benchmarks/bench_placement.py --skip-checks
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time


def _run(args, **kw):
    from repro.sim import MultiTenantReplay, multi_tenant_config

    cfg = multi_tenant_config(
        args.seed,
        n_tenants=args.tenants,
        vm_pool_size=args.pool,
        minutes=args.minutes,
        scale=args.scale,
        failover_at=args.failover_at,
        check_partition=not args.skip_checks,
        **kw,
    )
    t0 = time.perf_counter()
    res = MultiTenantReplay(cfg).run()
    return res, time.perf_counter() - t0


def _row(res, wall: float) -> dict:
    return {
        "wall_s": wall,
        "vm_hours": res.vm_hours(),
        "cold_starts": res.cold_starts,
        "prov_makespan_s": res.prov_makespan_s,
        "total_prov_time_s": res.total_prov_time_s,
        "peak_nic_utilization": res.peak_nic_utilization,
        "peak_registry_egress_gbps": res.peak_registry_egress * 8 / 1e9,
        "manager_stats": dict(res.manager_stats),
        "per_tenant_p99_prov_s": {
            fid: tr.p99_prov_s for fid, tr in sorted(res.per_tenant.items())
        },
        "worst_p99_prov_s": max(tr.p99_prov_s for tr in res.per_tenant.values()),
        "per_tenant": {
            fid: dataclasses.asdict(tr) for fid, tr in sorted(res.per_tenant.items())
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--pool", type=int, default=2000)
    ap.add_argument("--minutes", type=int, default=25)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--failover-at", type=int, default=12 * 60)
    ap.add_argument("--quick", action="store_true", help="3 tenants / 300 VMs / 8 min")
    ap.add_argument(
        "--skip-checks",
        action="store_true",
        help="skip the per-tick shared-pool invariant checks and assertions",
    )
    ap.add_argument("--out", default="BENCH_placement.json")
    args = ap.parse_args()
    if args.quick:
        args.tenants, args.pool, args.minutes = 3, 300, 8
        args.failover_at = min(args.failover_at, 4 * 60)

    rows: dict[str, dict] = {}
    excl, wall = _run(args, placement="exclusive")
    rows["exclusive"] = _row(excl, wall)
    shared, wall = _run(args, placement="shared")
    rows["shared"] = _row(shared, wall)
    binpack, wall = _run(args, placement="shared", ft_aware_placement=False)
    rows["shared_binpack"] = _row(binpack, wall)
    hist, wall = _run(args, placement="shared", reclaim="histogram")
    rows["shared_histogram"] = _row(hist, wall)

    vm_hours_saved_pct = (
        (1.0 - shared.vm_seconds / excl.vm_seconds) * 100.0
        if excl.vm_seconds > 0
        else float("nan")
    )
    out = {
        "n_tenants": args.tenants,
        "vm_pool_size": args.pool,
        "minutes": args.minutes,
        "trace_scale": args.scale,
        "seed": args.seed,
        "failover_at_s": args.failover_at,
        "rows": rows,
        "shared_vs_exclusive_vm_hours_saved_pct": vm_hours_saved_pct,
        "ft_aware_vs_binpack_worst_p99_prov": {
            "ft_aware_s": rows["shared"]["worst_p99_prov_s"],
            "binpack_s": rows["shared_binpack"]["worst_p99_prov_s"],
        },
        "histogram_vs_fixed_reclaim": {
            "vm_hours_fixed": rows["shared"]["vm_hours"],
            "vm_hours_histogram": rows["shared_histogram"]["vm_hours"],
            "cold_starts_fixed": rows["shared"]["cold_starts"],
            "cold_starts_histogram": rows["shared_histogram"]["cold_starts"],
        },
    }

    if not args.skip_checks:
        assert shared.vm_seconds < excl.vm_seconds, (
            f"shared pool did NOT save VM-hours: shared={shared.vm_hours():.1f} "
            f"vs exclusive={excl.vm_hours():.1f}"
        )
        assert (
            rows["shared"]["worst_p99_prov_s"]
            <= rows["shared_binpack"]["worst_p99_prov_s"]
        ), (
            f"FT-aware placement lost to binpack on worst-tenant p99 "
            f"provisioning: {rows['shared']['worst_p99_prov_s']:.2f}s vs "
            f"{rows['shared_binpack']['worst_p99_prov_s']:.2f}s"
        )
        out["checks_passed"] = True

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")

    print(
        f"{args.tenants} tenants / {args.pool} VMs / {args.minutes} min "
        f"({'quick' if args.quick else 'full'}):"
    )
    hdr = (
        f"{'row':18s} {'vm_hours':>9s} {'cold':>6s} {'worst_p99prov':>13s} "
        f"{'peak_nic':>8s} {'peak_reg':>8s}"
    )
    print(hdr)
    for name, r in rows.items():
        print(
            f"{name:18s} {r['vm_hours']:9.1f} {r['cold_starts']:6d} "
            f"{r['worst_p99_prov_s']:12.2f}s {r['peak_nic_utilization']:8.2f} "
            f"{r['peak_registry_egress_gbps']:6.2f}Gb"
        )
    print(
        f"shared saves {vm_hours_saved_pct:.1f}% VM-hours vs exclusive; "
        f"FT-aware worst p99 prov {rows['shared']['worst_p99_prov_s']:.2f}s "
        f"vs binpack {rows['shared_binpack']['worst_p99_prov_s']:.2f}s; "
        f"histogram reclaim {rows['shared_histogram']['vm_hours']:.1f} VM-h "
        f"vs fixed {rows['shared']['vm_hours']:.1f} VM-h -> {args.out}"
    )


if __name__ == "__main__":
    main()
